"""Mamba2 block via State Space Duality (SSD), TPU-adapted.

The CUDA Mamba2 kernel is a warp-level selective scan; the TPU-native
formulation is the *chunked* SSD algorithm from the paper itself
(arXiv:2405.21060 §6): the sequence is split into chunks, intra-chunk terms
become (MXU-friendly) matmuls against a decay-masked kernel matrix, and only
a tiny inter-chunk state recurrence remains (lax.scan over n_chunks).

Layout: x:[B,S,H,P] heads H = d_inner/head_dim, state N = ssm_state,
B/C shared across heads (n_groups = 1).

Recurrence (per head): h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T,
y_t = C_t . h_t + D * x_t.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.arch_config import ArchConfig
from repro.models.layers import ParamSpec, rmsnorm, rmsnorm_spec


class SSMCache(NamedTuple):
    conv: jax.Array  # [B, conv_w - 1, conv_channels]
    state: jax.Array  # [B, H, N, P]


def ssm_specs(cfg: ArchConfig) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    w = cfg.ssm_conv
    return {
        "wz": ParamSpec((d, di), ("embed", "inner")),
        "wx": ParamSpec((d, di), ("embed", "inner")),
        "wB": ParamSpec((d, ns), ("embed", "state")),
        "wC": ParamSpec((d, ns), ("embed", "state")),
        "wdt": ParamSpec((d, nh), ("embed", "heads")),
        "conv_w": ParamSpec((w, di + 2 * ns), (None, "inner")),
        "conv_b": ParamSpec((di + 2 * ns,), ("inner",), init="zeros"),
        "dt_bias": ParamSpec((nh,), ("heads",), init="ssm_dt_bias"),
        "A_log": ParamSpec((nh,), ("heads",), init="ssm_a"),
        "D": ParamSpec((nh,), ("heads",), init="ones"),
        "norm": rmsnorm_spec(di, "inner"),
        "out": ParamSpec((di, d), ("inner", "embed")),
    }


def _causal_conv(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4: unrolled adds beat a conv op here
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    return out + b


def ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x: [B,S,H,P]  dt: [B,S,H]  a_log: [H]  bmat/cmat: [B,S,N]
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // q

    a = -jnp.exp(a_log.astype(jnp.float32))  # [H], negative
    da = dt.astype(jnp.float32) * a  # [B,S,H]

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    dac = da.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=2)  # [B,nc,Q,H]

    # ---- intra-chunk: y_ij = (C_i.B_j) exp(cum_i - cum_j) dt_j x_j, j<=i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: the upper triangle holds large positive exponents
    # whose overflow would poison the backward pass through where()
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # [B,nc,Q,Q]
    kern = cb[..., None] * decay * dtc[:, :, None, :, :]  # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", kern, xc.astype(jnp.float32))

    # ---- chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                        decay_end * dtc, bc, xc.astype(jnp.float32))

    # ---- inter-chunk recurrence over nc
    total = jnp.exp(cum[:, :, -1, :])  # [B,nc,H] chunk total decay
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        tot, st = inp  # tot: [B,H]; st: [B,H,N,P]
        new = carry * tot[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final, entering = jax.lax.scan(
        step, h0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(states, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)  # [B,nc,H,N,P]

    # ---- inter-chunk contribution: C_i . (exp(cum_i) * h_entering)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cc, jnp.exp(cum), entering)

    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssm_forward(p: dict, cfg: ArchConfig, hidden: jax.Array,
                init_cache: SSMCache | None = None, return_cache: bool = False):
    """Full-sequence Mamba2 block. hidden: [B,S,d_model]."""
    b, s, _ = hidden.shape
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = di // nh

    z = hidden @ p["wz"]
    xbc = jnp.concatenate(
        [hidden @ p["wx"], hidden @ p["wB"], hidden @ p["wC"]], axis=-1)
    dt_raw = hidden @ p["wdt"]

    if init_cache is not None:
        xbc_in = jnp.concatenate([init_cache.conv, xbc], axis=1)
        conv_out = _causal_conv(p["conv_w"], p["conv_b"], xbc_in)[:, -s:]
    else:
        conv_out = _causal_conv(p["conv_w"], p["conv_b"], xbc)
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[..., :di].reshape(b, s, nh, hd)
    bmat = conv_out[..., di : di + ns]
    cmat = conv_out[..., di + ns :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, final_state = ssd_chunked(
        x, dt, p["A_log"], bmat, cmat, cfg.ssm_chunk,
        None if init_cache is None else init_cache.state)
    y = y + p["D"][None, None, :, None] * x
    y = y.reshape(b, s, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out"]
    if return_cache:
        w = cfg.ssm_conv
        src = xbc_in if init_cache is not None else jnp.concatenate(
            [jnp.zeros((b, w - 1, xbc.shape[-1]), xbc.dtype), xbc], axis=1)
        return out, SSMCache(src[:, -(w - 1):], final_state)
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = di // nh
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * ns), dtype),
        state=jnp.zeros((batch, nh, ns, hd), jnp.float32),
    )


def ssm_cache_logical_axes() -> SSMCache:
    return SSMCache(
        conv=("batch", None, "inner"),
        state=("batch", "heads", "state", None),
    )


def ssm_decode_step(p: dict, cfg: ArchConfig, hidden: jax.Array,
                    cache: SSMCache):
    """One-token decode. hidden: [B,1,d_model] -> (out [B,1,d], new cache)."""
    b = hidden.shape[0]
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = di // nh
    h1 = hidden[:, 0]  # [B, d]

    z = h1 @ p["wz"]
    xbc_new = jnp.concatenate([h1 @ p["wx"], h1 @ p["wB"], h1 @ p["wC"]],
                              axis=-1)  # [B, C]
    dt_raw = h1 @ p["wdt"]

    # conv over (stored w-1 inputs, new input)
    hist = jnp.concatenate([cache.conv, xbc_new[:, None]], axis=1)  # [B,W,C]
    conv_out = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[:, :di].reshape(b, nh, hd)
    bmat = conv_out[:, di : di + ns]
    cmat = conv_out[:, di + ns :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, bmat.astype(jnp.float32),
                     x.astype(jnp.float32))
    state = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, di).astype(hidden.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out"])[:, None]
    return out, SSMCache(hist[:, 1:], state)

"""Shims over ``jax.experimental.pallas.tpu`` API drift.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` in 0.4.x, ``CompilerParams`` from 0.5); kernels
import the resolved name from here so they run against either.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

from repro.data.partition import dirichlet_partition, class_histogram
from repro.data.synthetic import (Dataset, gaussian_mixture, token_sequences,
                                  train_val_test_split, batches)
from repro.data.distill_sources import (DistillSource, UnlabeledDataset,
                                        GeneratorSource, RandomNoiseSource)

"""Pytree checkpointing: flat .npz with path-encoded keys + a JSON manifest.

No external deps (orbax unavailable offline).  Handles arbitrary nested
dict/tuple/list/NamedTuple pytrees of jnp arrays and python scalars.

All writes are atomic: payload and manifest land in same-directory temp
files first and are moved into place with ``os.replace``, manifest LAST —
a crash mid-write leaves either the previous complete checkpoint or a
stray ``.tmp`` file, never a truncated ``.npz``/manifest pair that loads
garbage (kill-mid-write is pinned in ``tests/test_robust_fusion.py``).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _atomic_savez(path: str, arrays: dict) -> None:
    """Write ``arrays`` to ``path`` via a same-directory temp file +
    ``os.replace`` (atomic on POSIX within one filesystem)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _atomic_json(path: str, payload: dict, **dump_kwargs) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, **dump_kwargs)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _encode_leaf(x, name: str, dtypes: dict) -> np.ndarray:
    a = np.asarray(x)
    if a.dtype == jnp.bfloat16:  # numpy has no bf16: store uint16 bits
        dtypes[name] = "bfloat16"
        a = a.view(np.uint16)
    return a


def _decode_leaf(a: np.ndarray, name: str, dtypes: dict):
    if dtypes.get(name) == "bfloat16":
        return jnp.asarray(a).view(jnp.bfloat16)
    return jnp.asarray(a)


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, {}
    for i, x in enumerate(leaves):
        arrays[f"leaf_{i}"] = _encode_leaf(x, f"leaf_{i}", dtypes)
    _atomic_savez(path if path.endswith(".npz") else path + ".npz", arrays)
    # manifest last: its presence marks the checkpoint complete
    _atomic_json(_manifest_path(path), {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "metadata": metadata or {},
    }, indent=2)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    with open(_manifest_path(path)) as f:
        dtypes = json.load(f).get("dtypes", {})
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(leaves_like)
    loaded = [_decode_leaf(npz[f"leaf_{i}"], f"leaf_{i}", dtypes)
              for i in range(n)]
    for got, want in zip(loaded, leaves_like):
        if hasattr(want, "shape") and tuple(got.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint leaf shape {got.shape} != template {want.shape}")
    return jax.tree.unflatten(treedef, loaded)


def metadata(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["metadata"]


# ---------------------------------------------------------------------------
# structure-aware object serialization (no template needed on restore)
# ---------------------------------------------------------------------------
#
# `save`/`restore` above need a `like` template because the treedef string
# is not parseable back.  Server-strategy state (repro.api resume
# checkpoints) has no natural template — fedavgm's momentum buffers only
# exist after the first round — so `save_obj`/`load_obj` record the
# structure explicitly: nested dict/list/tuple/None/scalars with array
# leaves swapped for npz references.  NamedTuples round-trip as tuples.

def save_obj(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays: dict = {}
    dtypes: dict = {}

    def enc(o):
        if isinstance(o, (np.ndarray, np.generic, jax.Array)):
            i = len(arrays)
            arrays[f"leaf_{i}"] = _encode_leaf(o, f"leaf_{i}", dtypes)
            return {"__leaf__": i}
        if isinstance(o, dict):
            bad = [k for k in o if not isinstance(k, str)]
            if bad:
                raise TypeError(
                    f"save_obj requires string dict keys (JSON would "
                    f"silently coerce {bad[0]!r})")
            return {"__dict__": {k: enc(v) for k, v in o.items()}}
        if isinstance(o, (list, tuple)):
            return {"__seq__": [enc(v) for v in o],
                    "__tuple__": isinstance(o, tuple)}
        if o is None or isinstance(o, (bool, int, float, str)):
            return {"__val__": o}
        raise TypeError(f"save_obj cannot serialize {type(o).__name__}")

    structure = enc(obj)
    _atomic_savez(path if path.endswith(".npz") else path + ".npz", arrays)
    _atomic_json(_manifest_path(path),
                 {"structure": structure, "dtypes": dtypes})


# ---------------------------------------------------------------------------
# append-only binary record log (the distributed runtime's wire log)
# ---------------------------------------------------------------------------
#
# Each record is ``u32 length + u32 crc32 + payload``, appended with an
# fsync so accepted uploads survive a fusion-pod crash.  Appends are NOT
# atomic (that's the point — the log outlives the process), so readers
# tolerate a torn tail: the first truncated or checksum-failing record
# ends the scan, returning every complete record before it.

_REC_HEADER = 8  # u32 length + u32 crc


def append_record(path: str, payload: bytes) -> None:
    import struct
    import zlib

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    with open(path, "ab") as f:
        f.write(struct.pack("<II", len(payload), crc) + payload)
        f.flush()
        os.fsync(f.fileno())


def read_records(path: str) -> list:
    import struct
    import zlib

    out: list = []
    if not os.path.exists(path):
        return out
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off + _REC_HEADER <= len(data):
        length, crc = struct.unpack_from("<II", data, off)
        start = off + _REC_HEADER
        if start + length > len(data):
            break  # torn tail: append died mid-record
        payload = data[start: start + length]
        if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
            break  # corrupted tail record
        out.append(payload)
        off = start + length
    return out


def load_obj(path: str) -> Any:
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    with open(_manifest_path(path)) as f:
        manifest = json.load(f)
    dtypes = manifest.get("dtypes", {})

    def dec(node):
        if "__leaf__" in node:
            name = f"leaf_{node['__leaf__']}"
            return _decode_leaf(npz[name], name, dtypes)
        if "__dict__" in node:
            return {k: dec(v) for k, v in node["__dict__"].items()}
        if "__seq__" in node:
            seq = [dec(v) for v in node["__seq__"]]
            return tuple(seq) if node.get("__tuple__") else seq
        return node["__val__"]

    return dec(manifest["structure"])

"""Unified metrics registry: typed counters / gauges / histograms.

One process-wide :data:`REGISTRY` absorbs the formerly scattered
module-local ``TraceCounter`` singletons (``CLIENT_COMPILES``,
``CHUNK_COMPILES``, ``TEACHER_FORWARDS``) so every counter in the stack
is enumerable from one place — ``REGISTRY.snapshot()`` is the flat dict
the flight recorder stamps into per-round records and
``RunResult.summary()["obs"]``.

Three instrument types, all stdlib-only and cheap enough to live on the
hot path disarmed:

* :class:`Counter` — monotonic within a reset window.  Keeps the exact
  ``add/reset/count`` interface of the old ``common.counters.
  TraceCounter`` (which is now an alias of this class), so the
  trace-time side-effect idiom — bump from inside a traced function
  body to count re-compiles — keeps working unchanged.
* :class:`Gauge` — last-set value (device-memory watermark, bank bytes).
* :class:`Histogram` — running count/total/min/max of observations
  (per-round phase walls).

Per-round streaming happens through the existing ``RoundEvent``
observer chain: :class:`MetricsObserver` snapshots the registry (plus
the event's own fields) on every round and hands the record to
pluggable sinks (:class:`JSONLSink`, :class:`CSVSink`,
:class:`MemorySink`).  Sinks append — a resumed run pointed at the same
path continues the stream rather than truncating it.
"""
from __future__ import annotations

import csv
import json
import os
import threading
from typing import Dict, List, Optional


class Counter:
    """Monotonic counter; interface-compatible with the old TraceCounter."""

    def __init__(self, name: str = ""):
        self.name = name
        self.count = 0

    def add(self, n: int = 1) -> None:
        self.count += int(n)

    def reset(self) -> None:
        self.count = 0

    def value(self):
        return self.count


class Gauge:
    """Last-set value; ``None`` until first :meth:`set`."""

    def __init__(self, name: str = ""):
        self.name = name
        self._value = None

    def set(self, v) -> None:
        self._value = v

    def reset(self) -> None:
        self._value = None

    def value(self):
        return self._value


class Histogram:
    """Streaming count/total/min/max — enough for phase-wall summaries
    without storing every observation."""

    def __init__(self, name: str = ""):
        self.name = name
        self.reset()

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)

    def reset(self) -> None:
        self.count, self.total = 0, 0.0
        self.vmin = self.vmax = None

    def value(self):
        if not self.count:
            return None
        return {"count": self.count, "total": self.total,
                "mean": self.total / self.count,
                "min": self.vmin, "max": self.vmax}


class MetricsRegistry:
    """Get-or-create home for named instruments.

    Names are dotted paths (``core.client.compiles``); re-registering a
    name returns the existing instrument so module-level aliases and
    registry lookups share state.  Asking for a name under a different
    type is a wiring bug and raises.
    """

    def __init__(self):
        self._instruments: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name)
            elif type(inst) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, object]:
        """Flat ``{name: value}`` of every instrument with a value."""
        with self._lock:
            items = list(self._instruments.items())
        out = {}
        for name, inst in items:
            v = inst.value()
            if v is not None:
                out[name] = v
        return out

    def reset(self) -> None:
        with self._lock:
            items = list(self._instruments.values())
        for inst in items:
            inst.reset()


#: Process-wide registry.  Module-level counter singletons in core/
#: (``CLIENT_COMPILES`` et al.) are entries in here; tests keep calling
#: ``.reset()`` on the aliases exactly as before.
REGISTRY = MetricsRegistry()


def device_memory_watermark() -> Optional[int]:
    """Peak device bytes in use across local devices, or ``None`` when
    the backend doesn't expose ``memory_stats`` (CPU jax does not)."""
    try:
        import jax
        peaks = []
        for d in jax.local_devices():
            stats = getattr(d, "memory_stats", lambda: None)()
            if stats and "peak_bytes_in_use" in stats:
                peaks.append(int(stats["peak_bytes_in_use"]))
        return max(peaks) if peaks else None
    except Exception:  # pragma: no cover - backend quirk, never fatal
        return None


# ---------------------------------------------------------------------------
# sinks + per-round streaming
# ---------------------------------------------------------------------------

class MemorySink:
    """In-memory record list — the test sink."""

    def __init__(self):
        self.records: List[dict] = []

    def write(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


class JSONLSink:
    """One JSON object per line, append-mode (resume continues the file)."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a")

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CSVSink:
    """Flat CSV; nested values are JSON-encoded into their cell.  The
    header is fixed by the first record (append runs must match)."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a")
        self._writer = None
        self._fields = None

    def write(self, record: dict) -> None:
        flat = {k: (json.dumps(v) if isinstance(v, (dict, list)) else v)
                for k, v in record.items()}
        if self._writer is None:
            self._fields = list(flat)
            self._writer = csv.DictWriter(self._f, fieldnames=self._fields,
                                          extrasaction="ignore")
            if self._f.tell() == 0:
                self._writer.writeheader()
        self._writer.writerow({k: flat.get(k, "") for k in self._fields})
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class MetricsObserver:
    """RoundEvent observer streaming one record per round into sinks.

    Counter values are emitted as *deltas* since the previous round so a
    per-round record answers "what did this round cost" directly; the
    running totals stay available on the registry itself.
    """

    def __init__(self, sinks, registry: Optional[MetricsRegistry] = None):
        self.sinks = list(sinks)
        self.registry = registry or REGISTRY
        self._prev_counters: Dict[str, int] = {}

    def __call__(self, event) -> None:
        snap = self.registry.snapshot()
        record = {"round": int(event.round),
                  "group": int(getattr(event, "group", 0)),
                  "test_acc": float(event.log.test_acc),
                  "val_acc": float(event.log.val_acc)}
        wm = device_memory_watermark()
        if wm is not None:
            record["device_peak_bytes"] = wm
        for name, v in sorted(snap.items()):
            if isinstance(v, int):  # counters: per-round delta
                record[name] = v - self._prev_counters.get(name, 0)
                self._prev_counters[name] = v
            else:
                record[name] = v
        for sink in self.sinks:
            sink.write(record)

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()

"""Teacher-logit bank: the precomputed, shared, device-resident fast path
for FedDF's server-side distillation.

FedDF's cost center is the fusion loop — up to 10k Adam steps per round
where every step re-forwards *all K frozen teachers* on the distillation
batch, and in the heterogeneous case every one of the G group-students
redundantly re-forwards the same all-groups teacher ensemble.  But the
teachers are FROZEN during fusion and AVGLOGITS only ever consumes
``mean_k f(x_k, d)``: for a source with a finite pool (``DistillSource.
pool()``), the per-example averaged teacher logits can be computed ONCE —
one chunked vmapped forward pass per teacher group over the pool, reduced
on the fly to ``[N, C]`` — and the scan then *gathers* bank rows by the
sampled indices instead of calling the teachers per step:

    teacher forwards:  K x steps            ->  K x ceil(N / chunk)
    heterogeneous:     G x K x steps        ->  K x ceil(N / chunk)   (shared)

Memory: ``N x C x itemsize(bank_dtype)`` bytes (fp32 default; bf16 halves
it at the cost of bitwise trajectory equivalence).  The bank lives on
device next to its pool; pass a ``sharding`` to spread the N axis over a
mesh.  See docs/distill_fast_path.md for the lifecycle and the break-even
analysis against the on-the-fly path.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.common.options import BANK_DTYPES, LOGIT_BANK_MODES

DEFAULT_CHUNK = 512

_BANK_DTYPES = dict(zip(BANK_DTYPES, (jnp.float32, jnp.bfloat16)))


class _ForwardCounter:
    """Process-wide count of teacher *batch* forwards (one teacher, one
    batch of rows) — the bench/tests' evidence that the bank removes the
    K x steps (and hetero G x) redundancy."""

    def __init__(self):
        self.count = 0

    def add(self, n: int) -> None:
        self.count += int(n)

    def reset(self) -> None:
        self.count = 0


TEACHER_FORWARDS = _ForwardCounter()


@dataclasses.dataclass
class LogitBank:
    """Per-round bank of averaged teacher logits over a distillation pool.

    ``pool``: device-resident inputs [N, ...]; ``logits``: mean-over-all-
    teachers logits [N, C] in ``bank_dtype``.  Built once per round (and
    shared by every group-student in heterogeneous fusion); discarded when
    the round's fused models are done.
    """

    pool: jax.Array
    logits: jax.Array
    n_teachers: int
    n_teacher_batch_forwards: int
    build_time_s: float

    @property
    def n(self) -> int:
        return int(self.pool.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.logits.size) * self.logits.dtype.itemsize


def bank_dtype(name: str):
    if name not in _BANK_DTYPES:
        raise ValueError(f"bank_dtype must be one of "
                         f"{sorted(_BANK_DTYPES)}, got {name!r}")
    return _BANK_DTYPES[name]


def build_logit_bank(teacher_logit_fns: Sequence[Callable], pool, *,
                     chunk_size: int = DEFAULT_CHUNK, dtype=jnp.float32,
                     sharding=None) -> LogitBank:
    """One chunked pass of every teacher group over ``pool`` -> LogitBank.

    Each chunk evaluates all groups' stacked teachers ([K_g, c, C] each),
    concatenates along the teacher axis and reduces to the fp32 mean on
    the fly — the full [K, N, C] tensor is never materialized.  With
    ``dtype=float32`` the stored rows are the exact values the on-the-fly
    path would have averaged per step, so trajectories match.
    """
    t0 = time.time()
    pool = jnp.asarray(pool)
    n = int(pool.shape[0])
    c = max(1, min(int(chunk_size), n))
    n_chunks = -(-n // c)
    pad = n_chunks * c - n
    pool_p = (jnp.concatenate(
        [pool, jnp.zeros((pad,) + pool.shape[1:], pool.dtype)])
        if pad else pool)

    k_total = int(jax.eval_shape(
        lambda xc: jnp.concatenate(
            [jnp.asarray(f(xc)) for f in teacher_logit_fns], axis=0),
        jax.ShapeDtypeStruct((c,) + pool.shape[1:], pool.dtype)).shape[0])

    @jax.jit
    def fwd(xc):
        t = jnp.concatenate(
            [jnp.asarray(f(xc)) for f in teacher_logit_fns], axis=0)
        return jnp.mean(t.astype(jnp.float32), axis=0).astype(dtype)

    chunks = []
    for i in range(n_chunks):
        chunks.append(fwd(pool_p[i * c:(i + 1) * c]))
        TEACHER_FORWARDS.add(k_total)
    logits = (jnp.concatenate(chunks, axis=0)[:n] if n_chunks > 1
              else chunks[0][:n])
    if sharding is not None:
        pool = jax.device_put(pool, sharding)
        logits = jax.device_put(logits, sharding)
    return LogitBank(pool=pool, logits=logits, n_teachers=k_total,
                     n_teacher_batch_forwards=n_chunks * k_total,
                     build_time_s=time.time() - t0)


def bank_for_fusion(teacher_logit_fns: Sequence[Callable], source,
                    fusion, *, sharding=None) -> Optional[LogitBank]:
    """Resolve ``FusionConfig.logit_bank`` against the source.

    ``auto`` builds a bank whenever the source exposes a pool; ``on``
    additionally warns when it cannot (generator / noise synthesize inputs
    per step, so there is nothing to precompute over); ``off`` or no
    teachers -> None (the caller keeps the on-the-fly path).
    """
    mode = getattr(fusion, "logit_bank", "off")
    if mode not in LOGIT_BANK_MODES:
        raise ValueError(f"logit_bank must be one of {LOGIT_BANK_MODES}, "
                         f"got {mode!r}")
    if mode == "off" or not teacher_logit_fns:
        return None
    pool_fn = getattr(source, "pool", None)
    pool = pool_fn() if callable(pool_fn) else None
    if pool is None:
        if mode == "on":
            warnings.warn(
                f"logit_bank='on' but source {type(source).__name__} has "
                f"no indexable pool(); falling back to on-the-fly teacher "
                f"forwards", UserWarning, stacklevel=2)
        return None
    return build_logit_bank(teacher_logit_fns, pool,
                            dtype=bank_dtype(fusion.bank_dtype),
                            sharding=sharding)

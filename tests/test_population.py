"""Population subsystem (docs/population.md).

 1. ``SumTree`` point updates / prefix-sum lookups match the naive
    O(N) ``searchsorted(cumsum)`` reference exactly, and proportional
    sampling respects zeroed and updated priorities.
 2. ``ClientRegistry`` is a compact struct-of-arrays: round-robin
    partition mapping, traffic counters, and a checkpoint round trip
    at N = 10^5 through ``checkpoint/io.py``.
 3. ``TrafficModel`` draws are counter-based: wave ``w``'s arrivals /
    latencies / dropouts are a pure function of (config, seed, w) —
    identical in any call order, which is what makes resume replay-free.
 4. Cohort samplers: ``uniform`` reproduces the historic engine draw
    bit-for-bit, ``prioritized`` follows sum-tree priorities, and
    ``capacity_aware`` opens fewer (prototype, bucket) cells than
    uniform so bucket padding waste drops.
 5. ``PopulationManager``: virtual-clock upload buffer — push/pop flow,
    staleness cuts, underflow errors and a full state round trip.
 6. ``PopulationSpec`` / ``TrafficSpec`` JSON round trips, default
    back-compat for old configs, and eager validation of bad knobs.
 7. End-to-end: degenerate buffered_async == sync bitwise; buffered
    runs under traffic log population telemetry into
    ``RunResult.summary()``; killed + resumed buffered and ring-async
    (staleness=2) runs reproduce uninterrupted trajectories.
 8. Weighted teacher consensus: ``(1+s)^-a`` importance flows through
    ``avg_logits_kl``, the logit bank build, and ``GroupRound``
    aggregation weights; uniform weights keep the historic paths.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CohortSpec, DriverSpec, Experiment, ExperimentSpec,
                       FusionSpec, ModelSpec, PartitionSpec, PopulationSpec,
                       SourceSpec, StrategySpec, TaskSpec, TrafficSpec)
from repro.checkpoint import io as ckpt_io
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.core.engine import RoundLog
from repro.core.feddf import (avg_logits_kl, make_teacher_logits_fn,
                              normalize_teacher_weights)
from repro.core.logit_bank import build_logit_bank
from repro.core.strategies import GroupRound
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.drivers import AsyncPipelinedDriver, make_driver
from repro.population import (ClientRegistry, CohortSampler,
                              PopulationConfig, PopulationManager,
                              SamplerContext, SumTree, TrafficConfig,
                              TrafficModel, available_samplers, get_sampler,
                              make_sampler, register_sampler)
from repro.population import scheduler as _scheduler


# ---------------------------------------------------------------------------
# sum tree vs the naive O(N) reference
# ---------------------------------------------------------------------------

def _naive_find(values, u):
    return int(np.searchsorted(np.cumsum(values), u, side="right"))


def test_sumtree_build_total_and_values():
    vals = np.array([0.5, 2.0, 0.0, 1.5, 3.0])
    t = SumTree.from_values(vals)
    assert t.total() == pytest.approx(vals.sum())
    np.testing.assert_array_equal(t.values(), vals)
    assert t.get(3) == 1.5


def test_sumtree_find_matches_searchsorted_reference():
    rng = np.random.default_rng(0)
    vals = rng.random(37)  # non-power-of-two leaf count
    t = SumTree.from_values(vals)
    for u in rng.uniform(0, vals.sum(), 200):
        assert t.find(u) == _naive_find(vals, u)


def test_sumtree_set_propagates_and_still_matches_reference():
    rng = np.random.default_rng(1)
    vals = rng.random(20)
    t = SumTree.from_values(vals)
    for i in rng.integers(0, 20, 30):
        vals[i] = rng.random()
        t.set(int(i), vals[i])
    assert t.total() == pytest.approx(vals.sum())
    for u in rng.uniform(0, vals.sum(), 100):
        assert t.find(u) == _naive_find(vals, u)


def test_sumtree_sample_without_replacement_distinct_and_restores():
    t = SumTree.from_values(np.ones(10))
    before = t.values()
    ids = t.sample(np.random.default_rng(2), 10)
    assert sorted(ids) == list(range(10))
    np.testing.assert_array_equal(t.values(), before)


def test_sumtree_sample_skips_zero_priority():
    vals = np.zeros(16)
    vals[[3, 7, 11]] = 1.0
    t = SumTree.from_values(vals)
    for _ in range(20):
        ids = t.sample(np.random.default_rng(3), 3)
        assert set(ids) == {3, 7, 11}


def test_sumtree_sample_proportional_to_priority():
    t = SumTree.from_values(np.array([1.0, 9.0]))
    draws = [int(t.sample(np.random.default_rng(s), 1)[0])
             for s in range(400)]
    frac_heavy = np.mean(np.asarray(draws) == 1)
    assert 0.8 < frac_heavy < 1.0


def test_sumtree_exhaustion_and_validation():
    with pytest.raises(ValueError, match="n >= 1"):
        SumTree(0)
    with pytest.raises(ValueError, match="non-negative"):
        SumTree.from_values([1.0, -0.5])
    t = SumTree.from_values([1.0, 0.0, 0.0])
    with pytest.raises(ValueError, match="exhausted"):
        t.sample(np.random.default_rng(0), 2)
    with pytest.raises(IndexError):
        t.set(3, 1.0)


def test_sumtree_set_many():
    t = SumTree.from_values(np.ones(8))
    t.set_many([1, 5], [3.0, 0.0])
    assert t.get(1) == 3.0 and t.get(5) == 0.0
    assert t.total() == pytest.approx(6 + 3.0)


# ---------------------------------------------------------------------------
# client registry
# ---------------------------------------------------------------------------

def _registry(n=10, parts=4):
    return ClientRegistry(n, partition_sizes=[100 + p for p in range(parts)],
                          client_steps=[10 * (p + 1) for p in range(parts)],
                          client_proto=[p % 2 for p in range(parts)],
                          client_bucket=[p // 2 for p in range(parts)])


def test_registry_round_robin_partition_mapping():
    reg = _registry(n=10, parts=4)
    np.testing.assert_array_equal(reg.partition,
                                  np.arange(10) % 4)
    # derived per-client facts follow the partition row
    assert reg.data_size[5] == 100 + (5 % 4)
    assert reg.proto[6] == (6 % 4) % 2
    assert reg.steps[7] == 10 * ((7 % 4) + 1)


def test_registry_traffic_counters():
    reg = _registry()
    reg.record_dispatch(np.array([1, 2]), wave=3)
    assert reg.in_flight[1] and reg.in_flight[2]
    assert reg.last_seen[1] == 3
    reg.record_dropout([1])
    assert reg.dropouts[1] == 1 and not reg.in_flight[1]
    reg.record_stale_drop([2])
    assert reg.stale_drops[2] == 1 and not reg.in_flight[2]


def test_registry_upload_ema_and_priority():
    reg = _registry()
    reg.record_dispatch(np.array([4]), wave=1)
    reg.record_upload([4], latency=[2.0], staleness=[3])
    # first observation seeds the EMA directly
    assert reg.ema_latency[4] == pytest.approx(2.0)
    assert reg.priority[4] == pytest.approx(4.0)  # 1 + staleness
    reg.record_upload([4], latency=[4.0], staleness=[0])
    assert reg.ema_latency[4] == pytest.approx(0.9 * 2.0 + 0.1 * 4.0)
    assert reg.priority[4] == pytest.approx(1.0)
    assert reg.uploads[4] == 2


def test_registry_memory_footprint():
    reg = _registry(n=100_000)
    # docs/population.md formula: 45 bytes/client across the SoA fields
    # (8 x int32 + 2 x int16 + 1 x bool + 2 x float32)
    assert reg.nbytes == 45 * 100_000


def test_registry_checkpoint_round_trip_at_1e5(tmp_path):
    reg = _registry(n=100_000, parts=16)
    rng = np.random.default_rng(0)
    ids = rng.choice(100_000, 5_000, replace=False)
    reg.record_dispatch(ids, wave=7)
    reg.record_upload(ids[:2_000], rng.random(2_000), rng.integers(
        0, 4, 2_000))
    path = str(tmp_path / "registry")
    ckpt_io.save_obj(path, reg.state_dict())
    loaded = ClientRegistry.from_state(ckpt_io.load_obj(path))
    assert loaded.size == reg.size
    for f in ("partition", "proto", "last_seen", "uploads", "in_flight",
              "ema_latency", "priority"):
        np.testing.assert_array_equal(getattr(loaded, f), getattr(reg, f))
    # restored rows must stay mutable (checkpoint arrays are read-only)
    loaded.record_dispatch(np.array([0]), wave=8)
    assert loaded.last_seen[0] == 8


def test_registry_load_state_size_mismatch():
    reg = _registry(n=10)
    with pytest.raises(ValueError, match="size mismatch"):
        reg.load_state(_registry(n=11).state_dict())


# ---------------------------------------------------------------------------
# traffic model: counter-based determinism
# ---------------------------------------------------------------------------

_TRAFFIC = TrafficConfig(arrival="bernoulli", rate=0.7, latency=2.0,
                         jitter=0.4, straggler_frac=0.25, straggler_mult=8.0,
                         dropout=0.1)


def test_traffic_same_seed_same_trace():
    a = TrafficModel(_TRAFFIC, seed=3, n=64)
    b = TrafficModel(_TRAFFIC, seed=3, n=64)
    cohort = np.arange(16)
    for w in (1, 5, 9):
        np.testing.assert_array_equal(a.online_mask(w), b.online_mask(w))
        la, da = a.upload_draws(w, cohort)
        lb, db = b.upload_draws(w, cohort)
        np.testing.assert_array_equal(la, lb)
        np.testing.assert_array_equal(da, db)


def test_traffic_draws_are_call_order_independent():
    """Counter-based keying: wave 9's draws are the same whether the
    model served waves 1..8 first or jumped straight to 9 — the property
    that makes resumed runs replay-free."""
    fresh = TrafficModel(_TRAFFIC, seed=3, n=64)
    warm = TrafficModel(_TRAFFIC, seed=3, n=64)
    for w in range(1, 9):
        warm.online_mask(w)
        warm.upload_draws(w, np.arange(8))
    cohort = np.arange(16)
    np.testing.assert_array_equal(fresh.online_mask(9), warm.online_mask(9))
    lf, df = fresh.upload_draws(9, cohort)
    lw, dw = warm.upload_draws(9, cohort)
    np.testing.assert_array_equal(lf, lw)
    np.testing.assert_array_equal(df, dw)


def test_traffic_waves_differ():
    m = TrafficModel(_TRAFFIC, seed=0, n=256)
    assert not np.array_equal(m.online_mask(1), m.online_mask(2))
    l1, _ = m.upload_draws(1, np.arange(64))
    l2, _ = m.upload_draws(2, np.arange(64))
    assert not np.array_equal(l1, l2)


def test_traffic_always_arrival_and_zero_noise():
    cfg = TrafficConfig(latency=1.5)  # always online, no jitter/dropout
    m = TrafficModel(cfg, seed=0, n=8)
    assert m.online_mask(4).all()
    lat, dropped = m.upload_draws(4, np.arange(8))
    np.testing.assert_array_equal(lat, np.full(8, 1.5))
    assert not dropped.any()


def test_traffic_stragglers_are_persistently_slow():
    cfg = TrafficConfig(latency=1.0, straggler_frac=0.5, straggler_mult=8.0)
    m = TrafficModel(cfg, seed=1, n=200)
    frac = m.straggler.mean()
    assert 0.35 < frac < 0.65
    np.testing.assert_array_equal(
        m.base_latency, np.where(m.straggler, 8.0, 1.0))


def test_traffic_bernoulli_rate_and_dropout_rate():
    m = TrafficModel(_TRAFFIC, seed=5, n=2000)
    online = np.mean([m.online_mask(w).mean() for w in range(1, 6)])
    assert 0.65 < online < 0.75
    _, dropped = m.upload_draws(1, np.arange(2000))
    assert 0.06 < dropped.mean() < 0.14


# ---------------------------------------------------------------------------
# cohort samplers
# ---------------------------------------------------------------------------

def _ctx(n=32, n_proto=1, n_buckets=4, cap=2):
    return SamplerContext(
        n_clients=n, n_partitions=n,
        proto=np.arange(n) % n_proto,
        bucket=(np.arange(n) // n_proto) % n_buckets,
        bucket_client_caps=[[cap] * n_buckets for _ in range(n_proto)])


def test_sampler_registry():
    assert {"uniform", "capacity_aware", "prioritized"} <= \
        set(available_samplers())
    with pytest.raises(KeyError, match="unknown cohort sampler"):
        get_sampler("no-such-sampler")

    @register_sampler("_test_only")
    class _Custom(CohortSampler):
        pass

    try:
        assert get_sampler("_test_only") is _Custom
        assert "_test_only" in available_samplers()
    finally:
        _scheduler._SAMPLERS.pop("_test_only")


def test_uniform_matches_historic_engine_draw():
    s = make_sampler("uniform").bind(_ctx(n=50))
    got = s.sample(np.random.default_rng(7), 12)
    want = np.random.default_rng(7).choice(50, size=12, replace=False)
    np.testing.assert_array_equal(got, want)


def test_uniform_respects_availability_mask():
    s = make_sampler("uniform").bind(_ctx(n=50))
    avail = np.array([3, 8, 13, 21, 34])
    got = s.sample(np.random.default_rng(0), 3, available=avail)
    assert set(got) <= set(avail.tolist())
    # k is clamped to the available pool
    assert len(s.sample(np.random.default_rng(0), 99, available=avail)) == 5


def test_prioritized_follows_priorities():
    s = make_sampler("prioritized").bind(_ctx(n=16))
    s.observe(np.arange(16), staleness=np.zeros(16))
    s.tree.set_many(np.arange(12), 0.0)  # only 12..15 drawable
    for seed in range(5):
        got = s.sample(np.random.default_rng(seed), 4)
        assert set(got) == {12, 13, 14, 15}


def test_prioritized_observe_and_masked_draw_restores_tree():
    s = make_sampler("prioritized").bind(_ctx(n=10))
    s.observe([4], staleness=3)
    assert s.tree.get(4) == pytest.approx(4.0)
    before = s.tree.values()
    got = s.sample(np.random.default_rng(1), 2, available=np.array([4, 7]))
    assert set(got) == {4, 7}
    np.testing.assert_array_equal(s.tree.values(), before)


def test_prioritized_load_priorities():
    s = make_sampler("prioritized").bind(_ctx(n=6))
    s.load_priorities([0.0, 0.0, 5.0, 0.0, 0.0, 1.0])
    assert s.tree.total() == pytest.approx(6.0)
    got = s.sample(np.random.default_rng(0), 2)
    assert set(got) == {2, 5}


def _opened_cells(ctx, cohort):
    return len({(int(ctx.proto[i]), int(ctx.bucket[i])) for i in cohort})


def test_capacity_aware_reduces_padding_waste_vs_uniform():
    """build_round_batches pads every opened (proto, bucket) cell to its
    run-fixed capacity, so fewer/fuller cells == less padded-slot waste."""
    ctx = _ctx(n=64, n_buckets=8, cap=4)
    uni = make_sampler("uniform").bind(ctx)
    cap = make_sampler("capacity_aware").bind(ctx)
    waste_uni = waste_cap = 0
    for seed in range(10):
        k = 8
        c_uni = uni.sample(np.random.default_rng(seed), k)
        c_cap = cap.sample(np.random.default_rng(seed), k)
        assert len(set(map(int, c_cap))) == k
        waste_uni += _opened_cells(ctx, c_uni) * 4 - k
        waste_cap += _opened_cells(ctx, c_cap) * 4 - k
    assert waste_cap == 0      # k=8 fills exactly 2 cells of capacity 4
    assert waste_uni > waste_cap


def test_capacity_aware_spills_when_caps_exhausted():
    # 8 clients all in one cell of capacity 2: must still fill k=5
    ctx = SamplerContext(n_clients=8, n_partitions=8,
                         proto=np.zeros(8, int), bucket=np.zeros(8, int),
                         bucket_client_caps=[[2]])
    s = make_sampler("capacity_aware").bind(ctx)
    got = s.sample(np.random.default_rng(0), 5)
    assert len(got) == 5 and len(set(map(int, got))) == 5


# ---------------------------------------------------------------------------
# population manager: virtual-clock upload buffer
# ---------------------------------------------------------------------------

def _tiny_groups(n_proto, protos, rng):
    """GroupRound-alikes with a [K_p, 2] param stack per prototype."""
    class _G:
        def __init__(self, k):
            self.stack = {"w": rng.normal(size=(k, 2)).astype(np.float32)}
            self.weights = np.arange(1, k + 1, dtype=np.float64)
    counts = [int(np.sum(np.asarray(protos) == p)) for p in range(n_proto)]
    return [_G(k) for k in counts]


def _manager(cfg=None, n=12, parts=4, n_active=4, sampler="uniform"):
    cfg = cfg or PopulationConfig(size=n)
    return PopulationManager(
        cfg, seed=0, n_partitions=parts,
        partition_sizes=[50] * parts, client_steps=[5] * parts,
        client_proto=[0] * parts, client_bucket=[0] * parts,
        n_active=n_active, sampler=make_sampler(sampler).bind(
            _ctx(n=cfg.size or parts)))


def test_manager_available_none_when_all_free():
    m = _manager()
    assert m.available(1) is None  # the bit-identity fast path
    m.registry.record_dispatch(np.array([0, 5]), wave=1)
    avail = m.available(2)
    assert avail is not None and 0 not in avail and 5 not in avail


def test_manager_push_pop_zero_latency_flow():
    m = _manager()
    rng = np.random.default_rng(0)
    w, cohort = m.next_wave(rng)
    assert w == 1 and len(cohort) == 4
    groups = _tiny_groups(1, m.registry.proto[cohort], rng)
    assert m.push_wave(w, cohort, groups, base_version=0) == 4
    assert m.usable_pending(1) == 4
    uploads, tele = m.pop(1, 4)
    assert [s for _, s in uploads] == [0, 0, 0, 0]
    assert tele["staleness_hist"][0] == 4
    assert tele["eff_participants"] == pytest.approx(4.0)
    # zero latency: uploads pop in dispatch (seq) order, rows intact
    for j, (up, _) in enumerate(uploads):
        assert up.client == int(cohort[j])
        np.testing.assert_array_equal(np.asarray(up.params["w"])[0],
                                      groups[0].stack["w"][j])


def test_manager_staleness_cut_and_telemetry():
    cfg = PopulationConfig(size=12, max_staleness=1)
    m = _manager(cfg)
    rng = np.random.default_rng(0)
    w, cohort = m.next_wave(rng)
    groups = _tiny_groups(1, m.registry.proto[cohort], rng)
    m.push_wave(w, cohort, groups, base_version=0)
    # at round t=4 these uploads are (t-1)-base = 3 > max_staleness=1
    assert m.usable_pending(4) == 0
    with pytest.raises(RuntimeError, match="buffer underflow"):
        m.pop(4, 1)
    assert int(m.registry.stale_drops.sum()) == 4


def test_manager_virtual_clock_advances_to_arrival():
    cfg = PopulationConfig(size=12, traffic=TrafficConfig(latency=3.0))
    m = _manager(cfg)
    rng = np.random.default_rng(0)
    w, cohort = m.next_wave(rng)
    groups = _tiny_groups(1, m.registry.proto[cohort], rng)
    m.push_wave(w, cohort, groups, base_version=0)
    assert m.clock == 0.0
    m.pop(1, 4)
    assert m.clock == pytest.approx(3.0)


def test_manager_no_available_clients_raises():
    m = _manager(n=4, n_active=4)
    m.registry.in_flight[:] = True
    with pytest.raises(RuntimeError, match="no clients available"):
        m.next_wave(np.random.default_rng(0))


def test_manager_state_round_trip(tmp_path):
    cfg = PopulationConfig(size=12, traffic=TrafficConfig(latency=1.0,
                                                          jitter=0.2))
    m = _manager(cfg)
    rng = np.random.default_rng(0)
    for _ in range(2):
        w, cohort = m.next_wave(rng)
        groups = _tiny_groups(1, m.registry.proto[cohort], rng)
        m.push_wave(w, cohort, groups, base_version=0)
    m.pop(1, 3)
    path = str(tmp_path / "pop")
    ckpt_io.save_obj(path, m.state_dict())
    m2 = _manager(cfg)
    m2.load_state(ckpt_io.load_obj(path))
    assert (m2.clock, m2.wave, m2.seq) == (m.clock, m.wave, m.seq)
    assert len(m2._heap) == len(m._heap)
    a, ta = m.pop(2, 2)
    b, tb = m2.pop(2, 2)
    assert ta == tb
    for (ua, sa), (ub, sb) in zip(a, b):
        assert (ua.client, ua.seq, ua.ready, sa) == \
            (ub.client, ub.seq, ub.ready, sb)
        np.testing.assert_array_equal(np.asarray(ua.params["w"]),
                                      np.asarray(ub.params["w"]))


# ---------------------------------------------------------------------------
# spec layer: round trips + validation
# ---------------------------------------------------------------------------

def api_spec(driver=None, strategy="feddf", rounds=3, **kw):
    return ExperimentSpec(
        task=TaskSpec(name="blobs", n_samples=1200),
        partition=PartitionSpec(n_clients=6, alpha=1.0),
        cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                {"hidden": [16, 16]})]),
        strategy=StrategySpec(name=strategy,
                              fusion=FusionSpec(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32)),
        source=(SourceSpec(name="unlabeled", params={"n": 500})
                if strategy == "feddf" else None),
        driver=driver if driver is not None else DriverSpec(),
        rounds=rounds, client_fraction=0.5, local_epochs=3,
        local_batch_size=32, local_lr=0.05, seed=0, **kw)


_POP = PopulationSpec(size=24, sampler="prioritized", buffer_size=6,
                      max_staleness=3, staleness_exponent=0.7,
                      traffic=TrafficSpec(arrival="bernoulli", rate=0.8,
                                          latency=1.0, jitter=0.2,
                                          straggler_frac=0.1,
                                          straggler_mult=4.0, dropout=0.05))


def test_population_spec_round_trips():
    spec = api_spec(DriverSpec(kind="buffered_async", staleness=1),
                    population=_POP)
    spec.validate()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    d = spec.to_dict()["population"]
    assert d["sampler"] == "prioritized"
    assert d["traffic"]["arrival"] == "bernoulli"


def test_population_spec_back_compat_defaults():
    # specs predating the population axis still load (classic roster)
    d = api_spec().to_dict()
    del d["population"]
    assert ExperimentSpec.from_dict(d).population == PopulationSpec()


def test_population_spec_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown field"):
        PopulationSpec.from_dict({"size": 4, "nope": 1})
    with pytest.raises(ValueError, match="unknown field"):
        TrafficSpec.from_dict({"arrival": "always", "nope": 1})


@pytest.mark.parametrize("pop,match", [
    (dataclasses.replace(_POP, sampler="no-such"), "unknown cohort sampler"),
    (dataclasses.replace(_POP, size=0), "population.size"),
    (dataclasses.replace(_POP, buffer_size=0), "buffer_size"),
    (dataclasses.replace(_POP, max_staleness=-1), "max_staleness"),
    (dataclasses.replace(_POP, staleness_exponent=-0.1),
     "staleness_exponent"),
    (dataclasses.replace(_POP, traffic=TrafficSpec(arrival="nope")),
     "arrival"),
    (dataclasses.replace(_POP, traffic=TrafficSpec(rate=0.0)), "rate"),
    (dataclasses.replace(_POP, traffic=TrafficSpec(dropout=1.0)), "dropout"),
    (dataclasses.replace(_POP, traffic=TrafficSpec(straggler_mult=0.5)),
     "straggler_mult"),
])
def test_population_spec_validation(pop, match):
    spec = api_spec(DriverSpec(kind="buffered_async"), population=pop)
    with pytest.raises((ValueError, KeyError), match=match):
        spec.validate()


def test_buffered_overlap_needs_max_staleness_headroom():
    spec = api_spec(DriverSpec(kind="buffered_async", staleness=1),
                    population=dataclasses.replace(_POP, max_staleness=0))
    with pytest.raises(ValueError, match="stale-dropped"):
        spec.validate()


def test_cli_population_flags_round_trip(tmp_path):
    from repro.launch.train import main
    cfg_path = str(tmp_path / "spec.json")
    main(["--strategy", "feddf", "--rounds", "1", "--clients", "4",
          "-C", "1.0", "--local-epochs", "2", "--n-samples", "400",
          "--distill-steps", "25", "--checkpoint-every", "0",
          "--driver", "buffered_async", "--staleness", "1",
          "--population-size", "16", "--sampler", "prioritized",
          "--buffer-size", "4", "--max-staleness", "5",
          "--staleness-exponent", "0.7", "--traffic", "bernoulli",
          "--traffic-rate", "0.9", "--traffic-latency", "0.5",
          "--traffic-jitter", "0.1", "--straggler-frac", "0.2",
          "--straggler-mult", "4", "--traffic-dropout", "0.01",
          "--dump-config", cfg_path, "--out", str(tmp_path / "a")])
    spec = ExperimentSpec.load(cfg_path)
    assert spec.population == PopulationSpec(
        size=16, sampler="prioritized", buffer_size=4, max_staleness=5,
        staleness_exponent=0.7,
        traffic=TrafficSpec(arrival="bernoulli", rate=0.9, latency=0.5,
                            jitter=0.1, straggler_frac=0.2,
                            straggler_mult=4.0, dropout=0.01))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    summary = json.load(open(tmp_path / "a" / "summary.json"))
    assert summary["config"] == spec.to_dict()
    assert "population" in summary


def test_roundlog_back_compat_defaults():
    # pre-population checkpoint dicts must still construct a RoundLog
    old = {"round": 1, "test_acc": 0.5, "val_acc": 0.5}
    log = RoundLog(**old)
    assert log.staleness_hist is None and log.eff_participants == 0.0


# ---------------------------------------------------------------------------
# end-to-end: degenerate equality, telemetry, resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


def small_cfg(strategy="feddf", rounds=2, **kw):
    return FLConfig(strategy=strategy, rounds=rounds, client_fraction=0.5,
                    local_epochs=3, local_batch_size=32, local_lr=0.05,
                    seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32), **kw)


@pytest.mark.parametrize("strategy", ["fedavg", "feddf"])
def test_degenerate_buffered_matches_sync(problem, strategy):
    """buffer_size == K, zero latency, uniform sampler, staleness=0: the
    population seam reproduces the sync trajectory bit-for-bit."""
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(strategy=strategy, rounds=3)

    def run(driver):
        return run_rounds([net], [0] * len(parts), train, parts, val, test,
                          cfg, source=src, driver=driver)

    sync = run("sync")
    buf = run(make_driver("buffered_async", staleness=0))
    # every upload fused fresh, and the trajectory is the pin:
    assert all(sum(l.staleness_hist[1:]) == 0 for l in buf[0][0].logs)
    assert [l.test_acc for l in buf[0][0].logs] == \
        [l.test_acc for l in sync[0][0].logs]
    assert [l.val_acc for l in buf[0][0].logs] == \
        [l.val_acc for l in sync[0][0].logs]
    for x, y in zip(jax.tree.leaves(buf[1][0]), jax.tree.leaves(sync[1][0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_buffered_traffic_runs_and_logs_telemetry(problem):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(rounds=3, population=PopulationConfig(
        size=18, sampler="prioritized", buffer_size=3, max_staleness=4,
        traffic=TrafficConfig(arrival="bernoulli", rate=0.9, latency=1.0,
                              jitter=0.3, straggler_frac=0.2,
                              straggler_mult=4.0, dropout=0.05)))
    results, globals_, _ = run_rounds(
        [net], [0] * len(parts), train, parts, val, test, cfg,
        source=src, driver=make_driver("buffered_async", staleness=1))
    logs = results[0].logs
    assert [l.round for l in logs] == [1, 2, 3]
    for l in logs:
        assert l.staleness_hist is not None
        assert sum(l.staleness_hist) == 3          # M uploads fused
        assert 0 < l.eff_participants <= 3.0
    # some upload actually aged under latency+overlap
    assert any(sum(l.staleness_hist[1:]) > 0 for l in logs)


def test_population_summary_in_run_result():
    spec = api_spec(DriverSpec(kind="buffered_async", staleness=1),
                    population=PopulationSpec(
                        size=18, buffer_size=3, max_staleness=4,
                        traffic=TrafficSpec(latency=1.0, jitter=0.2)))
    res = Experiment(spec).run()
    s = res.summary()
    pop = s["population"]
    assert pop["uploads_fused"] == 3 * len(res.result.logs)
    assert set(pop) >= {"mean_staleness", "staleness_hist",
                        "dropped_uploads", "stale_dropped",
                        "mean_eff_participants"}
    # sync runs don't grow the section
    assert "population" not in Experiment(api_spec()).run().summary()


class _StopAfter(Exception):
    pass


@pytest.mark.parametrize("staleness", [0, 1])
def test_buffered_resume_matches_uninterrupted(tmp_path, staleness):
    """Kill a checkpointed buffered-async run mid-stream and resume: the
    trajectory (telemetry included) must equal an uninterrupted run —
    registry arrays, the pending upload heap and the cohort rng state all
    ride in the checkpoint, and traffic draws are counter-based."""
    spec = api_spec(DriverSpec(kind="buffered_async", staleness=staleness),
                    rounds=5,
                    population=PopulationSpec(
                        size=18, sampler="prioritized", buffer_size=3,
                        max_staleness=4,
                        traffic=TrafficSpec(arrival="bernoulli", rate=0.9,
                                            latency=1.0, jitter=0.3,
                                            dropout=0.05)))
    baseline = Experiment(spec).run()
    assert [l.round for l in baseline.result.logs] == [1, 2, 3, 4, 5]

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    ckpt_dir = str(tmp_path / f"run-{staleness}")
    with pytest.raises(_StopAfter):
        Experiment(spec).run(observers=[bomb], checkpoint_dir=ckpt_dir)
    assert os.path.isdir(os.path.join(ckpt_dir, "rounds", "00002"))

    resumed = Experiment.resume(ckpt_dir)
    assert resumed.result.logs == baseline.result.logs
    for a, b in zip(jax.tree.leaves(resumed.global_params[0]),
                    jax.tree.leaves(baseline.global_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# async ring: bounded staleness S > 1
# ---------------------------------------------------------------------------

def test_async_ring_s2_runs_to_target_rounds(problem):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(strategy="fedavg", rounds=5)
    results, _, _ = run_rounds(
        [net], [0] * len(parts), train, parts, val, test, cfg, source=src,
        driver=AsyncPipelinedDriver(staleness=2, prefetch=2))
    assert [l.round for l in results[0].logs] == [1, 2, 3, 4, 5]


def test_async_ring_s2_resume_matches_uninterrupted(tmp_path):
    """The S=2 checkpoint carries a base RING (two in-flight training
    bases); a resumed run must reproduce the uninterrupted trajectory."""
    spec = api_spec(DriverSpec(kind="async_pipelined", staleness=2,
                               prefetch=2), strategy="feddf", rounds=5)
    baseline = Experiment(spec).run()

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    ckpt_dir = str(tmp_path / "ring")
    with pytest.raises(_StopAfter):
        Experiment(spec).run(observers=[bomb], checkpoint_dir=ckpt_dir)
    resumed = Experiment.resume(ckpt_dir)
    assert resumed.result.logs == baseline.result.logs
    for a, b in zip(jax.tree.leaves(resumed.global_params[0]),
                    jax.tree.leaves(baseline.global_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_ring_s2_differs_from_sync(problem):
    """S=2 really trains from two-fusions-stale bases: the trajectory is
    NOT the sync one (if it were, the ring would be a no-op)."""
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(strategy="fedavg", rounds=4)

    def run(driver):
        return run_rounds([net], [0] * len(parts), train, parts, val, test,
                          cfg, source=src, driver=driver)

    sync = run("sync")
    s2 = run(AsyncPipelinedDriver(staleness=2))
    sync_leaves = jax.tree.leaves(sync[1][0])
    s2_leaves = jax.tree.leaves(s2[1][0])
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(sync_leaves, s2_leaves))


def test_async_staleness_validation():
    with pytest.raises(ValueError, match="staleness"):
        AsyncPipelinedDriver(staleness=-1)
    assert AsyncPipelinedDriver(staleness=4).staleness == 4


# ---------------------------------------------------------------------------
# weighted teacher consensus: (1+s)^-a importance
# ---------------------------------------------------------------------------

def test_normalize_teacher_weights():
    assert normalize_teacher_weights(None) is None
    w = normalize_teacher_weights([2.0, 2.0])
    np.testing.assert_allclose(np.asarray(w), [0.5, 0.5])
    with pytest.raises(ValueError, match="positive sum"):
        normalize_teacher_weights([0.0, 0.0])


def test_avg_logits_kl_uniform_weights_match_none():
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.normal(size=(4, 8, 3)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32))
    base = avg_logits_kl(s, t)
    uni = avg_logits_kl(s, t, teacher_weights=jnp.full(4, 0.25))
    np.testing.assert_allclose(float(base), float(uni), rtol=1e-5)
    # skewed weights move the consensus
    skew = avg_logits_kl(s, t,
                         teacher_weights=jnp.asarray([0.97, 0.01, 0.01,
                                                      0.01]))
    assert abs(float(skew) - float(base)) > 1e-6


def test_logit_bank_folds_teacher_weights():
    rng = np.random.default_rng(1)
    net = mlp(2, 3, hidden=(8,))
    stack = jax.tree.map(
        lambda l: jnp.stack([l + 0.1 * i for i in range(3)]),
        net.init(jax.random.PRNGKey(0)))
    tfn = make_teacher_logits_fn(net, stack)
    pool = rng.normal(size=(32, 2)).astype(np.float32)
    w = np.array([4.0, 1.0, 1.0])
    bank = build_logit_bank([tfn], pool, teacher_weights=w)
    t = np.asarray(tfn(jnp.asarray(pool)))  # [3, 32, 3]
    want = np.tensordot(w / w.sum(), t, axes=([0], [0]))
    np.testing.assert_allclose(np.asarray(bank.logits), want, atol=1e-5)
    with pytest.raises(ValueError, match="teacher_weights"):
        build_logit_bank([tfn], pool, teacher_weights=np.ones(5))


def test_group_round_effective_weights():
    g = GroupRound(net=None, prev_global=None, stack=None,
                   weights=np.array([10.0, 20.0]))
    np.testing.assert_array_equal(g.effective_weights(), [10.0, 20.0])
    g.importance = np.array([1.0, 0.5])  # (1+s)^-a for s = 0, 3 @ a=0.5
    np.testing.assert_allclose(g.effective_weights(), [10.0, 10.0])

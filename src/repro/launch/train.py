"""End-to-end federated training driver (CLI).

Runs the complete FedDF pipeline on CPU at paper scale: synthetic non-iid
data (Dirichlet alpha), K clients, local SGD epochs, server-side ensemble
distillation against a chosen unlabeled source, per-round evaluation,
checkpointing, rounds-to-target reporting.

    PYTHONPATH=src python -m repro.launch.train \\
        --strategy feddf --rounds 20 --clients 20 -C 0.4 --alpha 0.1 \\
        --local-epochs 20 --task tokens --out runs/feddf

Strategies: any name in the server-strategy registry
(``core/strategies.py``: fedavg | fedprox | fedavgm | feddf | ...)
plus ``feddf-hetero`` for Algorithm 3.  ``--shard-clients`` shards the
round engine's client axis over all visible devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from repro.checkpoint import io as ckpt
from repro.core import (FLConfig, FusionConfig, available_strategies, mlp,
                        run_federated, run_federated_heterogeneous,
                        tiny_transformer)
from repro.core.quantize import binarize
from repro.data import (GeneratorSource, RandomNoiseSource, UnlabeledDataset,
                        dirichlet_partition, gaussian_mixture,
                        token_sequences, train_val_test_split)


def build_task(task: str, n: int, seed: int):
    if task == "blobs":
        ds = gaussian_mixture(n, n_classes=3, dim=2, seed=seed)
        net_fn = lambda norm="none": mlp(2, 3, hidden=(64, 64, 64), norm=norm)
        distill_shape = (2,)
        vocab = None
    elif task == "tokens":
        ds = token_sequences(n, n_classes=4, vocab=64, seq_len=16, seed=seed)
        net_fn = lambda norm="none": tiny_transformer(64, 4, 16)
        distill_shape = (16,)
        vocab = 64
    else:
        raise ValueError(task)
    return ds, net_fn, distill_shape, vocab


def build_source(kind: str, train, distill_shape, vocab, seed: int):
    if kind == "unlabeled":
        # out-of-domain unlabeled pool (different seed = different manifold)
        if vocab is None:
            x = np.random.default_rng(seed + 7).uniform(
                -3, 3, (4000,) + distill_shape).astype(np.float32)
        else:
            from repro.data.synthetic import token_sequences as ts
            x = ts(4000, n_classes=4, vocab=vocab,
                   seq_len=distill_shape[0], seed=seed + 7).x
        return UnlabeledDataset(x)
    if kind == "generator":
        return GeneratorSource(distill_shape, discrete_vocab=vocab,
                               mean=0.0, std=1.5, seed=seed)
    if kind == "noise":
        return RandomNoiseSource(distill_shape, discrete_vocab=vocab)
    raise ValueError(kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="feddf",
                    choices=available_strategies() + ["feddf-hetero"])
    ap.add_argument("--task", default="blobs", choices=["blobs", "tokens"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("-C", "--fraction", type=float, default=0.4)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--local-epochs", type=int, default=20)
    ap.add_argument("--local-lr", type=float, default=0.05)
    ap.add_argument("--n-samples", type=int, default=6000)
    ap.add_argument("--distill-source", default="unlabeled",
                    choices=["unlabeled", "generator", "noise"])
    ap.add_argument("--distill-steps", type=int, default=1000)
    ap.add_argument("--norm", default="none", choices=["none", "bn", "gn"])
    ap.add_argument("--drop-worst", action="store_true")
    ap.add_argument("--binarize", action="store_true")
    ap.add_argument("--target", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/latest")
    ap.add_argument("--shard-clients", action="store_true",
                    help="shard the round engine's client axis over all "
                         "devices (active clients must divide the count)")
    args = ap.parse_args(argv)

    mesh = None
    if args.shard_clients:
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh()

    ds, net_fn, dshape, vocab = build_task(args.task, args.n_samples,
                                           args.seed)
    train, val, test = train_val_test_split(ds, seed=args.seed)
    parts = dirichlet_partition(train.y, args.clients, args.alpha,
                                seed=args.seed)
    source = build_source(args.distill_source, train, dshape, vocab,
                          args.seed)

    cfg = FLConfig(
        rounds=args.rounds, client_fraction=args.fraction,
        local_epochs=args.local_epochs, local_lr=args.local_lr,
        strategy="feddf" if args.strategy == "feddf-hetero" else args.strategy,
        drop_worst=args.drop_worst, seed=args.seed,
        quantize=binarize if args.binarize else None,
        target_accuracy=args.target,
        fusion=FusionConfig(max_steps=args.distill_steps,
                            patience=max(args.distill_steps // 5, 100),
                            eval_every=100, batch_size=64))

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    def log_fn(entry):
        if isinstance(entry, tuple):
            g, l = entry
            print(f"[round {l.round:3d}] proto{g} test={l.test_acc:.4f} "
                  f"ens={l.ensemble_acc:.4f}")
        else:
            print(f"[round {entry.round:3d}] test={entry.test_acc:.4f} "
                  f"val={entry.val_acc:.4f} "
                  f"distill_steps={entry.distill_steps} "
                  f"dropped={entry.n_dropped}")

    if args.strategy == "feddf-hetero":
        if args.task == "blobs":
            nets = [mlp(2, 3, hidden=(48, 48), name="proto-s"),
                    mlp(2, 3, hidden=(64, 64, 64), name="proto-m"),
                    mlp(2, 3, hidden=(96, 96), name="proto-l")]
        else:
            nets = [tiny_transformer(64, 4, 16, d_model=48, n_layers=1),
                    tiny_transformer(64, 4, 16, d_model=64, n_layers=2),
                    tiny_transformer(64, 4, 16, d_model=96, n_layers=2)]
        proto = [k % len(nets) for k in range(args.clients)]
        results, globals_ = run_federated_heterogeneous(
            nets, proto, train, parts, val, test, cfg, source, log_fn,
            mesh=mesh)
        summary = {f"proto_{g}": {"final": r.final_acc, "best": r.best_acc}
                   for g, r in enumerate(results)}
        for g, p in enumerate(globals_):
            ckpt.save(os.path.join(args.out, f"proto_{g}"), p,
                      {"arch": nets[g].name})
    else:
        net = net_fn(args.norm)
        res = run_federated(net, train, parts, val, test, cfg,
                            source=source, log_fn=log_fn, mesh=mesh)
        summary = {"final": res.final_acc, "best": res.best_acc,
                   "rounds_to_target": res.rounds_to_target,
                   "per_round": [l.test_acc for l in res.logs]}
        ckpt.save(os.path.join(args.out, "global"), res.global_params,
                  {"net": net.name, "strategy": args.strategy})

    summary["wall_s"] = time.time() - t0
    summary["config"] = {k: v for k, v in vars(args).items()}
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("per_round", "config")}, indent=2))


if __name__ == "__main__":
    main()

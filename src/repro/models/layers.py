"""Primitive layers + parameter-spec machinery.

Parameters are described by :class:`ParamSpec` (shape + logical axes + init),
so a single walk yields both the materialised arrays (``init_params``) and
the logical-axis pytree consumed by the sharding rules (``logical_axes``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Logical = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Logical
    init: str = "normal"  # normal | zeros | ones | ssm_a | ssm_dt_bias
    scale: float = 1.0

    def materialise(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "ssm_a":
            # A_log init: A in [1, 16) -> log
            n = self.shape[-1] if self.shape else 1
            a = jnp.linspace(1.0, 16.0, max(int(math.prod(self.shape)), 1))
            return jnp.log(a.reshape(self.shape)).astype(dtype)
        if self.init == "ssm_dt_bias":
            # dt bias s.t. softplus(dt_bias) in [1e-3, 1e-1]
            u = jnp.linspace(0.0, 1.0, max(int(math.prod(self.shape)), 1))
            dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
            inv = dt + jnp.log(-jnp.expm1(-dt))
            return inv.reshape(self.shape).astype(dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[-1], 1)
        std = self.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [s.materialise(k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def logical_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def stack_specs(specs: Any, n: int, axis_name: str = "layers") -> Any:
    """Add a stacked leading dim (scanned layers) to every spec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.logical, s.init, s.scale),
        specs,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int, logical: str = "embed") -> ParamSpec:
    return ParamSpec((dim,), (logical,), init="ones")


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def groupnorm(w: jax.Array, b: jax.Array, x: jax.Array, groups: int,
              eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the channel (last) dim — paper's GN-vs-BN ablation."""
    dt = x.dtype
    *lead, c = x.shape
    x = x.astype(jnp.float32).reshape(*lead, groups, c // groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x.reshape(*lead, c)
    return (x * w + b).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_specs(d_model: int, d_ff: int) -> dict:
    return {
        "wi_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wi_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["wi_gate"])
    return (g * (x @ p["wi_up"])) @ p["wo"]


def gelu_mlp_specs(d_model: int, d_ff: int) -> dict:
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]

"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512,
MoE 32 experts top-8, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,         # per-expert intermediate size
    vocab_size=49155,
    head_dim=64,
    tie_embeddings=True,
    n_experts=32,
    top_k=8,
    pattern=(BlockSpec("attn_global", "moe"),),
)

"""Round drivers: schedulers over the RoundEngine phases
(``core/engine.py``), selected by ``DriverSpec(kind=...)`` or
``run_rounds(driver=...)``.  See docs/drivers.md.

    sync            serial reference loop (bit-identical to the historic
                    ``run_rounds``)
    async_pipelined round t+1 client training overlapped with round t
                    fusion (staleness <= 1; 0 == sync semantics)
    multihost       sync semantics, client axis sharded over a host mesh;
                    plus ``drive_fed_rounds`` for the production
                    ``make_fed_round_step`` loop
"""
from repro.drivers.base import (Driver, available_drivers, get_driver,
                                make_driver, register_driver,
                                resolve_driver, unwrap_state, wrap_state)
from repro.drivers.sync import SyncDriver
from repro.drivers.async_pipelined import AsyncPipelinedDriver
from repro.drivers.multihost import MultiHostDriver, drive_fed_rounds

__all__ = [
    "Driver", "SyncDriver", "AsyncPipelinedDriver", "MultiHostDriver",
    "register_driver", "get_driver", "make_driver", "available_drivers",
    "resolve_driver", "wrap_state", "unwrap_state", "drive_fed_rounds",
]

"""Round-driver protocol + registry (mirrors ``core/strategies.py``).

A :class:`Driver` owns the ROUND LOOP over a
:class:`~repro.core.engine.RoundEngine`: which phase of which round runs
when, what overlaps what, and when the checkpoint hook fires.  The engine
owns the math — every phase is a deterministic function of its inputs —
so drivers trade *schedule* (latency, overlap, device placement), never
*semantics*, except where a staleness knob says so explicitly.

Built-ins (register more with :func:`register_driver`):

  sync            — the historic serial loop, extracted; bit-identical
  async_pipelined — round t+1's client training overlaps round t's
                    FedDF/logit-bank fusion (bounded staleness <= 1;
                    ``staleness=0`` keeps sync semantics and only
                    prefetches host-side batch building)
  multihost       — sync semantics with the stacked client axis sharded
                    over a host/device mesh (``launch/mesh.py``)

See docs/drivers.md for the lifecycle and staleness semantics.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.core.engine import _UNSET, FLResult, RoundEngine, RoundLog
from repro.obs import trace as _trace


# marker key of the wrapped async-pipeline checkpoint state; kept a plain
# dict so checkpoint/io.save_obj round-trips it without special cases
_STATE_KEY = "__async_pipeline__"


def wrap_state(strategy_state, prev_globals, *, base_ring=None,
               population=None):
    """Checkpoint state carrying the stale base(s) the in-flight round(s)
    trained from (async driver, staleness >= 1).

    ``base_ring`` (staleness S > 1 only) is the ordered list of training
    bases of ALL unjoined in-flight rounds; ``prev_globals`` stays the
    next round's base, so the S=1 checkpoint format is byte-identical to
    the historic one.  ``population`` carries the buffered-async driver's
    manager snapshot (registry + pending uploads + rng state)."""
    d = {_STATE_KEY: True, "strategy_state": strategy_state,
         "prev_globals": prev_globals}
    if base_ring is not None:
        d["base_ring"] = list(base_ring)
    if population is not None:
        d["population"] = population
    return d


def unwrap_state(state):
    """(strategy_state, prev_globals_or_None) from a possibly-wrapped
    checkpoint state.  Safe for any driver: a sync resume of an async
    checkpoint just drops the stale base."""
    if isinstance(state, dict) and state.get(_STATE_KEY):
        return state["strategy_state"], state.get("prev_globals")
    return state, None


class Driver:
    """Interface: compose engine phases into a full run.

    ``run`` returns the same triple as the historic ``run_rounds``:
    ``(per-prototype FLResults, final globals, rounds_to_target)``.
    """

    kind: str = "base"

    def __init__(self, staleness: int = 0, prefetch: int = 1):
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if prefetch < 0:
            raise ValueError(f"prefetch must be >= 0, got {prefetch}")
        self.staleness = staleness
        self.prefetch = prefetch

    def run(self, engine: RoundEngine, *, log_fn: Optional[Callable] = None,
            init_globals: Optional[List[dict]] = None, init_state=_UNSET,
            start_round: int = 1,
            init_logs: Optional[List[List[RoundLog]]] = None,
            round_end_hook: Optional[Callable] = None
            ) -> Tuple[List[FLResult], List[dict], Optional[int]]:
        raise NotImplementedError

    # -- shared plumbing --------------------------------------------------

    def _setup(self, engine: RoundEngine, init_globals, init_state,
               init_logs, start_round: int):
        """Initial globals/state/logs plus the cohort rng with completed
        rounds' draws replayed (identical resume trajectories)."""
        # flight-recorder attribution: every span closed from here on
        # carries the driver name (no-op while disarmed)
        _trace.set_context(driver=self.kind)
        globals_ = (list(init_globals) if init_globals is not None
                    else engine.init_globals())
        state = (engine.init_state(globals_) if init_state is _UNSET
                 else init_state)
        # async staleness>=1 checkpoints wrap the strategy state with the
        # stale training base(s) of the in-flight round(s) (see wrap_state);
        # buffered_async additionally carries its population snapshot
        self._resume_base_ring = None
        self._resume_population = None
        if isinstance(state, dict) and state.get(_STATE_KEY):
            self._resume_base_ring = state.get("base_ring")
            self._resume_population = state.get("population")
        state, self._resume_prev_base = unwrap_state(state)
        logs: List[List[RoundLog]] = (
            [list(l) for l in init_logs] if init_logs is not None
            else [[] for _ in range(engine.n_proto)])
        rng = engine.make_rng()
        for _ in range(start_round - 1):
            engine.sample_cohort(rng)
        return globals_, state, logs, rng

    def _emit_round(self, engine: RoundEngine, t: int,
                    round_logs: List[RoundLog],
                    logs: List[List[RoundLog]], log_fn) -> Tuple[bool, bool]:
        """Append the round's logs and notify ``log_fn`` per group.
        Returns ``(target_reached, stop_requested)`` — a log_fn returning
        the literal ``True`` requests a stop after this round (the
        ``RoundEvent.request_stop`` seam).  Deliberately ``is True``, not
        truthiness: legacy log_fns predate the return-value contract and
        may return arbitrary objects (e.g. the log itself)."""
        stop_requested = False
        for p, log in enumerate(round_logs):
            logs[p].append(log)
            if log_fn:
                ret = log_fn((p, log) if engine.heterogeneous else log)
                stop_requested = stop_requested or ret is True
        return engine.target_reached(round_logs), stop_requested

    @staticmethod
    def _results(engine: RoundEngine, logs, globals_, rounds_to_target):
        results = [FLResult(logs=logs[p], global_params=globals_[p])
                   for p in range(engine.n_proto)]
        return results, globals_, rounds_to_target


_REGISTRY: Dict[str, type] = {}


def register_driver(name: str):
    """Class decorator: ``@register_driver("mine")`` adds a driver
    selectable via ``DriverSpec(kind="mine")`` / ``run_rounds(driver=...)``.
    """

    def deco(cls):
        cls.kind = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_driver(name: str) -> type:
    """The registered driver CLASS (construct with staleness/prefetch)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown driver {name!r}; registered: "
                         f"{available_drivers()}")
    return _REGISTRY[name]


def make_driver(name: str, *, staleness: int = 0,
                prefetch: int = 1) -> Driver:
    return get_driver(name)(staleness=staleness, prefetch=prefetch)


def available_drivers() -> List[str]:
    return sorted(_REGISTRY)


def resolve_driver(driver) -> Driver:
    """None -> sync; a name -> registry lookup; an instance -> itself."""
    if driver is None:
        driver = "sync"
    if isinstance(driver, str):
        return make_driver(driver)
    if isinstance(driver, Driver):
        return driver
    raise TypeError(f"driver must be None, a registry name or a Driver "
                    f"instance, got {type(driver).__name__}")

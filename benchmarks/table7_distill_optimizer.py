"""Table 7 (Appendix C.4.1): the SERVER distillation optimizer.

Paper finding (CIFAR-10/ResNet-8): SGD-distillation underperforms
(76.68 vs Adam's 80.27 at alpha=1); SWAG-sampled extra teachers
(FedDistill [10]) perform on par with plain Adam (80.84 vs 80.27) at the
cost of two extra hyperparameters — justifying FedDF's default choice.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import default_problem, emit, fl_cfg, fusion_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(4, 10)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=1.0)
    results = {}
    variants = {
        "sgd": dict(optimizer="sgd", lr=0.05),
        "adam": dict(optimizer="adam"),
        "swag": dict(optimizer="adam", swag_samples=5, swag_scale=0.5),
    }
    for name, fkw in variants.items():
        cfg = fl_cfg("feddf", rounds, seed=seed,
                     fusion=dataclasses.replace(fusion_cfg(), **fkw))
        net = mlp(2, 3, hidden=(64, 64))
        res = run_federated(net, train, parts, val, test, cfg, source=src)
        results[name] = {"best_acc": res.best_acc,
                         "final_acc": res.final_acc}
    dt = time.time() - t0
    claims = {
        # Adam >= SGD for the server-side ensemble distillation
        "adam_at_least_sgd": (results["adam"]["best_acc"]
                              >= results["sgd"]["best_acc"] - 0.01),
        # SWAG teachers are on par with plain Adam (paper: 80.84 vs 80.27)
        "swag_on_par_with_adam": (abs(results["swag"]["best_acc"]
                                      - results["adam"]["best_acc"]) <= 0.03),
    }
    emit("table7_distill_optimizer", dt,
         f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

"""Low-bit federated learning (paper §4.3, Table 4): clients train 1-bit
binarized models with the straight-through estimator; the server fuses the
low-precision ensemble into a full-precision model via distillation.

    PYTHONPATH=src python examples/lowbit_fl.py
"""
import jax
import numpy as np

from repro.core import FLConfig, FusionConfig, mlp, run_federated
from repro.core.quantize import binarize, comm_bytes
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

ds = gaussian_mixture(5000, n_classes=3, dim=2, seed=2)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, n_clients=10, alpha=1.0, seed=2)
net = mlp(2, 3, hidden=(64, 64))
source = UnlabeledDataset(
    np.random.default_rng(7).uniform(-3, 3, (3000, 2)).astype(np.float32))

p0 = net.init(jax.random.PRNGKey(0))
print(f"uplink per round: fp32={comm_bytes(p0)/1e3:.1f}kB  "
      f"binary={comm_bytes(p0, binarized=True)/1e3:.1f}kB  "
      f"({comm_bytes(p0)/comm_bytes(p0, True):.1f}x compression)")

for strategy in ("fedavg", "feddf"):
    cfg = FLConfig(strategy=strategy, rounds=8, client_fraction=0.4,
                   local_epochs=20, local_batch_size=32, local_lr=0.1,
                   quantize=binarize, seed=2,
                   fusion=FusionConfig(max_steps=400, patience=200,
                                       eval_every=50, batch_size=64))
    res = run_federated(net, train, parts, val, test, cfg,
                        source=source if strategy == "feddf" else None)
    print(f"{strategy:7s} (1-bit clients) best={res.best_acc:.3f}")

"""Robust fusion under byzantine uploads (ISSUE 8 acceptance).

Three runs of the same fedavg problem with ``f`` byzantine clients
(persistent sign-flip at 10x scale, ``FaultModel`` injection):

  * **undefended** — plain fedavg, screening and teacher filtering off:
    the attacker's uploads fuse straight into the global, measuring the
    raw damage;
  * **screened** — the default defense stack (delta-norm robust-z
    screening + quarantine), plain fedavg aggregation;
  * **robust_agg** — screening off but ``trimmed_mean`` aggregation
    (trim_frac sized to f), measuring what coordinate-wise trimming
    alone buys.

A fault-free fedavg run anchors the comparison; recorded per arm is
the final accuracy and its drift vs fault-free.  Also measured and
gated: the *validation overhead* — a vanishing injection rate turns
the full screening pipeline on without any fault ever firing, which
must cost <= 5% wall time over the plain config (min-of-3 walls both
sides) and reproduce its trajectory bitwise (asserted).

Writes ``BENCH_robustness.json`` (override with ``BENCH_ROBUSTNESS_OUT``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.population import FaultConfig

K = 10
DIM, CLASSES = 16, 10
OUT = os.environ.get("BENCH_ROBUSTNESS_OUT", "BENCH_robustness.json")

CHAOS = dict(byzantine_frac=0.2, byzantine_scale=10.0,
             byzantine_mode="sign_flip", nan_rate=0.05)


def _problem(seed=0):
    ds = gaussian_mixture(4000, n_classes=CLASSES, dim=DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, K, 1.0, seed=seed)
    src = UnlabeledDataset(np.random.default_rng(seed + 1).uniform(
        -3, 3, (2048, DIM)).astype(np.float32))
    return train, val, test, parts, src


def _config(rounds, strategy="fedavg", **kw):
    return FLConfig(strategy=strategy, rounds=rounds, client_fraction=1.0,
                    local_epochs=10, local_batch_size=32, local_lr=0.05,
                    seed=0, fusion=FusionConfig(max_steps=200, patience=200,
                                                eval_every=50,
                                                batch_size=64), **kw)


def run() -> None:
    rounds = scale(10, 16)
    train, val, test, parts, src = _problem()
    net = mlp(DIM, CLASSES, hidden=(128, 128))

    def one(cfg):
        t0 = time.perf_counter()
        results, globals_, _ = run_rounds(
            [net], [0] * K, train, parts, val, test, cfg,
            source=src, driver="sync")
        jax.block_until_ready(jax.tree.leaves(globals_[0])[0])
        wall = time.perf_counter() - t0
        logs = results[0].logs
        finite = all(bool(np.isfinite(np.asarray(l)).all())
                     for l in jax.tree.leaves(globals_[0]))
        return {"final_acc": results[0].final_acc, "wall_s": wall,
                "finite": finite,
                "quarantined": sum(l.n_quarantined for l in logs),
                "corrupted": sum(l.n_corrupted for l in logs)}, results[0]

    clean, r_clean = one(_config(rounds))

    # armed-and-screening: a vanishing injection rate keeps every fault
    # draw silent but turns the validation pipeline ON — delta-norm
    # screening + the divergence guard run every round against honest
    # uploads.  The trajectory is asserted bitwise (an honest cohort
    # never trips the robust-z screen); the wall overhead is min-of-3
    # on both sides so jit warmup and scheduler noise cancel.
    armed_cfg = _config(rounds, faults=FaultConfig(
        nan_rate=1e-12, screen="on", quorum=0.8, retries=3))
    walls_plain, walls_armed = [], []
    r_armed = None
    for _ in range(3):
        c2, _ = one(_config(rounds))
        walls_plain.append(c2["wall_s"])
        a2, r_armed = one(armed_cfg)
        walls_armed.append(a2["wall_s"])
    assert [l.test_acc for l in r_armed.logs] == \
        [l.test_acc for l in r_clean.logs], \
        "armed screening on honest uploads must not perturb the trajectory"
    overhead = min(walls_armed) / min(walls_plain) - 1.0

    undefended, _ = one(_config(rounds, faults=FaultConfig(
        **CHAOS, screen="off", teacher_filter="off")))
    screened, _ = one(_config(rounds, faults=FaultConfig(**CHAOS)))
    # trim sized to the threat: byzantine_frac 0.2 of K=10 realizes 2
    # attackers at this seed; trim_frac 0.35 -> trim 3 per side leaves
    # room for an occasional unscreened NaN row in the same tail
    robust, _ = one(_config(rounds, strategy="trimmed_mean",
                            trim_frac=0.35,
                            faults=FaultConfig(**CHAOS, screen="off",
                                               teacher_filter="off")))

    drift = lambda arm: arm["final_acc"] - clean["final_acc"]
    rec = {
        "K": K, "dim": DIM, "classes": CLASSES, "rounds": rounds,
        "chaos": CHAOS,
        "clean": clean,
        "idle_overhead_frac": overhead,
        "undefended": {**undefended, "drift": drift(undefended)},
        "screened": {**screened, "drift": drift(screened)},
        "trimmed_mean": {**robust, "drift": drift(robust)},
    }
    emit("robustness_screened_drift", abs(drift(screened)) * 1e6,
         f"undef_drift_{drift(undefended):.3f}", record=rec)
    finish_bench("robustness", rec, out=OUT,
                 config={"K": K, "rounds": rounds, "chaos": CHAOS})
    print(f"wrote {OUT}: clean {clean['final_acc']:.4f}, undefended "
          f"{undefended['final_acc']:.4f} (drift {drift(undefended):+.4f}), "
          f"screened {screened['final_acc']:.4f} "
          f"(drift {drift(screened):+.4f}, quarantined "
          f"{screened['quarantined']}), trimmed_mean "
          f"{robust['final_acc']:.4f} (drift {drift(robust):+.4f}); "
          f"idle fault-seam overhead {overhead * 100:+.1f}%")


if __name__ == "__main__":
    run()

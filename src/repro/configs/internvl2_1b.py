"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT (stub) + Qwen2-0.5B-style LM. [arXiv:2404.16821]"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    tie_embeddings=True,
    frontend="vision_patches",
    n_frontend_tokens=256,   # projected ViT patch embeddings (stub)
    pattern=(BlockSpec("attn_global", "swiglu"),),
)

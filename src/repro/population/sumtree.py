"""O(log N) prioritized sampling over a complete binary sum tree.

The classic prioritized-replay structure: leaf ``i`` holds a non-negative
priority, internal nodes hold subtree sums, so point updates and
prefix-sum lookups (sample u ~ U[0, total), walk down to the leaf whose
cumulative interval contains u) are both O(log N).  Backs the
``prioritized`` cohort sampler (population/scheduler.py) at population
scale, where a naive ``searchsorted(cumsum(p))`` would be O(N) per
update.
"""
from __future__ import annotations

import numpy as np


class SumTree:
    """Fixed-capacity sum tree over ``n`` non-negative priorities.

    Stored as a flat heap-ordered array of ``2 * capacity`` float64 slots
    (capacity = next power of two >= n); leaves live at
    ``[capacity, capacity + n)`` and the root sum at index 1.
    """

    def __init__(self, n: int):
        if n < 1:
            raise ValueError(f"sum tree needs n >= 1, got {n}")
        self.n = int(n)
        cap = 1
        while cap < self.n:
            cap *= 2
        self._cap = cap
        self._tree = np.zeros(2 * cap, dtype=np.float64)

    @classmethod
    def from_values(cls, values) -> "SumTree":
        """Vectorized O(N) build: fill the leaves, sum level by level."""
        values = np.asarray(values, dtype=np.float64)
        if values.ndim != 1:
            raise ValueError("from_values expects a 1-D priority array")
        if (values < 0).any():
            raise ValueError("priorities must be non-negative")
        t = cls(len(values))
        t._tree[t._cap:t._cap + t.n] = values
        level = t._tree[t._cap:2 * t._cap]
        lo = t._cap
        while lo > 1:
            lo //= 2
            level = level[0::2] + level[1::2]
            t._tree[lo:2 * lo] = level
        return t

    def total(self) -> float:
        return float(self._tree[1])

    def get(self, i: int) -> float:
        return float(self._tree[self._cap + i])

    def values(self) -> np.ndarray:
        """Copy of the current leaf priorities (length n)."""
        return self._tree[self._cap:self._cap + self.n].copy()

    def set(self, i: int, value: float) -> None:
        """Point update, propagating sums to the root: O(log N)."""
        if not 0 <= i < self.n:
            raise IndexError(f"leaf {i} out of range [0, {self.n})")
        if value < 0:
            raise ValueError("priorities must be non-negative")
        node = self._cap + i
        delta = float(value) - self._tree[node]
        while node >= 1:
            self._tree[node] += delta
            node //= 2

    def set_many(self, ids, values) -> None:
        ids = np.asarray(ids)
        values = np.broadcast_to(np.asarray(values, np.float64), ids.shape)
        for i, v in zip(ids.ravel(), values.ravel()):
            self.set(int(i), float(v))

    def find(self, u: float) -> int:
        """Leaf whose cumulative-priority interval contains ``u``.

        Equivalent to ``searchsorted(cumsum(values), u, side='right')``
        for ``u`` in ``[0, total)``, in O(log N).
        """
        node = 1
        while node < self._cap:
            left = 2 * node
            if u < self._tree[left]:
                node = left
            else:
                u -= self._tree[left]
                node = left + 1
        return min(node - self._cap, self.n - 1)

    def sample(self, rng: np.random.Generator, k: int,
               replace: bool = False) -> np.ndarray:
        """Draw ``k`` leaves with probability proportional to priority.

        Without replacement, drawn leaves are temporarily zeroed and
        restored afterwards, so the tree is unchanged on return.
        """
        out = np.empty(k, dtype=np.int64)
        if replace:
            for j in range(k):
                out[j] = self.find(rng.random() * self.total())
            return out
        saved = []
        try:
            for j in range(k):
                total = self.total()
                if total <= 0.0:
                    raise ValueError(
                        f"sum tree exhausted after {j} draws (k={k}): "
                        f"not enough positive-priority leaves")
                i = self.find(rng.random() * total)
                out[j] = i
                saved.append((i, self.get(i)))
                self.set(i, 0.0)
        finally:
            for i, v in reversed(saved):
                self.set(i, v)
        return out

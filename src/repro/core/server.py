"""Legacy federated server entry points — Algorithm 1 (homogeneous) /
Algorithm 3 (heterogeneous prototypes).

DEPRECATED: new code should use the declarative API
(``repro.api.Experiment`` — one spec, one ``run()``, one ``RunResult``,
typed ``RoundEvent`` observers, resumable checkpoints; see
docs/experiment_api.md).  These shims are kept because their trajectories
are the reference the API is pinned against
(``tests/test_experiment_api.py``) and existing callers/tests rely on
their signatures.

Both loops route through the shared vectorized round engine
(``core/engine.py``): each round, all active clients of a prototype group
train in one jitted vmap-over-clients scan, and the stacked uploads are
handed to a pluggable :class:`~repro.core.strategies.ServerStrategy` from
the registry in ``core/strategies.py``:

  fedavg   — weighted parameter average (McMahan et al.)
  fedprox  — fedavg aggregation + proximal local objective (Li et al.)
  fedavgm  — server momentum:  v = beta v + dx;  x = x - v  (Hsu et al.,
             exactly the update scheme in Appendix C.2)
  feddf    — fedavg init + server-side ensemble distillation (this paper)

Architecture notes: docs/round_engine.md.  The loop tracks per-round test
accuracy and rounds-to-target (Table 1's metric).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# Re-exported for backward compatibility: these historically lived here.
from repro.core.engine import (FLConfig, FLResult, RoundLog, _make_opt,
                               run_rounds)
from repro.core.nets import Net
from repro.data.distill_sources import DistillSource
from repro.data.synthetic import Dataset

__all__ = ["FLConfig", "FLResult", "RoundLog", "run_federated",
           "run_federated_heterogeneous", "run_rounds"]


def run_federated(
    net: Net,
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    source: Optional[DistillSource] = None,
    log_fn: Optional[Callable[[RoundLog], None]] = None,
    mesh=None,
) -> FLResult:
    """Homogeneous FL (Algorithm 1).  ``mesh`` optionally shards the round
    engine's client axis across devices (K active clients must divide the
    mesh's "data" axis)."""
    results, _, rounds_to_target = run_rounds(
        [net], [0] * len(parts), train, parts, val, test, cfg,
        source=source, log_fn=log_fn, heterogeneous=False, mesh=mesh)
    return dataclasses.replace(results[0], rounds_to_target=rounds_to_target)


def run_federated_heterogeneous(
    nets: List[Net],                      # one per prototype group
    client_proto: Sequence[int],          # client k -> prototype index
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    source: Optional[DistillSource] = None,
    log_fn=None,
    mesh=None,
) -> Tuple[List[FLResult], List[dict]]:
    """Heterogeneous FL (Algorithm 3).  strategy='fedavg' averages within
    each prototype group only (paper Fig. 4 dashed lines); 'feddf' fuses each
    group against the all-groups ensemble.  ``mesh`` is currently ignored
    here (rng-driven group sizes can't satisfy shard_map divisibility)."""
    results, globals_, _ = run_rounds(
        nets, client_proto, train, parts, val, test, cfg,
        source=source, log_fn=log_fn, heterogeneous=True, mesh=mesh)
    return results, globals_

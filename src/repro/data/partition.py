"""Dirichlet non-i.i.d. client partitioning (paper §4.1, Appendix C.2).

Each client's class distribution q_k ~ Dir(alpha * p), where p is the prior
class distribution.  alpha -> inf mimics identical local distributions;
alpha -> 0 gives one-class clients.  Partitions are *disjoint* — samples are
allocated class-by-class proportionally to the clients' Dirichlet weights,
exactly as in Yurochkin et al. / Hsu et al. (refs [79, 25] of the paper).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 1
                        ) -> List[np.ndarray]:
    """Return a list of disjoint index arrays, one per client."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    idx_by_class = {c: rng.permutation(np.where(labels == c)[0])
                    for c in classes}
    # client weights per class: column k of a [C, K] Dirichlet draw
    props = rng.dirichlet([alpha] * n_clients, size=len(classes))  # [C, K]
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for ci, c in enumerate(classes):
        idx = idx_by_class[c]
        # proportional split with exact coverage
        cuts = (np.cumsum(props[ci]) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].extend(part.tolist())
    out = [np.asarray(sorted(ix), dtype=np.int64) for ix in client_idx]
    # guarantee non-empty clients (tiny datasets + small alpha)
    pool = max(range(n_clients), key=lambda k: len(out[k]))
    for k in range(n_clients):
        while len(out[k]) < min_per_client and len(out[pool]) > min_per_client:
            out[k] = np.append(out[k], out[pool][-1])
            out[pool] = out[pool][:-1]
    return out


def class_histogram(labels: np.ndarray, parts: Sequence[np.ndarray],
                    n_classes: int) -> np.ndarray:
    """[K, C] sample counts — the paper's Fig. 2 dot plot data."""
    h = np.zeros((len(parts), n_classes), dtype=np.int64)
    for k, ix in enumerate(parts):
        for c in range(n_classes):
            h[k, c] = int(np.sum(labels[ix] == c))
    return h

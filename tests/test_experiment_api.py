"""Declarative experiment API (docs/experiment_api.md).

 1. Specs are lossless as data: ``from_json(to_json(spec)) == spec``,
    unknown fields/registry names fail loudly, validation catches bad
    wiring before any work starts.
 2. Trajectory equivalence: ``Experiment.run()`` reproduces the legacy
    ``run_federated`` / ``run_federated_heterogeneous`` logs EXACTLY at
    fixed seed (the facade is a re-wiring, not a re-implementation).
 3. Typed ``RoundEvent`` observers replace the shape-shifting ``log_fn``.
 4. ``Experiment.resume`` continues an interrupted checkpointed run with
    a trajectory identical to an uninterrupted one, including stateful
    strategies (fedavgm momentum buffers).
 5. The train CLI's ``--dump-config`` -> ``--config`` round trip
    reproduces the identical per-round accuracy log.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.api import (CohortSpec, Experiment, ExperimentSpec, FusionSpec,
                       ModelSpec, PartitionSpec, PrivacySpec, SourceSpec,
                       StrategySpec, TaskSpec, get_model, get_source,
                       get_task, register_task)
from repro.checkpoint import io as ckpt
from repro.core import (FLConfig, FusionConfig, mlp, run_federated,
                        run_federated_heterogeneous)
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)


def small_fusion():
    return FusionSpec(max_steps=50, patience=50, eval_every=25,
                      batch_size=32)


def homo_spec(strategy="feddf", rounds=2):
    return ExperimentSpec(
        task=TaskSpec(name="blobs", n_samples=1200),
        partition=PartitionSpec(n_clients=6, alpha=1.0),
        cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                {"hidden": [16, 16]})]),
        strategy=StrategySpec(name=strategy, fusion=small_fusion()),
        source=(SourceSpec(name="unlabeled", params={"n": 500})
                if strategy == "feddf" else None),
        rounds=rounds, client_fraction=0.5, local_epochs=3,
        local_batch_size=32, local_lr=0.05, seed=0)


# ---------------------------------------------------------------------------
# spec serialization + validation
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = ExperimentSpec(
        task=TaskSpec(name="tokens", n_samples=900,
                      params={"vocab": 32, "seq_len": 8}),
        partition=PartitionSpec(n_clients=4, alpha=0.3, seed=5),
        cohort=CohortSpec(
            prototypes=[ModelSpec("tiny_transformer", {"d_model": 32}),
                        ModelSpec("tiny_transformer", {"d_model": 48})],
            assignment=[0, 1, 0, 1]),
        strategy=StrategySpec(name="feddf", drop_worst=True,
                              fusion=small_fusion()),
        source=SourceSpec(name="generator", params={"std": 2.0}),
        privacy=PrivacySpec(clip=1.0, noise_multiplier=0.3,
                            quantizer="binarize"),
        rounds=3, client_fraction=0.5, local_optimizer="adam",
        local_adam_lr=0.01, seed=7)
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # and through a file
    default = ExperimentSpec()
    assert ExperimentSpec.from_dict(default.to_dict()) == default


def test_spec_no_source_round_trips():
    spec = homo_spec(strategy="fedavg")
    assert spec.source is None
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown field"):
        ExperimentSpec.from_dict({"roundz": 5})
    with pytest.raises(ValueError, match="unknown field"):
        TaskSpec.from_dict({"name": "blobs", "nsamples": 5})


@pytest.mark.parametrize("mutate,match", [
    (lambda s: dataclasses.replace(s, task=TaskSpec(name="no-such-task")),
     "unknown task"),
    (lambda s: dataclasses.replace(
        s, cohort=CohortSpec(prototypes=[ModelSpec("no-such-model")])),
     "unknown model"),
    (lambda s: dataclasses.replace(
        s, source=SourceSpec(name="no-such-source")), "unknown source"),
    (lambda s: dataclasses.replace(
        s, privacy=PrivacySpec(quantizer="no-such-quantizer")),
     "unknown quantizer"),
    (lambda s: dataclasses.replace(
        s, strategy=StrategySpec(name="no-such-strategy")),
     "unknown strategy"),
    (lambda s: dataclasses.replace(s, source=None), "needs a distillation"),
    (lambda s: dataclasses.replace(s, rounds=0), "rounds"),
    (lambda s: dataclasses.replace(s, client_fraction=1.5),
     "client_fraction"),
    (lambda s: dataclasses.replace(
        s, cohort=CohortSpec(prototypes=[ModelSpec("mlp")],
                             assignment=[0, 0])),
     "entries for"),
])
def test_validate_fails_loudly(mutate, match):
    with pytest.raises(ValueError, match=match):
        mutate(homo_spec()).validate()


def test_registry_unknown_names():
    for get, kind in ((get_task, "task"), (get_model, "model"),
                      (get_source, "source")):
        with pytest.raises(ValueError, match=f"unknown {kind}"):
            get("definitely-not-registered")


def test_registry_extension():
    @register_task("api-test-task")
    def build(n_samples=100, seed=0):  # pragma: no cover - trivial
        return get_task("blobs")(n_samples=n_samples, seed=seed)

    try:
        spec = dataclasses.replace(
            homo_spec(), task=TaskSpec(name="api-test-task", n_samples=100))
        spec.validate()  # resolves through the registry
    finally:
        from repro.api import registries as R
        # the registry table lives in the closure shared by register/get
        table = next(c.cell_contents for c in R.get_task.__closure__
                     if isinstance(c.cell_contents, dict))
        table.pop("api-test-task", None)


# ---------------------------------------------------------------------------
# trajectory equivalence with the legacy entry points
# ---------------------------------------------------------------------------

def legacy_problem(seed=0, n=1200, n_clients=6, alpha=1.0, n_src=500):
    ds = gaussian_mixture(n, n_classes=3, dim=2, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, n_clients, alpha, seed=seed)
    src = UnlabeledDataset(np.random.default_rng(seed + 7).uniform(
        -3, 3, (n_src, 2)).astype(np.float32))
    return train, val, test, parts, src


def legacy_cfg(strategy="feddf"):
    return FLConfig(strategy=strategy, rounds=2, client_fraction=0.5,
                    local_epochs=3, local_batch_size=32, local_lr=0.05,
                    seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32))


def test_run_matches_legacy_homogeneous():
    train, val, test, parts, src = legacy_problem()
    legacy = run_federated(mlp(2, 3, hidden=(16, 16)), train, parts, val,
                           test, legacy_cfg(), source=src)

    events = []
    res = Experiment(homo_spec()).run(observers=[events.append])
    assert res.result.logs == legacy.logs
    assert res.rounds_to_target == legacy.rounds_to_target
    for a, b in zip(jax.tree.leaves(res.global_params[0]),
                    jax.tree.leaves(legacy.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # typed events replace log_fn: one per (round, group), uniform shape
    assert [(e.round, e.group, e.heterogeneous) for e in events] == \
        [(1, 0, False), (2, 0, False)]
    assert [e.log for e in events] == legacy.logs


def test_run_matches_legacy_heterogeneous():
    train, val, test, parts, src = legacy_problem()
    nets = [mlp(2, 3, hidden=(12,), name="proto-s"),
            mlp(2, 3, hidden=(24,), name="proto-m")]
    proto = [k % 2 for k in range(len(parts))]
    legacy_results, legacy_globals = run_federated_heterogeneous(
        nets, proto, train, parts, val, test, legacy_cfg(), source=src)

    spec = dataclasses.replace(
        homo_spec(),
        cohort=CohortSpec(prototypes=[
            ModelSpec("mlp", {"hidden": [12], "name": "proto-s"}),
            ModelSpec("mlp", {"hidden": [24], "name": "proto-m"})]))
    events = []
    res = Experiment(spec).run(observers=[events.append])
    assert res.heterogeneous and len(res.results) == 2
    for r_new, r_old in zip(res.results, legacy_results):
        assert r_new.logs == r_old.logs
    for g_new, g_old in zip(res.global_params, legacy_globals):
        for a, b in zip(jax.tree.leaves(g_new), jax.tree.leaves(g_old)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert {(e.round, e.group) for e in events} == \
        {(t, g) for t in (1, 2) for g in (0, 1)}


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def test_save_load_obj_round_trip(tmp_path):
    obj = {"a": np.arange(3, dtype=np.float32), "b": None,
           "c": [np.ones((2, 2)), {"d": 5}], "e": (1.5, "x", True)}
    path = str(tmp_path / "state")
    ckpt.save_obj(path, obj)
    back = ckpt.load_obj(path)
    assert back["b"] is None
    assert back["c"][1] == {"d": 5}
    assert back["e"] == (1.5, "x", True)
    np.testing.assert_array_equal(np.asarray(back["a"]), obj["a"])
    np.testing.assert_array_equal(np.asarray(back["c"][0]), obj["c"][0])
    # non-string dict keys would come back silently stringified — refuse
    with pytest.raises(TypeError, match="string dict keys"):
        ckpt.save_obj(str(tmp_path / "bad"), {0: 1.0})


class _StopAfter(Exception):
    pass


def test_resume_matches_uninterrupted(tmp_path):
    """Interrupt a checkpointed fedavgm run (server momentum state!) after
    round 2 of 4; resuming must reproduce the uninterrupted trajectory and
    final globals exactly."""
    spec = homo_spec(strategy="fedavgm", rounds=4)
    baseline = Experiment(spec).run()

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    ckpt_dir = str(tmp_path / "run")
    with pytest.raises(_StopAfter):
        Experiment(spec).run(observers=[bomb], checkpoint_dir=ckpt_dir)
    assert os.path.isdir(os.path.join(ckpt_dir, "rounds", "00002"))

    resumed = Experiment.resume(ckpt_dir)
    assert resumed.result.logs == baseline.result.logs
    for a, b in zip(jax.tree.leaves(resumed.global_params[0]),
                    jax.tree.leaves(baseline.global_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_of_complete_run_is_a_noop(tmp_path):
    spec = homo_spec(strategy="fedavg", rounds=2)
    ckpt_dir = str(tmp_path / "run")
    first = Experiment(spec).run(checkpoint_dir=ckpt_dir)
    again = Experiment.resume(ckpt_dir)
    assert again.result.logs == first.result.logs


def test_resume_after_target_stop_does_not_retrain(tmp_path):
    """A checkpointed run that early-stopped on target_accuracy must
    resume as a no-op, not retrain past the recorded stop."""
    spec = dataclasses.replace(homo_spec(strategy="fedavg", rounds=6),
                               target_accuracy=0.4)
    ckpt_dir = str(tmp_path / "run")
    first = Experiment(spec).run(checkpoint_dir=ckpt_dir)
    assert first.rounds_to_target is not None
    assert first.rounds_to_target < 6
    resumed = Experiment.resume(ckpt_dir)
    assert resumed.rounds_to_target == first.rounds_to_target
    assert resumed.result.logs == first.result.logs


def test_superseded_checkpoints_are_pruned(tmp_path):
    """Only the newest snapshots stay on disk (each holds the full log
    history, so older round dirs are dead weight)."""
    spec = homo_spec(strategy="fedavg", rounds=4)
    ckpt_dir = str(tmp_path / "run")
    Experiment(spec).run(checkpoint_dir=ckpt_dir)
    assert sorted(os.listdir(os.path.join(ckpt_dir, "rounds"))) == \
        ["00003", "00004"]


def test_resume_without_checkpoints_fails_loudly(tmp_path):
    homo_spec().save(str(tmp_path / "spec.json"))
    with pytest.raises(FileNotFoundError, match="no complete round"):
        Experiment.resume(str(tmp_path))


def test_resume_falls_back_past_partial_checkpoint(tmp_path):
    """A crash mid-checkpoint leaves a round dir without logs.json; the
    loader must fall back to the intact previous snapshot."""
    spec = homo_spec(strategy="fedavg", rounds=3)
    baseline = Experiment(spec).run()
    ckpt_dir = str(tmp_path / "run")

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    with pytest.raises(_StopAfter):
        Experiment(spec).run(observers=[bomb], checkpoint_dir=ckpt_dir)
    # simulate a kill partway through writing round 2's snapshot
    os.remove(os.path.join(ckpt_dir, "rounds", "00002", "logs.json"))
    resumed = Experiment.resume(ckpt_dir)  # falls back to round 1
    assert resumed.result.logs == baseline.result.logs


# ---------------------------------------------------------------------------
# CLI: flags compile to a spec; --dump-config/--config replay identically
# ---------------------------------------------------------------------------

def test_cli_config_round_trip(tmp_path):
    from repro.launch.train import main
    common = ["--strategy", "feddf", "--rounds", "2", "--clients", "4",
              "-C", "1.0", "--local-epochs", "2", "--n-samples", "600",
              "--distill-steps", "50", "--checkpoint-every", "0"]
    cfg_path = str(tmp_path / "run.json")
    main(common + ["--dump-config", cfg_path,
                   "--out", str(tmp_path / "a")])
    main(["--config", cfg_path, "--out", str(tmp_path / "b")])
    a = json.load(open(tmp_path / "a" / "summary.json"))
    b = json.load(open(tmp_path / "b" / "summary.json"))
    assert a["per_round"] == b["per_round"]
    # summary.json carries the canonical spec, not raw argparse vars
    assert a["config"] == ExperimentSpec.load(cfg_path).to_dict()
    assert a["config"] == b["config"]

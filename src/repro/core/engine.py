"""Vectorized federated round engine (see docs/round_engine.md).

One loop serves both Algorithm 1 (homogeneous) and Algorithm 3
(heterogeneous prototypes).  Per round:

  1. sample the active cohort and bucket it by prototype group;
  2. train every group's clients in ONE jitted vmap-over-clients scan
     (``client.make_batched_local_update``) — batches stacked to
     [K_g, n_steps, B, ...], FedProx / quantize / DP inside the jit, and
     optionally the client axis sharded over a device mesh;
  3. optional drop-worst hook filters the stacked uploads;
  4. dispatch the stacks to the configured :class:`ServerStrategy`
     (``core/strategies.py`` registry) which emits the new globals;
  5. evaluate, log, early-stop on the rounds-to-target criterion.

Clients with fewer local steps than the padded scan length are masked, so
each trajectory matches the sequential reference path exactly; padding to
the fixed per-prototype maximum means one compiled program per prototype
for the whole run instead of one per client per distinct shape.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feddf as feddf_mod
from repro.core.client import (build_batched_batches, evaluate,
                               make_batched_local_update, n_local_steps)
from repro.common.pytree import tree_take
from repro.core.dropworst import drop_worst_stacked
from repro.core.nets import Net
from repro.core.strategies import GroupRound, RoundContext, get_strategy
from repro.data.distill_sources import DistillSource
from repro.data.synthetic import Dataset
from repro.optim.optimizers import Optimizer, sgd

# distinguishes "no init_state passed" from a legitimately-None state
# (most strategies keep no server state at all)
_UNSET = object()


@dataclasses.dataclass
class FLConfig:
    rounds: int = 20
    client_fraction: float = 0.4  # C
    local_epochs: int = 20        # E
    local_batch_size: int = 32
    local_lr: float = 0.1
    strategy: str = "fedavg"      # any name in the strategy registry
    prox_mu: float = 0.01
    server_momentum: float = 0.3  # beta for fedavgm
    drop_worst: bool = False
    seed: int = 0
    local_optimizer: str = "sgd"  # sgd | adam (Table 6 ablation)
    local_adam_lr: float = 1e-3   # adam local lr (sgd uses local_lr)
    quantize: Optional[Callable] = None
    fusion: feddf_mod.FusionConfig = dataclasses.field(
        default_factory=feddf_mod.FusionConfig)
    feddf_init_from: str = "average"  # Table 5 ablation: average | previous
    target_accuracy: Optional[float] = None  # stop early when reached
    # client-level DP on uploads (paper §3 privacy extension; core/privacy.py)
    dp_clip: Optional[float] = None
    dp_noise_multiplier: float = 0.0


@dataclasses.dataclass
class RoundLog:
    round: int
    test_acc: float
    val_acc: float
    ensemble_acc: Optional[float] = None
    pre_distill_acc: Optional[float] = None
    distill_steps: int = 0
    n_participants: int = 0
    n_dropped: int = 0
    # teacher batch-forwards this round's fusion cost (0 when the shared
    # logit bank served a group, or for non-distillation strategies)
    teacher_forwards: int = 0


@dataclasses.dataclass
class FLResult:
    logs: List[RoundLog]
    global_params: dict
    rounds_to_target: Optional[int] = None

    @property
    def final_acc(self) -> float:
        return self.logs[-1].test_acc if self.logs else 0.0

    @property
    def best_acc(self) -> float:
        return max(l.test_acc for l in self.logs) if self.logs else 0.0


def _make_opt(cfg: FLConfig) -> Optimizer:
    if cfg.local_optimizer == "adam":
        from repro.optim.optimizers import adam
        return adam(cfg.local_adam_lr)
    return sgd(cfg.local_lr)


def run_rounds(
    nets: List[Net],
    client_proto: Sequence[int],          # client k -> prototype index
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    *,
    source: Optional[DistillSource] = None,
    log_fn: Optional[Callable] = None,
    heterogeneous: bool = False,
    mesh=None,
    client_axis: str = "data",
    init_globals: Optional[List[dict]] = None,
    init_state=_UNSET,
    start_round: int = 1,
    init_logs: Optional[List[List["RoundLog"]]] = None,
    round_end_hook: Optional[Callable] = None,
) -> Tuple[List[FLResult], List[dict], Optional[int]]:
    """The shared round loop.  Returns (per-prototype results, final
    globals, rounds_to_target).  ``mesh`` shards the client axis of local
    training over ``client_axis`` (homogeneous runs only — the active
    cohort size must divide the axis size; it is ignored for
    heterogeneous runs, whose group sizes are rng-driven).  Homogeneous
    callers pass one net and ``client_proto`` all zeros; ``log_fn``
    receives ``RoundLog`` (homogeneous) or ``(group, RoundLog)``
    (heterogeneous) to match the historic APIs.

    Resume support (``repro.api.Experiment.resume``): pass the
    checkpointed ``init_globals`` / ``init_state`` / ``init_logs`` and
    ``start_round = <last completed round> + 1``; the cohort-sampling rng
    replays the completed rounds' draws so the trajectory is identical to
    an uninterrupted run.  ``round_end_hook(t, globals_, state, logs)``
    fires after every completed round (this is the checkpoint seam)."""
    strategy = get_strategy(cfg.strategy)
    rng = np.random.default_rng(cfg.seed)
    n_clients = len(parts)
    n_active = max(1, int(round(cfg.client_fraction * n_clients)))
    n_proto = len(nets)
    if heterogeneous and mesh is not None:
        # per-group cohort sizes are rng-driven each round, so shard_map's
        # divisibility constraint cannot be met — client-axis device
        # sharding is homogeneous-only for now (see ROADMAP)
        warnings.warn(
            "client-axis mesh sharding is ignored for heterogeneous runs "
            "(rng-driven per-group cohort sizes cannot satisfy shard_map "
            "divisibility); training unsharded",
            UserWarning, stacklevel=2)
        mesh = None

    globals_: List[dict] = (
        list(init_globals) if init_globals is not None else
        [nets[p].init(jax.random.PRNGKey(cfg.seed + p if heterogeneous
                                         else cfg.seed))
         for p in range(n_proto)])

    prox = strategy.local_prox_mu(cfg)
    updates = [
        make_batched_local_update(
            nets[p], _make_opt(cfg), prox_mu=prox, quantize=cfg.quantize,
            dp_clip=cfg.dp_clip,
            dp_noise_multiplier=cfg.dp_noise_multiplier,
            mesh=mesh, client_axis=client_axis,
            # the engine rebuilds the batch tensors every round, so their
            # device buffers are donatable scratch
            donate_batches=True)
        for p in range(n_proto)]
    # transfer the eval sets to device ONCE per run: `evaluate`, drop-worst
    # and the distillation val loop otherwise re-upload the same numpy
    # arrays every round (labels stay host-side, they are compared there)
    val_x = jnp.asarray(val.x)
    test_x = jnp.asarray(test.x)
    # fixed scan length AND fixed client-axis size per prototype -> one
    # compiled program per prototype for the whole run (group sizes vary
    # round to round in the heterogeneous case; padded clients get an
    # all-False step mask and are sliced off the stack afterwards)
    steps_cap = [
        max([n_local_steps(len(parts[k]), cfg.local_batch_size,
                           cfg.local_epochs)
             for k in range(n_clients) if client_proto[k] == p] or [1])
        for p in range(n_proto)]
    proto_counts = [sum(1 for q in client_proto if q == p)
                    for p in range(n_proto)]
    k_cap = [min(n_active, c) if c else 1 for c in proto_counts]
    batch_seed_mult = 99991 if heterogeneous else 100_003

    state = (strategy.init_state(globals_) if init_state is _UNSET
             else init_state)
    logs: List[List[RoundLog]] = (
        [list(l) for l in init_logs] if init_logs is not None
        else [[] for _ in range(n_proto)])
    rounds_to_target = None

    # replay the cohort draws of already-completed rounds so a resumed run
    # samples the same clients an uninterrupted run would have
    for _ in range(start_round - 1):
        rng.choice(n_clients, size=n_active, replace=False)

    for t in range(start_round, cfg.rounds + 1):
        active = rng.choice(n_clients, size=n_active, replace=False)
        by_proto: List[List[int]] = [[] for _ in range(n_proto)]
        for k in active:
            by_proto[client_proto[k]].append(int(k))

        groups: List[GroupRound] = []
        for p in range(n_proto):
            ks = by_proto[p]
            if not ks:
                groups.append(GroupRound(nets[p], globals_[p], None,
                                         np.zeros(0)))
                continue
            xb, yb, step_mask = build_batched_batches(
                train.x, train.y, [parts[k] for k in ks],
                cfg.local_batch_size, cfg.local_epochs,
                seeds=[cfg.seed * batch_seed_mult + t * 131 + k for k in ks],
                n_steps=steps_cap[p])
            if cfg.dp_clip is not None:
                dp_keys = np.stack([
                    np.asarray(jax.random.PRNGKey(
                        cfg.seed * 7919 + t * 131 + k)) for k in ks])
            else:
                dp_keys = np.zeros((len(ks), 2), np.uint32)
            k_real = len(ks)
            if k_real < k_cap[p]:  # pad the client axis to the fixed size
                pad = k_cap[p] - k_real
                zpad = lambda a: np.concatenate(
                    [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
                xb, yb, step_mask, dp_keys = (zpad(xb), zpad(yb),
                                              zpad(step_mask), zpad(dp_keys))
            stack = updates[p](globals_[p], jnp.asarray(xb),
                               jnp.asarray(yb), globals_[p],
                               jnp.asarray(step_mask), jnp.asarray(dp_keys))
            if k_real < k_cap[p]:
                stack = tree_take(stack, np.arange(k_real))
            weights = np.array([float(len(parts[k])) for k in ks])
            groups.append(GroupRound(nets[p], globals_[p], stack, weights))

        dropped = [0] * n_proto
        if cfg.drop_worst:
            for p, g in enumerate(groups):
                if g.stack is None:
                    continue
                kept, kept_w, kept_i = drop_worst_stacked(
                    g.net, g.stack, g.weights, val_x, val.y,
                    train.n_classes)
                dropped[p] = len(g.weights) - len(kept_i)
                g.stack, g.weights = kept, np.asarray(kept_w)

        ens_acc = None
        if heterogeneous:
            from repro.core.ensemble import ensemble_accuracy_stacked
            ens_acc = ensemble_accuracy_stacked(
                [(g.net, g.stack) for g in groups if g.stack is not None],
                test_x, test.y)

        ctx = RoundContext(cfg=cfg, round=t, heterogeneous=heterogeneous,
                           source=source, val_x=val_x, val_y=val.y,
                           test_x=test_x, test_y=test.y)
        globals_, state, infos = strategy.aggregate(groups, state, ctx)

        for p in range(n_proto):
            acc = evaluate(nets[p], globals_[p], test_x, test.y,
                           quantize=cfg.quantize)
            vacc = evaluate(nets[p], globals_[p], val_x, val.y,
                            quantize=cfg.quantize)
            log = RoundLog(
                round=t, test_acc=acc, val_acc=vacc, ensemble_acc=ens_acc,
                pre_distill_acc=infos[p].get("pre_distill_acc"),
                distill_steps=infos[p].get("distill_steps", 0),
                n_participants=len(groups[p].weights),
                n_dropped=dropped[p],
                teacher_forwards=infos[p].get("teacher_forwards", 0))
            logs[p].append(log)
            if log_fn:
                log_fn((p, log) if heterogeneous else log)

        if (not heterogeneous and cfg.target_accuracy is not None
                and logs[0][-1].test_acc >= cfg.target_accuracy):
            rounds_to_target = t

        # target check precedes the hook so checkpoints record the stop —
        # a resumed run must not retrain past a recorded early stop
        if round_end_hook is not None:
            round_end_hook(t, globals_, state, logs, rounds_to_target)

        if rounds_to_target is not None:
            break

    results = [FLResult(logs=logs[p], global_params=globals_[p])
               for p in range(n_proto)]
    return results, globals_, rounds_to_target

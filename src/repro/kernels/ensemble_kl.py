"""Pallas TPU kernel for FedDF's AVGLOGITS distillation loss.

The fusion hot-loop evaluates KL(softmax(mean_k teacher), softmax(student))
over [K, B, V] logits with V up to 262 144 (gemma3).  Materialising the
averaged-probability tensors costs 3+ full [B, V] fp32 round-trips to HBM;
this kernel streams V in VMEM tiles with *online* logsumexp (flash-attention
style), producing per-row KL plus the two logsumexps (saved as residuals for
the backward kernel) in a single pass over the logits.

    KL_row = (St - Ss)/Z - lse_t + lse_s
      where, over v:  m  = max t̄_v          (running)
                      Z  = Σ e^{t̄_v - m}
                      St = Σ e^{t̄_v - m} t̄_v
                      Ss = Σ e^{t̄_v - m} s_v
                      lse_t = m + log Z ;  lse_s analogous.

Backward: d/ds = (softmax(s) - softmax(t̄)) * ḡ / B  — one more masked pass.

Grid: (B_tiles, V_tiles), V innermost/sequential; accumulators live in VMEM
scratch and persist across the V iterations of one B tile.

Three entry points share the kernels:

* :func:`ensemble_kl` — raw teachers [K, B, V]; the K axis is reduced to
  t̄ inside the kernel tile.
* :func:`ensemble_kl_pre` — PRE-AVERAGED teacher rows [B, V] (the
  teacher-logit-bank fast path, ``core/logit_bank.py``): bank rows stream
  through the same online-logsumexp pipeline with no [K, B, V]
  materialization anywhere.
* :func:`ensemble_kl_bank` — the WHOLE bank [N, V] (any storage dtype,
  fp32/bf16/int8/fp8) plus per-sample indices and dequant scales: gather,
  dequantize, log-softmax and KL are fused into one kernel via scalar-
  prefetch index maps, so neither the gathered nor the dequantized
  [B, V] teacher rows ever round-trip through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes as jax_dtypes
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG = -1e30


def _teacher_tile(t_ref):
    """Teacher tile -> averaged [bB, bV] fp32 rows.  Rank-3 blocks carry
    the K teacher axis (AVGLOGITS reduces it here); rank-2 blocks are
    already-averaged logit-bank rows used as-is."""
    t = t_ref[...].astype(jnp.float32)
    return jnp.mean(t, axis=0) if t.ndim == 3 else t


def _pad_mask(vi, bv: int, v_total: int, shape):
    """True over the padded tail of the V axis for this tile."""
    v_idx = vi * bv + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return v_idx >= v_total


def _init_row_stats(m_t, z_t, st_acc, ss_acc, m_s, z_s):
    m_t[...] = jnp.full_like(m_t, NEG)
    z_t[...] = jnp.zeros_like(z_t)
    st_acc[...] = jnp.zeros_like(st_acc)
    ss_acc[...] = jnp.zeros_like(ss_acc)
    m_s[...] = jnp.full_like(m_s, NEG)
    z_s[...] = jnp.zeros_like(z_s)


def _online_step(s, t, pad, m_t, z_t, st_acc, ss_acc, m_s, z_s):
    """One V tile of the flash-style running stats.  ``s``/``t`` are fp32
    [bB, bV] with the padded tail already pushed to NEG."""
    # --- online update for teacher stats
    m_new = jnp.maximum(m_t[...], jnp.max(t, axis=-1, keepdims=True))
    scale = jnp.exp(m_t[...] - m_new)
    e_t = jnp.exp(t - m_new)
    e_t = jnp.where(pad, 0.0, e_t)
    z_t[...] = z_t[...] * scale + jnp.sum(e_t, -1, keepdims=True)
    st_acc[...] = st_acc[...] * scale + jnp.sum(e_t * t, -1, keepdims=True)
    ss_acc[...] = ss_acc[...] * scale + jnp.sum(e_t * s, -1, keepdims=True)
    m_t[...] = m_new

    # --- online logsumexp for student
    ms_new = jnp.maximum(m_s[...], jnp.max(s, axis=-1, keepdims=True))
    e_s = jnp.exp(s - ms_new)
    e_s = jnp.where(pad, 0.0, e_s)
    z_s[...] = z_s[...] * jnp.exp(m_s[...] - ms_new) + jnp.sum(
        e_s, -1, keepdims=True)
    m_s[...] = ms_new


def _emit_row_stats(kl_ref, lse_t_ref, lse_s_ref,
                    m_t, z_t, st_acc, ss_acc, m_s, z_s):
    lse_t = m_t[...] + jnp.log(z_t[...])
    lse_s = m_s[...] + jnp.log(z_s[...])
    kl = (st_acc[...] - ss_acc[...]) / z_t[...] - lse_t + lse_s
    kl_ref[...] = kl[:, 0]
    lse_t_ref[...] = lse_t[:, 0]
    lse_s_ref[...] = lse_s[:, 0]


def _fwd_kernel(s_ref, t_ref, kl_ref, lse_t_ref, lse_s_ref,
                m_t, z_t, st_acc, ss_acc, m_s, z_s, *, n_v_tiles: int,
                v_total: int, bv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        _init_row_stats(m_t, z_t, st_acc, ss_acc, m_s, z_s)

    s = s_ref[...].astype(jnp.float32)          # [bB, bV]
    t = _teacher_tile(t_ref)                    # [(K,)bB,bV] -> [bB,bV]

    pad = _pad_mask(vi, bv, v_total, s.shape)
    s = jnp.where(pad, NEG, s)
    t = jnp.where(pad, NEG, t)
    _online_step(s, t, pad, m_t, z_t, st_acc, ss_acc, m_s, z_s)

    @pl.when(vi == n_v_tiles - 1)
    def _finish():
        _emit_row_stats(kl_ref, lse_t_ref, lse_s_ref,
                        m_t, z_t, st_acc, ss_acc, m_s, z_s)


def _bwd_kernel(s_ref, t_ref, lse_t_ref, lse_s_ref, g_ref, ds_ref, *,
                v_total: int, bv: int, b_total: int):
    vi = pl.program_id(1)
    s = s_ref[...].astype(jnp.float32)
    t = _teacher_tile(t_ref)
    pad = _pad_mask(vi, bv, v_total, s.shape)
    p_s = jnp.where(pad, 0.0, jnp.exp(s - lse_s_ref[...][:, None]))
    p_t = jnp.where(pad, 0.0, jnp.exp(t - lse_t_ref[...][:, None]))
    g = g_ref[0]
    ds_ref[...] = ((p_s - p_t) * (g / b_total)).astype(ds_ref.dtype)


# ---------------------------------------------------------------------------
# fused bank kernels: gather-by-index + dequantize + log-softmax + KL
# ---------------------------------------------------------------------------
#
# Grid (B, n_v) with row blocks of 1: the sampled index vector rides in as
# a SCALAR-PREFETCH operand, so the bank's BlockSpec index map
# ``lambda i, j, idx_ref: (idx_ref[i], j)`` DMAs exactly the sampled bank
# row for grid row i — the gathered [B, V] teacher tensor (let alone its
# dequantized fp32 copy) never exists in HBM.  Quantized rows dequantize
# in-register: ``t = t_tile * (scale_row / T)``; fp32/bf16 banks pass
# scale 1.  The student's 1/T fold also happens in-tile (temperature is a
# static nondiff arg), so there is no [B, V] pre-scaling pass either.

def _bank_fwd_kernel(idx_ref, s_ref, t_ref, sc_ref,
                     kl_ref, lse_t_ref, lse_s_ref,
                     m_t, z_t, st_acc, ss_acc, m_s, z_s, *,
                     n_v_tiles: int, v_total: int, bv: int, inv_t: float):
    del idx_ref  # consumed by the BlockSpec index maps
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        _init_row_stats(m_t, z_t, st_acc, ss_acc, m_s, z_s)

    s = s_ref[...].astype(jnp.float32) * inv_t          # [1, bV]
    t = t_ref[...].astype(jnp.float32) * (sc_ref[0] * inv_t)

    pad = _pad_mask(vi, bv, v_total, s.shape)
    s = jnp.where(pad, NEG, s)
    t = jnp.where(pad, NEG, t)
    _online_step(s, t, pad, m_t, z_t, st_acc, ss_acc, m_s, z_s)

    @pl.when(vi == n_v_tiles - 1)
    def _finish():
        _emit_row_stats(kl_ref, lse_t_ref, lse_s_ref,
                        m_t, z_t, st_acc, ss_acc, m_s, z_s)


def _bank_bwd_kernel(idx_ref, s_ref, t_ref, sc_ref, lse_t_ref, lse_s_ref,
                     g_ref, ds_ref, *, v_total: int, bv: int, b_total: int,
                     inv_t: float):
    del idx_ref
    vi = pl.program_id(1)
    s = s_ref[...].astype(jnp.float32) * inv_t
    t = t_ref[...].astype(jnp.float32) * (sc_ref[0] * inv_t)
    pad = _pad_mask(vi, bv, v_total, s.shape)
    p_s = jnp.where(pad, 0.0, jnp.exp(s - lse_s_ref[...][:, None]))
    p_t = jnp.where(pad, 0.0, jnp.exp(t - lse_t_ref[...][:, None]))
    g = g_ref[0]
    ds_ref[...] = ((p_s - p_t) * (g / b_total)).astype(ds_ref.dtype)


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def ensemble_kl(student_logits, teacher_logits, temperature: float = 1.0,
                block_b: int = 8, interpret: bool = True):
    loss, _ = _fwd(student_logits, teacher_logits, temperature, block_b,
                   interpret)
    return loss


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def ensemble_kl_pre(student_logits, teacher_avg_logits,
                    temperature: float = 1.0, block_b: int = 8,
                    interpret: bool = True):
    """AVGLOGITS loss against pre-averaged teacher rows [B, V] (logit-bank
    fast path); numerically identical to :func:`ensemble_kl` fed the
    un-averaged [K, B, V] teachers whose mean these rows are."""
    loss, _ = _fwd(student_logits, teacher_avg_logits, temperature, block_b,
                   interpret)
    return loss


def _block_v(v: int) -> int:
    # V tile: multiple of 128 lanes, bounded by VMEM budget
    return min(2048, max(128, 128 * ((v + 127) // 128)))


def _pad_teacher(t, bb, bv):
    """Pad [B, V] (pre-averaged) or [K, B, V] teachers + their BlockSpec."""
    if t.ndim == 2:
        return (_pad_to(_pad_to(t, bb, 0), bv, 1),
                pl.BlockSpec((bb, bv), lambda i, j: (i, j)))
    k = t.shape[0]
    return (_pad_to(_pad_to(t, bb, 1), bv, 2),
            pl.BlockSpec((k, bb, bv), lambda i, j: (0, i, j)))


def _fwd(student_logits, teacher_logits, temperature, block_b, interpret):
    b, v = student_logits.shape
    s = student_logits / temperature
    t = teacher_logits / temperature

    bv = _block_v(v)
    bb = min(block_b, b)
    s_p = _pad_to(_pad_to(s, bb, 0), bv, 1)
    t_p, t_spec = _pad_teacher(t, bb, bv)
    bp, vp = s_p.shape
    n_b, n_v = bp // bb, vp // bv

    kern = functools.partial(_fwd_kernel, n_v_tiles=n_v, v_total=v, bv=bv)
    out_shape = [jax.ShapeDtypeStruct((bp,), jnp.float32)] * 3
    kl, lse_t, lse_s = pl.pallas_call(
        kern,
        grid=(n_b, n_v),
        in_specs=[
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
            t_spec,
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        scratch_shapes=[pltpu.VMEM((bb, 1), jnp.float32)] * 6,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(s_p, t_p)
    loss = jnp.sum(kl[:b]) / b * temperature ** 2
    return loss, (student_logits, teacher_logits, lse_t, lse_s)


def _fwd_rule(student_logits, teacher_logits, temperature, block_b,
              interpret):
    return _fwd(student_logits, teacher_logits, temperature, block_b,
                interpret)


def _bwd_rule(temperature, block_b, interpret, res, g):
    student_logits, teacher_logits, lse_t, lse_s = res
    b, v = student_logits.shape
    s = student_logits / temperature
    t = teacher_logits / temperature

    bv = _block_v(v)
    bb = min(block_b, b)
    s_p = _pad_to(_pad_to(s, bb, 0), bv, 1)
    t_p, t_spec = _pad_teacher(t, bb, bv)
    bp, vp = s_p.shape
    n_b, n_v = bp // bb, vp // bv

    kern = functools.partial(_bwd_kernel, v_total=v, bv=bv, b_total=b)
    g_arr = jnp.asarray([g * temperature], jnp.float32)  # T^2 / T = T
    ds = pl.pallas_call(
        kern,
        grid=(n_b, n_v),
        in_specs=[
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
            t_spec,
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, vp), student_logits.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(s_p, t_p, lse_t, lse_s, g_arr)
    return ds[:b, :v], None


ensemble_kl.defvjp(_fwd_rule, _bwd_rule)
ensemble_kl_pre.defvjp(_fwd_rule, _bwd_rule)


def _zero_cotangent(x):
    """Cotangent for a non-differentiated primal: symbolic float0 zeros
    for integer args (idx, int8 bank rows), same-dtype zeros for inexact
    ones (DCE'd under jit — nothing consumes them)."""
    if jnp.issubdtype(x.dtype, jnp.inexact):
        return jnp.zeros(x.shape, x.dtype)
    return np.zeros(x.shape, jax_dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ensemble_kl_bank(student_logits, bank_rows, row_scale, idx,
                     temperature: float = 1.0, interpret: bool = True):
    """AVGLOGITS loss straight off a resident logit bank.

    student_logits: [B, V] (differentiable); bank_rows: [N, V] in any
    bank storage dtype (fp32 / bf16 / int8 / fp8); row_scale: [B] fp32
    dequant scale PER SAMPLED ROW (``scales[idx]``, or ones for
    unquantized banks); idx: [B] int row indices into the bank.
    Equals ``ensemble_kl_pre(student, dequant(bank_rows[idx]))`` without
    ever materializing the gathered or dequantized [B, V] rows.
    """
    loss, _ = _bank_fwd(student_logits, bank_rows, row_scale, idx,
                        temperature, interpret)
    return loss


def _bank_specs(b: int, n_v: int, bv: int):
    """(grid, in_specs) shared by the bank fwd/bwd: student row blocks by
    grid row, bank row blocks by the PREFETCHED sampled index."""
    grid = (b, n_v)
    in_specs = [
        pl.BlockSpec((1, bv), lambda i, j, idx_ref: (i, j)),
        pl.BlockSpec((1, bv), lambda i, j, idx_ref: (idx_ref[i], j)),
        pl.BlockSpec((1,), lambda i, j, idx_ref: (i,)),
    ]
    return grid, in_specs


def _bank_fwd(student_logits, bank_rows, row_scale, idx, temperature,
              interpret):
    b, v = student_logits.shape
    bv = _block_v(v)
    n_v = -(-v // bv)

    grid, in_specs = _bank_specs(b, n_v, bv)
    kern = functools.partial(_bank_fwd_kernel, n_v_tiles=n_v, v_total=v,
                             bv=bv, inv_t=1.0 / temperature)
    row_spec = pl.BlockSpec((1,), lambda i, j, idx_ref: (i,))
    kl, lse_t, lse_s = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=[row_spec, row_spec, row_spec],
            scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)] * 6,
        ),
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 3,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(idx.astype(jnp.int32), student_logits, bank_rows,
      row_scale.astype(jnp.float32))
    loss = jnp.sum(kl) / b * temperature ** 2
    return loss, (student_logits, bank_rows, row_scale, idx, lse_t, lse_s)


def _bank_fwd_rule(student_logits, bank_rows, row_scale, idx, temperature,
                   interpret):
    return _bank_fwd(student_logits, bank_rows, row_scale, idx,
                     temperature, interpret)


def _bank_bwd_rule(temperature, interpret, res, g):
    student_logits, bank_rows, row_scale, idx, lse_t, lse_s = res
    b, v = student_logits.shape
    bv = _block_v(v)
    n_v = -(-v // bv)

    grid, in_specs = _bank_specs(b, n_v, bv)
    row_spec = pl.BlockSpec((1,), lambda i, j, idx_ref: (i,))
    in_specs = in_specs + [row_spec, row_spec,
                           pl.BlockSpec(memory_space=pltpu.SMEM)]
    kern = functools.partial(_bank_bwd_kernel, v_total=v, bv=bv, b_total=b,
                             inv_t=1.0 / temperature)
    g_arr = jnp.asarray([g * temperature], jnp.float32)  # T^2 / T = T
    ds = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bv), lambda i, j, idx_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_v * bv), student_logits.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(idx.astype(jnp.int32), student_logits, bank_rows,
      row_scale.astype(jnp.float32), lse_t, lse_s, g_arr)
    return (ds[:, :v], _zero_cotangent(bank_rows),
            _zero_cotangent(row_scale), _zero_cotangent(idx))


ensemble_kl_bank.defvjp(_bank_fwd_rule, _bank_bwd_rule)

"""Round drivers: schedulers over the RoundEngine phases
(``core/engine.py``), selected by ``DriverSpec(kind=...)`` or
``run_rounds(driver=...)``.  See docs/drivers.md.

    sync            serial reference loop (bit-identical to the historic
                    ``run_rounds``)
    async_pipelined up to S rounds of client training overlapped with the
                    oldest round's fusion (bounded staleness ring;
                    0 == sync semantics, 1 == the historic one-round
                    overlap)
    buffered_async  FedBuff-style: traffic-driven waves over a registered
                    population, aggregate every M buffered uploads with
                    FedAsync (1+s)^-a importance (``repro.population``)
    multihost       sync semantics, client axis sharded over a host mesh;
                    plus ``drive_fed_rounds`` for the production
                    ``make_fed_round_step`` loop
    distributed     fusion pod + client pods behind the versioned wire
                    protocol (``repro.dist``; loopback or tcp transport,
                    heartbeats/deadlines/quorum — docs/distributed.md)
"""
from repro.drivers.base import (Driver, available_drivers, get_driver,
                                make_driver, register_driver,
                                resolve_driver, unwrap_state, wrap_state)
from repro.drivers.sync import SyncDriver
from repro.drivers.async_pipelined import AsyncPipelinedDriver
from repro.drivers.buffered_async import BufferedAsyncDriver
from repro.drivers.multihost import MultiHostDriver, drive_fed_rounds
from repro.dist.driver import DistributedDriver

__all__ = [
    "Driver", "SyncDriver", "AsyncPipelinedDriver", "BufferedAsyncDriver",
    "MultiHostDriver", "DistributedDriver",
    "register_driver", "get_driver", "make_driver", "available_drivers",
    "resolve_driver", "wrap_state", "unwrap_state", "drive_fed_rounds",
]

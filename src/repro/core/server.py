"""Federated server loop — Algorithm 1 (homogeneous) / Algorithm 3
(heterogeneous prototypes), with pluggable aggregation strategies:

  fedavg   — weighted parameter average (McMahan et al.)
  fedprox  — fedavg aggregation + proximal local objective (Li et al.)
  fedavgm  — server momentum:  v = beta v + dx;  x = x - v  (Hsu et al.,
             exactly the update scheme in Appendix C.2)
  feddf    — fedavg init + server-side ensemble distillation (this paper)

The loop tracks per-round test accuracy and rounds-to-target (Table 1's
metric).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.common.pytree import (tree_scale, tree_stack, tree_sub,
                                 tree_weighted_mean, tree_zeros_like, tree_add)
from repro.core import feddf as feddf_mod
from repro.core.client import build_batches, evaluate, make_local_update
from repro.core.dropworst import drop_worst
from repro.core.nets import Net
from repro.data.distill_sources import DistillSource
from repro.data.synthetic import Dataset
from repro.optim.optimizers import Optimizer, sgd


@dataclasses.dataclass
class FLConfig:
    rounds: int = 20
    client_fraction: float = 0.4  # C
    local_epochs: int = 20        # E
    local_batch_size: int = 32
    local_lr: float = 0.1
    strategy: str = "fedavg"      # fedavg | fedprox | fedavgm | feddf
    prox_mu: float = 0.01
    server_momentum: float = 0.3  # beta for fedavgm
    drop_worst: bool = False
    seed: int = 0
    local_optimizer: str = "sgd"  # sgd | adam (Table 6 ablation)
    quantize: Optional[Callable] = None
    fusion: feddf_mod.FusionConfig = dataclasses.field(
        default_factory=feddf_mod.FusionConfig)
    feddf_init_from: str = "average"  # Table 5 ablation: average | previous
    target_accuracy: Optional[float] = None  # stop early when reached
    # client-level DP on uploads (paper §3 privacy extension; core/privacy.py)
    dp_clip: Optional[float] = None
    dp_noise_multiplier: float = 0.0


@dataclasses.dataclass
class RoundLog:
    round: int
    test_acc: float
    val_acc: float
    ensemble_acc: Optional[float] = None
    pre_distill_acc: Optional[float] = None
    distill_steps: int = 0
    n_participants: int = 0
    n_dropped: int = 0


@dataclasses.dataclass
class FLResult:
    logs: List[RoundLog]
    global_params: dict
    rounds_to_target: Optional[int] = None

    @property
    def final_acc(self) -> float:
        return self.logs[-1].test_acc if self.logs else 0.0

    @property
    def best_acc(self) -> float:
        return max(l.test_acc for l in self.logs) if self.logs else 0.0


def _make_opt(cfg: FLConfig) -> Optimizer:
    if cfg.local_optimizer == "adam":
        from repro.optim.optimizers import adam
        return adam(1e-3)
    return sgd(cfg.local_lr)


def run_federated(
    net: Net,
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    source: Optional[DistillSource] = None,
    log_fn: Optional[Callable[[RoundLog], None]] = None,
) -> FLResult:
    """Homogeneous FL (Algorithm 1)."""
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    global_params = net.init(key)
    n_clients = len(parts)
    n_active = max(1, int(round(cfg.client_fraction * n_clients)))

    prox = cfg.prox_mu if cfg.strategy == "fedprox" else 0.0
    local_update = make_local_update(net, _make_opt(cfg), prox_mu=prox,
                                     quantize=cfg.quantize)
    momentum_buf = None
    logs: List[RoundLog] = []
    rounds_to_target = None

    for t in range(1, cfg.rounds + 1):
        active = rng.choice(n_clients, size=n_active, replace=False)
        client_params, weights = [], []
        for k in active:
            idx = parts[k]
            xb, yb = build_batches(train.x[idx], train.y[idx],
                                   cfg.local_batch_size, cfg.local_epochs,
                                   seed=cfg.seed * 100_003 + t * 131 + int(k))
            p = local_update(global_params, jax.numpy.asarray(xb),
                             jax.numpy.asarray(yb), global_params)
            if cfg.dp_clip is not None:
                from repro.core.privacy import privatize_update
                p = privatize_update(
                    global_params, p, clip=cfg.dp_clip,
                    noise_multiplier=cfg.dp_noise_multiplier,
                    key=jax.random.PRNGKey(cfg.seed * 7919 + t * 131
                                           + int(k)))
            client_params.append(p)
            weights.append(float(len(idx)))

        n_dropped = 0
        if cfg.drop_worst:
            kept_p, kept_w, kept_i = drop_worst(
                net, client_params, weights, val.x, val.y, train.n_classes)
            n_dropped = len(client_params) - len(kept_p)
            client_params, weights = kept_p, kept_w

        avg = tree_weighted_mean(client_params, weights)
        pre_acc = None
        distill_steps = 0

        if cfg.strategy in ("fedavg", "fedprox"):
            new_global = avg
        elif cfg.strategy == "fedavgm":
            # dv = beta v + dx ; x = x - dv   (dx = x_old - avg)
            dx = tree_sub(global_params, avg)
            if momentum_buf is None:
                momentum_buf = tree_zeros_like(dx)
            momentum_buf = tree_add(tree_scale(momentum_buf,
                                               cfg.server_momentum), dx)
            new_global = tree_sub(global_params, momentum_buf)
        elif cfg.strategy == "feddf":
            assert source is not None, "FedDF needs a distillation source"
            pre_acc = evaluate(net, avg, test.x, test.y)
            new_global, info = feddf_mod.feddf_fuse_homogeneous(
                net, client_params, weights, source, cfg.fusion,
                val.x, val.y, seed=cfg.seed + t,
                init_from=cfg.feddf_init_from, prev_global=global_params)
            distill_steps = info["steps"]
        else:
            raise ValueError(cfg.strategy)

        global_params = new_global
        test_acc = evaluate(net, global_params, test.x, test.y,
                            quantize=cfg.quantize)
        val_acc = evaluate(net, global_params, val.x, val.y,
                           quantize=cfg.quantize)
        log = RoundLog(round=t, test_acc=test_acc, val_acc=val_acc,
                       pre_distill_acc=pre_acc, distill_steps=distill_steps,
                       n_participants=len(client_params), n_dropped=n_dropped)
        logs.append(log)
        if log_fn:
            log_fn(log)
        if (cfg.target_accuracy is not None and rounds_to_target is None
                and test_acc >= cfg.target_accuracy):
            rounds_to_target = t
            break

    return FLResult(logs=logs, global_params=global_params,
                    rounds_to_target=rounds_to_target)


def run_federated_heterogeneous(
    nets: List[Net],                      # one per prototype group
    client_proto: Sequence[int],          # client k -> prototype index
    train: Dataset,
    parts: Sequence[np.ndarray],
    val: Dataset,
    test: Dataset,
    cfg: FLConfig,
    source: Optional[DistillSource] = None,
    log_fn=None,
) -> Tuple[List[FLResult], List[dict]]:
    """Heterogeneous FL (Algorithm 3).  strategy='fedavg' averages within
    each prototype group only (paper Fig. 4 dashed lines); 'feddf' fuses each
    group against the all-groups ensemble."""
    rng = np.random.default_rng(cfg.seed)
    n_clients = len(parts)
    n_active = max(1, int(round(cfg.client_fraction * n_clients)))
    n_proto = len(nets)

    globals_: List[dict] = [
        nets[p].init(jax.random.PRNGKey(cfg.seed + p)) for p in range(n_proto)]
    local_updates = [make_local_update(nets[p], _make_opt(cfg))
                     for p in range(n_proto)]
    logs: List[List[RoundLog]] = [[] for _ in range(n_proto)]
    ens_hist: List[float] = []

    for t in range(1, cfg.rounds + 1):
        active = rng.choice(n_clients, size=n_active, replace=False)
        received: List[List[dict]] = [[] for _ in range(n_proto)]
        weights: List[List[float]] = [[] for _ in range(n_proto)]
        for k in active:
            p_id = client_proto[k]
            idx = parts[k]
            xb, yb = build_batches(train.x[idx], train.y[idx],
                                   cfg.local_batch_size, cfg.local_epochs,
                                   seed=cfg.seed * 99991 + t * 131 + int(k))
            p = local_updates[p_id](globals_[p_id], jax.numpy.asarray(xb),
                                    jax.numpy.asarray(yb), globals_[p_id])
            received[p_id].append(p)
            weights[p_id].append(float(len(idx)))

        from repro.core.ensemble import ensemble_accuracy
        ens_acc = ensemble_accuracy(
            [(nets[g], received[g]) for g in range(n_proto) if received[g]],
            test.x, test.y)
        ens_hist.append(ens_acc)

        if cfg.strategy == "feddf":
            protos = [(nets[g], received[g], weights[g])
                      for g in range(n_proto)]
            fused, _ = feddf_mod.feddf_fuse_heterogeneous(
                protos, source, cfg.fusion, val.x, val.y, seed=cfg.seed + t)
            for g in range(n_proto):
                if fused[g] is not None:
                    globals_[g] = fused[g]
        else:  # group-wise fedavg
            for g in range(n_proto):
                if received[g]:
                    globals_[g] = tree_weighted_mean(received[g], weights[g])

        for g in range(n_proto):
            acc = evaluate(nets[g], globals_[g], test.x, test.y)
            vacc = evaluate(nets[g], globals_[g], val.x, val.y)
            log = RoundLog(round=t, test_acc=acc, val_acc=vacc,
                           ensemble_acc=ens_acc,
                           n_participants=len(received[g]))
            logs[g].append(log)
            if log_fn:
                log_fn((g, log))

    results = [FLResult(logs=logs[g], global_params=globals_[g])
               for g in range(n_proto)]
    return results, globals_

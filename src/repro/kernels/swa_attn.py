"""Pallas TPU kernel: flash-style causal attention with sliding window.

Used by gemma3's local layers (5 of every 6).  The win over plain flash
attention is structural: for a window ``w`` and query block ``bq``, each
query block only visits ``ceil(w/bk)+1`` KV blocks instead of all preceding
ones — O(S*w) instead of O(S^2) compute *and* HBM reads.

Grid: (B*H, S/bq, n_kv_blocks) with the KV dimension innermost; the KV
block index is *relative*: absolute kv block = q_block - n_rel + 1 + j,
clamped to 0 by the index_map and exactly masked inside the kernel (an
out-of-range relative block contributes nothing, so clamp-duplicates are
killed by the mask on intended-vs-actual block id).

Online softmax accumulators (m, l, o) persist in VMEM scratch across the KV
iterations of one query block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_acc, l_acc, o_acc, *,
                bq: int, bk: int, n_rel: int, window: int | None,
                s_total: int, scale: float):
    qi = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, NEG)
        l_acc[...] = jnp.zeros_like(l_acc)
        o_acc[...] = jnp.zeros_like(o_acc)

    intended = qi + j - (n_rel - 1)  # relative -> absolute kv block
    q = q_ref[0].astype(jnp.float32) * scale   # [bq, d]
    k = k_ref[0].astype(jnp.float32)           # [bk, d]
    v = v_ref[0].astype(jnp.float32)           # [bk, d]

    s = q @ k.T  # [bq, bk]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = intended * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos >= 0) & (intended >= 0)
    mask &= (q_pos < s_total) & (k_pos < s_total)
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG)

    m_new = jnp.maximum(m_acc[...], jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_acc[...] - m_new)
    l_acc[...] = l_acc[...] * alpha + jnp.sum(p, -1, keepdims=True)
    o_acc[...] = o_acc[...] * alpha + p @ v
    m_acc[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (o_acc[...] / jnp.maximum(l_acc[...], 1e-30)).astype(
            o_ref.dtype)


def swa_attn_pallas(q, k, v, window: int | None, *, block: int = 128,
                    interpret: bool = True):
    """q/k/v: [B, H, S, D] -> [B, H, S, D]; causal (+ window if not None).

    Q and KV share one block size so the relative-block arithmetic in the
    kernel is exact."""
    b, h, s, d = q.shape
    bq = bk = min(block, max(8, s))
    pad_s = (-s) % bq
    if pad_s:
        pad = ((0, 0), (0, 0), (0, pad_s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    sp = s + pad_s
    qf = q.reshape(b * h, sp, d)
    kf = k.reshape(b * h, sp, d)
    vf = v.reshape(b * h, sp, d)

    if window is None:
        n_rel = sp // bk  # all preceding blocks (full causal)
    else:
        n_rel = min(sp // bk, math.ceil(window / bk) + 1)

    kern = functools.partial(
        _swa_kernel, bq=bq, bk=bk, n_rel=n_rel, window=window, s_total=s,
        scale=1.0 / math.sqrt(d))

    def kv_index(bi, qi, j):
        return (bi, _clamp(qi + j - (n_rel - 1), sp // bk), 0)

    out = pl.pallas_call(
        kern,
        grid=(b * h, sp // bq, n_rel),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bi, qi, j: (bi, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bi, qi, j: (bi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sp, d)[:, :, :s]


def _clamp(x, n_blocks):
    return jnp.clip(x, 0, n_blocks - 1)

"""Shared timing/marginal-measure helpers for the engine benchmarks.

``driver_bench`` and ``round_engine_bench`` historically carried two
divergent copies of the same two idioms; they live here now:

* :func:`time_rounds` — steady-state per-call wall clock: one warm-up
  call absorbs the jit compile, then the mean over ``rounds`` repeats.
* :func:`min_wall` / :func:`marginal_rate` — the distill_bench idiom for
  whole-run measurements: wall-clock a SHORT and a LONG run of the same
  config (min over ``reps`` each, so a GC pause or noisy neighbour can't
  corrupt one side) and report the marginal units/second between them —
  the identical per-run compile cost appears in both lengths and cancels
  in the difference, leaving the steady-state throughput.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple


def time_rounds(fn: Callable[[], None], rounds: int) -> float:
    """Mean seconds per ``fn()`` call over ``rounds`` calls, after one
    un-timed warm-up call (the compile)."""
    fn()  # warm-up: compile
    t0 = time.time()
    for _ in range(rounds):
        fn()
    return (time.time() - t0) / rounds


def min_wall(fn: Callable[[], object], reps: int = 2
             ) -> Tuple[float, object]:
    """``(best wall seconds, result of the best rep)`` over ``reps`` runs."""
    best, result = None, None
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        wall = time.time() - t0
        if best is None or wall < best:
            best, result = wall, out
    return best, result


def marginal_rate(make_run: Callable[[int], object], n_short: int,
                  n_long: int, reps: int = 2) -> Tuple[Dict, object]:
    """Marginal units/second between a short and a long run.

    ``make_run(n)`` executes a fresh ``n``-unit run (fresh engine, fresh
    jits) and returns its result.  Returns ``(stats, long-run result)``
    where stats carries ``wall_short_s`` / ``wall_long_s`` / ``per_s``.
    """
    t_s, _ = min_wall(lambda: make_run(n_short), reps)
    t_l, result = min_wall(lambda: make_run(n_long), reps)
    return {"wall_short_s": t_s, "wall_long_s": t_l,
            "per_s": (n_long - n_short) / max(t_l - t_s, 1e-3)}, result

"""Async-pipelined round driver: overlap round t's server-side fusion
with round t+1's client training.

FedDF's per-round cost is dominated by two phases with no mutual data
dependency once the teacher snapshot is taken: the batched client
training of the NEXT round and the ensemble-distillation fusion of the
CURRENT one.  This driver runs fusion on a worker thread while the main
thread builds and dispatches the next round's client training — jax
dispatch is asynchronous and never calls ``block_until_ready``, and the
engine's donated batch buffers are rebuilt per round, so the two
computations interleave on the backend.

Staleness semantics (``staleness`` knob, bounded <= 1):

  staleness=0  sync semantics, bit-identical: round t+1's training waits
               for round t's fused globals.  Only the HOST-side batch
               building (a pure function of (round, cohort)) is
               prefetched ``prefetch`` rounds ahead on the worker.
  staleness=1  round t+1's clients initialise from the newest COMPLETED
               fusion — at most one round staler than sync — while round
               t's fusion runs concurrently.  The trajectory drifts from
               sync (gated <= 0.5pt on the toy config in CI) but each
               round's aggregation still consumes every upload.

Checkpoint/resume: ``round_end_hook`` fires in round order.  Under
staleness=1 the hook's ``state`` is wrapped with the stale base the
in-flight round trained from, so ``Experiment.resume`` re-trains the
interrupted round from the SAME base an uninterrupted pipeline used —
trajectory equality is pinned in ``tests/test_drivers.py``.  In-flight
work past the last completed hook is discarded on kill and recomputed on
resume.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

from repro.core.engine import _UNSET, RoundEngine
from repro.drivers.base import Driver, register_driver, wrap_state


@register_driver("async_pipelined")
class AsyncPipelinedDriver(Driver):
    def run(self, engine: RoundEngine, *, log_fn=None, init_globals=None,
            init_state=_UNSET, start_round=1, init_logs=None,
            round_end_hook=None):
        globals_, state, logs, rng = self._setup(
            engine, init_globals, init_state, init_logs, start_round)
        prev_base = self._resume_prev_base
        if self.staleness == 0:
            prev_base = None  # sync semantics never train from a stale base
        rounds = engine.cfg.rounds
        rounds_to_target = None
        stopped = False

        # fusion gets a DEDICATED worker: sharing a pool with the batch
        # prefetcher could queue an aggregate behind host batch building
        # — exactly the phase the pipeline exists to keep busy
        agg_ex = ThreadPoolExecutor(max_workers=1)
        batch_ex = ThreadPoolExecutor(max_workers=1)
        batch_futs: Dict[int, object] = {}
        next_draw = start_round

        def prefetch_to(limit: int) -> None:
            # cohort draws stay on the driver thread IN ROUND ORDER (the
            # rng sequence is the resume contract); only the pure host
            # batch building goes to the worker
            nonlocal next_draw
            while next_draw <= min(limit, rounds):
                t_, next_draw = next_draw, next_draw + 1
                active = engine.sample_cohort(rng)
                batch_futs[t_] = batch_ex.submit(engine.build_round_batches,
                                                 t_, active)

        def aggregate_task(t, groups, st):
            out = engine.aggregate(t, groups, st)
            return (groups,) + out

        agg_fut = None
        agg_round: Optional[int] = None
        try:
            for t in range(start_round, rounds + 1):
                prefetch_to(t + self.prefetch)
                batches = batch_futs.pop(t).result()

                if self.staleness == 0 and agg_fut is not None:
                    # sync semantics: fused globals gate the next training
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, agg_fut, agg_round, logs, log_fn,
                        round_end_hook, train_base=None)
                    agg_fut = None
                    if rounds_to_target is not None or stop:
                        stopped = True
                        break

                base = prev_base if prev_base is not None else globals_
                prev_base = None
                groups = engine.train_clients(t, base, batches)

                if agg_fut is not None:  # staleness=1: join AFTER training
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, agg_fut, agg_round, logs, log_fn,
                        round_end_hook, train_base=base)
                    agg_fut = None
                    if rounds_to_target is not None or stop:
                        stopped = True  # round t's trained groups discarded
                        break

                agg_fut = agg_ex.submit(aggregate_task, t, groups, state)
                agg_round = t

            if agg_fut is not None and not stopped:
                globals_, state, rounds_to_target, _ = self._finish(
                    engine, agg_fut, agg_round, logs, log_fn,
                    round_end_hook, train_base=None)
        finally:
            batch_ex.shutdown(wait=True, cancel_futures=True)
            agg_ex.shutdown(wait=True, cancel_futures=True)

        return self._results(engine, logs, globals_, rounds_to_target)

    def _finish(self, engine, agg_fut, t, logs, log_fn, round_end_hook,
                train_base):
        """Join round t's in-flight aggregation, then evaluate / log /
        checkpoint it.  ``train_base`` is the globals round t+1's training
        (already dispatched under staleness=1) initialised from — wrapped
        into the checkpoint state so a resumed pipeline re-trains t+1 from
        the same base."""
        groups, globals_, state, infos, dropped, ens_acc = agg_fut.result()
        round_logs = engine.evaluate_round(t, globals_, groups, infos,
                                           dropped, ens_acc)
        reached, stop_requested = self._emit_round(engine, t, round_logs,
                                                   logs, log_fn)
        rounds_to_target = t if reached else None
        if round_end_hook is not None:
            hook_state = state
            if self.staleness > 0:
                hook_state = wrap_state(
                    state, train_base if train_base is not None else globals_)
            round_end_hook(t, globals_, hook_state, logs, rounds_to_target)
        return globals_, state, rounds_to_target, stop_requested

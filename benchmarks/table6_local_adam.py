"""Table 6 (Appendix C.4.1): impact of the LOCAL optimizer (SGD vs Adam).

Paper finding: Adam for local training can help FL at mild heterogeneity
(alpha=1) but its benefit vanishes at alpha=0.1, while FedDF's gain over
FedAvg is robust to the local-training scheme — the benefit is orthogonal
to local optimization quality.
"""
from __future__ import annotations

import time

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(4, 10)
    t0 = time.time()
    results = {}
    for alpha in (1.0, 0.1):
        train, val, test, parts, src = default_problem(seed=seed, alpha=alpha)
        for local_opt in ("sgd", "adam"):
            for strat, source in (("fedavg", None), ("feddf", src)):
                cfg = fl_cfg(strat, rounds, seed=seed,
                             local_optimizer=local_opt)
                net = mlp(2, 3, hidden=(64, 64))
                res = run_federated(net, train, parts, val, test, cfg,
                                    source=source)
                results[f"alpha={alpha}/{local_opt}/{strat}"] = {
                    "best_acc": res.best_acc, "final_acc": res.final_acc}
    dt = time.time() - t0

    def best(k):
        return results[k]["best_acc"]

    claims = {
        # FedDF >= FedAvg under BOTH local optimizers at high heterogeneity
        "feddf_robust_to_local_opt_noniid": (
            best("alpha=0.1/sgd/feddf") >= best("alpha=0.1/sgd/fedavg") - 0.01
            and best("alpha=0.1/adam/feddf")
            >= best("alpha=0.1/adam/fedavg") - 0.01),
        # local Adam is not a substitute for better fusion at alpha=0.1
        # (paper: "the benefit vanishes with higher data heterogeneity")
        "feddf_sgd_beats_fedavg_adam_noniid": (
            best("alpha=0.1/sgd/feddf")
            >= best("alpha=0.1/adam/fedavg") - 0.01),
    }
    emit("table6_local_adam", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

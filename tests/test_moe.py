"""MoE block: routing invariants, gather-vs-capacity consistency, expert
parallelism via shard_map (subprocess with 8 host devices so the main test
process keeps jax on 1 device)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.common.arch_config import reduced
from repro.models import moe as moe_mod

import dataclasses


def _cfg(capacity=8.0):
    base = reduced(configs.get("granite-moe-1b-a400m"))
    return dataclasses.replace(base, capacity_factor=capacity)


def _params(cfg, key):
    from repro.models.layers import init_params
    return init_params(moe_mod.moe_specs(cfg), key)


def test_router_topk_and_aux():
    cfg = _cfg()
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    w, idx, aux = moe_mod._route(p, cfg, x)
    assert w.shape == (32, cfg.top_k) and idx.shape == (32, cfg.top_k)
    assert jnp.allclose(jnp.sum(w, -1), 1.0, atol=1e-5)  # renormalised
    assert bool(jnp.all(idx >= 0)) and bool(jnp.all(idx < cfg.n_experts))
    assert float(aux) >= 0.99  # aux >= 1 at optimum (E * sum f*p / k)


def test_gather_equals_capacity_when_dropfree():
    """The tiny-T decode path and the capacity path compute the same math."""
    cfg = _cfg(capacity=64.0)  # drop-free
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.d_model))
    w, idx, _ = moe_mod._route(p, cfg, x)
    out_cap = moe_mod._moe_capacity(p, cfg, x, w, idx, 0, cfg.n_experts)
    out_gat = moe_mod._moe_gather(p, cfg, x, w, idx)
    assert jnp.allclose(out_cap, out_gat, rtol=1e-4, atol=1e-5)


def test_capacity_partition_over_expert_slices():
    """Computing expert slices separately and summing == full pass
    (the shard_map psum decomposition, checked without a mesh)."""
    cfg = _cfg(capacity=64.0)
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    w, idx, _ = moe_mod._route(p, cfg, x)
    full = moe_mod._moe_capacity(p, cfg, x, w, idx, 0, cfg.n_experts)
    e_half = cfg.n_experts // 2

    def slice_params(lo, hi):
        return {"router": p["router"],
                "wi_gate": p["wi_gate"][lo:hi], "wi_up": p["wi_up"][lo:hi],
                "wo": p["wo"][lo:hi]}

    lo_half = moe_mod._moe_capacity(slice_params(0, e_half), cfg, x, w, idx,
                                    0, e_half)
    hi_half = moe_mod._moe_capacity(slice_params(e_half, cfg.n_experts), cfg,
                                    x, w, idx, e_half, e_half)
    assert jnp.allclose(lo_half + hi_half, full, rtol=1e-4, atol=1e-5)


def test_capacity_drops_overflow():
    cfg = _cfg(capacity=0.25)  # force drops
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    w, idx, _ = moe_mod._route(p, cfg, x)
    out = moe_mod._moe_capacity(p, cfg, x, w, idx, 0, cfg.n_experts)
    # some tokens must have been dropped -> zero output rows exist
    norms = jnp.linalg.norm(out, axis=-1)
    assert float(jnp.min(norms)) < 1e-6
    assert bool(jnp.all(jnp.isfinite(out)))


SHARD_MAP_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from repro import configs
from repro.common.arch_config import reduced
from repro.models import moe as moe_mod
from repro.models.layers import init_params

cfg = dataclasses.replace(reduced(configs.get("granite-moe-1b-a400m")),
                          capacity_factor=64.0)
p = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
mesh = jax.make_mesh((2, 4), ("data", "model"))

local, aux_l = moe_mod.moe_block(p, cfg, x, mesh=None)
dist, aux_d = moe_mod.moe_block(p, cfg, x, mesh=mesh, dp_axes=("data",))
err = float(jnp.max(jnp.abs(local - dist)))
aux_err = abs(float(aux_l - aux_d))
assert err < 1e-4, f"shard_map mismatch: {err}"
# the load-balance aux is computed per data shard then averaged (standard
# Switch practice) -> small difference vs the global-batch aux
assert aux_err < 0.1, f"aux mismatch: {aux_err}"
print("SHARD_MAP_OK", err)
"""


def test_shard_map_expert_parallel_matches_local():
    res = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SNIPPET], capture_output=True,
        text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(
            __file__)))
    assert "SHARD_MAP_OK" in res.stdout, res.stdout + res.stderr

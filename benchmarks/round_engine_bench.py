"""Round-engine microbenchmarks.

Case ``engine`` (ISSUE 1 acceptance): per-round client training
wall-clock, sequential python-loop (`make_local_update` per client) vs
the vectorized engine path (`make_batched_local_update`, one jitted
vmap-over-clients scan).  Equal-size partitions, so neither path pays
padding; both are warmed up before timing so the numbers compare
steady-state rounds, not compiles.  Emits
``round_engine_K{K},us_per_round,speedup`` per client count.

Case ``bucketing`` (ISSUE 5 acceptance): the heterogeneous skewed-cohort
client phase — Dirichlet alpha=0.1, K=16 clients over G=2 prototypes —
with and without step-count bucketing (docs/bucketing.md).  On this
split the largest client has tens of times the local steps of the
median, so the unbucketed path pads most vmapped lanes with masked
no-op steps; bucketing removes them without touching the trajectory
(the bench asserts bit-identical round logs and globals).  Records the
padded-step waste of both paths and the MARGINAL real-client-steps/sec
(steady-state rounds after a warm-up that absorbs every bucket's
compile; ``benchmarks/timing.py``) into ``BENCH_bucketing.json``
(override with ``BENCH_BUCKETING_OUT``) for CI's bench-smoke gate.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench, time_rounds
from repro.core import BucketConfig, FLConfig, mlp, run_rounds
from repro.core.client import (build_batched_batches, build_batches,
                               make_batched_local_update, make_local_update)
from repro.core.engine import RoundEngine
from repro.data import (dirichlet_partition, gaussian_mixture,
                        train_val_test_split)
from repro.optim.optimizers import sgd

SAMPLES_PER_CLIENT = 256
BATCH = 32
EPOCHS = 8
LR = 0.05
OUT = os.environ.get("BENCH_BUCKETING_OUT", "BENCH_bucketing.json")

# skewed heterogeneous case (ISSUE 5 acceptance config)
SKEW_K = 16
SKEW_ALPHA = 0.1
SKEW_DIM, SKEW_CLASSES = 16, 5
SKEW_EPOCHS = 6
SKEW_HIDDEN = ((96,), (192,))


def _problem(k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = k * SAMPLES_PER_CLIENT
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.integers(0, 3, size=n)
    parts = [np.arange(i * SAMPLES_PER_CLIENT, (i + 1) * SAMPLES_PER_CLIENT)
             for i in range(k)]
    return x, y, parts


def run_engine_case() -> None:
    rounds = scale(3, 10)
    net = mlp(2, 3, hidden=(32, 32))
    g = net.init(jax.random.PRNGKey(0))

    for k in (4, 8, 16):
        x, y, parts = _problem(k)

        upd = make_local_update(net, sgd(LR))
        per = [build_batches(x[idx], y[idx], BATCH, EPOCHS, seed=i)
               for i, idx in enumerate(parts)]
        per = [(jnp.asarray(xb), jnp.asarray(yb)) for xb, yb in per]

        def seq_round():
            outs = [upd(g, xb, yb, g) for xb, yb in per]
            jax.block_until_ready(outs[-1])

        bupd = make_batched_local_update(net, sgd(LR))
        xb, yb, mask = build_batched_batches(x, y, parts, BATCH, EPOCHS,
                                             seeds=list(range(k)))
        xb, yb, mask = jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(mask)
        keys = jnp.zeros((k, 2), jnp.uint32)

        def bat_round():
            jax.block_until_ready(bupd(g, xb, yb, g, mask, keys))

        t_seq = time_rounds(seq_round, rounds)
        t_bat = time_rounds(bat_round, rounds)
        speedup = t_seq / t_bat
        emit(f"round_engine_K{k}", t_bat,
             f"speedup_x{speedup:.2f}",
             record={"n_clients": k, "seq_s": t_seq, "batched_s": t_bat,
                     "speedup": speedup, "steps_per_client":
                     EPOCHS * (SAMPLES_PER_CLIENT // BATCH)})


# ---------------------------------------------------------------------------
# skewed-cohort bucketing case
# ---------------------------------------------------------------------------

def _skew_problem(seed: int = 0):
    ds = gaussian_mixture(scale(8000, 12_000), n_classes=SKEW_CLASSES,
                          dim=SKEW_DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, SKEW_K, SKEW_ALPHA, seed=seed)
    nets = [mlp(SKEW_DIM, SKEW_CLASSES, hidden=SKEW_HIDDEN[0],
                name="proto-s"),
            mlp(SKEW_DIM, SKEW_CLASSES, hidden=SKEW_HIDDEN[1],
                name="proto-m")]
    proto = [k % 2 for k in range(SKEW_K)]
    return train, val, test, parts, nets, proto


def _skew_cfg(rounds: int, bucketing: BucketConfig) -> FLConfig:
    return FLConfig(strategy="fedavg", rounds=rounds, client_fraction=1.0,
                    local_epochs=SKEW_EPOCHS, local_batch_size=BATCH,
                    local_lr=LR, seed=0, bucketing=bucketing)


def _client_phase_stats(bucketing: BucketConfig, rounds: int):
    """Steady-state wall-clock of the CLIENT phase (batch build + batched
    training, the part bucketing changes) per round, plus the
    padding-waste accounting the engine's RoundBatches carry.

    ``client_fraction=1.0`` makes every round activate every client, so
    all (prototype, bucket) shapes compile during the warm-up round that
    :func:`benchmarks.timing.time_rounds` discards — the timed rounds are
    marginal steady state, the same quantity driver_bench's short-vs-long
    difference isolates."""
    train, val, test, parts, nets, proto = _skew_problem()
    engine = RoundEngine(nets, proto, train, parts, val, test,
                         _skew_cfg(rounds, bucketing), heterogeneous=True)
    globals_ = engine.init_globals()
    rng = engine.make_rng()
    active = engine.sample_cohort(rng)
    acct = engine.build_round_batches(1, active)
    real = sum(rb.real_steps for rb in acct if rb is not None)
    padded = sum(rb.padded_slots for rb in acct if rb is not None)

    t_holder = [0]

    def round_fn():
        t_holder[0] += 1
        batches = engine.build_round_batches(t_holder[0], active)
        groups = engine.train_clients(t_holder[0], globals_, batches)
        jax.block_until_ready(
            [jax.tree.leaves(g.stack)[0] for g in groups
             if g.stack is not None])

    t_round = time_rounds(round_fn, rounds)
    return {
        "kind": bucketing.kind, "max_buckets": bucketing.max_buckets,
        "round_s": t_round,
        "rounds_per_s": 1.0 / max(t_round, 1e-9),
        "real_steps_per_round": real,
        "padded_slots_per_round": padded,
        "wasted_steps_per_round": padded - real,
        "steps_per_s": real / max(t_round, 1e-9),
    }


def _trajectories_equal() -> bool:
    """Bucketed and unbucketed full runs must be bit-identical."""
    train, val, test, parts, nets, proto = _skew_problem()

    def full_run(bucketing):
        return run_rounds(nets, proto, train, parts, val, test,
                          _skew_cfg(2, bucketing), heterogeneous=True)

    base = full_run(BucketConfig())
    buck = full_run(BucketConfig(kind="pow2", max_buckets=4))
    if any(ra.logs != rb.logs for ra, rb in zip(base[0], buck[0])):
        return False
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for ga, gb in zip(base[1], buck[1])
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)))


def run_bucketing_case() -> None:
    rounds = scale(4, 8)
    unbucketed = _client_phase_stats(BucketConfig(), rounds)
    bucketed = _client_phase_stats(
        BucketConfig(kind="pow2", max_buckets=4), rounds)

    waste_reduction = (unbucketed["wasted_steps_per_round"]
                       / max(bucketed["wasted_steps_per_round"], 1e-9))
    speedup = bucketed["steps_per_s"] / unbucketed["steps_per_s"]
    trajectory_equal = _trajectories_equal()

    rec = {
        "K": SKEW_K, "alpha": SKEW_ALPHA, "prototypes": 2,
        "dim": SKEW_DIM, "classes": SKEW_CLASSES,
        "local_epochs": SKEW_EPOCHS, "batch": BATCH,
        "rounds_long": rounds,
        "unbucketed": unbucketed, "bucketed": bucketed,
        "waste_reduction_x": waste_reduction,
        "marginal_steps_per_s_speedup": speedup,
        "trajectory_equal": trajectory_equal,
    }
    emit("round_engine_bucketing", 1.0 / max(bucketed["steps_per_s"], 1e-9),
         f"speedup_x{speedup:.2f}_waste_x{waste_reduction:.1f}", record=rec)
    finish_bench("bucketing", rec, out=OUT,
                 config={"K": SKEW_K, "alpha": SKEW_ALPHA,
                         "rounds_long": rounds})
    print(f"wrote {OUT}: bucketed steps/s x{speedup:.2f} over padded "
          f"({unbucketed['steps_per_s']:.0f} -> "
          f"{bucketed['steps_per_s']:.0f} marginal), padded-step waste "
          f"/{waste_reduction:.1f} ({unbucketed['wasted_steps_per_round']:.0f}"
          f" -> {bucketed['wasted_steps_per_round']:.0f} slots/round), "
          f"trajectory_equal={trajectory_equal}")


def run(case: str = "all") -> None:
    if case in ("all", "engine"):
        run_engine_case()
    if case in ("all", "bucketing"):
        run_bucketing_case()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="all",
                    choices=["all", "engine", "bucketing"])
    run(ap.parse_args().case)

"""Assemble the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json and experiments/paper/*.json.

    PYTHONPATH=src python experiments/make_report.py > experiments/report_sections.md
"""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))

ARCH_ORDER = ["gemma3-4b", "mamba2-2.7b", "qwen3-8b", "hubert-xlarge",
              "qwen3-moe-235b-a22b", "minicpm-2b", "internvl2-1b",
              "phi3-medium-14b", "granite-moe-1b-a400m", "zamba2-1.2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "distill_fusion"]


def load_dryruns():
    recs = {}
    for f in glob.glob(os.path.join(HERE, "dryrun", "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"], r.get("variant",
                                                      "baseline"))] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/1e9:.1f}G" if b >= 1e8 else f"{b/1e6:.1f}M"


def dryrun_table(recs, mesh):
    lines = [
        "| arch | shape | status | compile s | temp (global) | "
        "args/dev | HLO GFLOP/dev (corrected) | collectives/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, "baseline"))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | SKIP — {r['skipped'][:58]}"
                             " | — | — | — | — | — |")
                continue
            if not r.get("ok"):
                lines.append(f"| {arch} | {shape} | **FAIL** "
                             f"{r.get('error','')[:50]} | — | — | — | — | — |")
                continue
            m = r.get("memory_analysis", {})
            dc = r.get("depth_corrected", {})
            coll = r.get("collectives_scanned", {}).get("total_bytes")
            lines.append(
                f"| {arch} | {shape} | ok | {r.get('total_s', 0):.0f} "
                f"| {fmt_bytes(m.get('temp_size_in_bytes'))} "
                f"| {fmt_bytes(m.get('argument_size_in_bytes'))} "
                f"| {dc.get('flops', 0)/1e9:.0f} "
                f"| {fmt_bytes(coll)} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="16x16"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | 6ND/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER[:4]:
            r = recs.get((arch, shape, mesh, "baseline"))
            if r is None or "roofline" not in r:
                if r is not None and "skipped" in r:
                    lines.append(f"| {arch} | {shape} | — | — | — | — | — | — "
                                 f"| SKIP |")
                continue
            rf = r["roofline"]
            ratio = rf.get("useful_flops_ratio")
            note = ""
            if ratio and ratio > 1.05:
                note = "HLO<6ND: see remat note"
            lines.append(
                f"| {arch} | {shape} | {rf['compute_s']:.3g} "
                f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
                f"| **{rf['dominant'][:-2]}** | {rf['model_flops']:.2e} "
                f"| {ratio:.2f} | {note} |" if ratio else
                f"| {arch} | {shape} | {rf['compute_s']:.3g} "
                f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
                f"| **{rf['dominant'][:-2]}** | {rf['model_flops']:.2e} "
                f"| — | {note} |")
    return "\n".join(lines)


def paper_table():
    lines = ["| benchmark | paper claim | our result | wall s |",
             "|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(HERE, "paper", "*.json"))):
        r = json.load(open(f))
        claims = r.get("claims", {})
        ok = sum(bool(v) for v in claims.values())
        lines.append(f"| {r['name']} | {len(claims)} claims | "
                     f"{ok}/{len(claims)} hold | {r.get('wall_s', 0):.0f} |")
    return "\n".join(lines)


def variants_table(recs):
    lines = [
        "| arch | shape | mesh | variant | compute s | memory s | "
        "collective s | dominant | temp GB | args GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    keys = sorted({k for k in recs if k[3] != "baseline"})
    for arch, shape, mesh, variant in keys:
        r = recs[(arch, shape, mesh, variant)]
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        m = r.get("memory_analysis", {})
        lines.append(
            f"| {arch} | {shape} | {mesh} | {variant} "
            f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} "
            f"| {rf['collective_s']:.3g} | {rf['dominant'][:-2]} "
            f"| {m.get('temp_size_in_bytes', 0)/1e9:.1f} "
            f"| {m.get('argument_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(lines)


def main():
    recs = load_dryruns()
    print("## Generated: §Dry-run (16x16 single pod)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n## Generated: §Dry-run (2x16x16 multi-pod)\n")
    print(dryrun_table(recs, "2x16x16"))
    print("\n## Generated: §Roofline (single pod)\n")
    print(roofline_table(recs))
    print("\n## Generated: §Perf variant runs (all meshes)\n")
    print(variants_table(recs))
    print("\n## Generated: §Paper-validation summary\n")
    print(paper_table())


if __name__ == "__main__":
    main()

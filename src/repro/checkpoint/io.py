"""Pytree checkpointing: flat .npz with path-encoded keys + a JSON manifest.

No external deps (orbax unavailable offline).  Handles arbitrary nested
dict/tuple/list/NamedTuple pytrees of jnp arrays and python scalars.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype == jnp.bfloat16:  # numpy has no bf16: store uint16 bits
            dtypes[f"leaf_{i}"] = "bfloat16"
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": dtypes,
        "metadata": metadata or {},
    }
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=2)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    with open(_manifest_path(path)) as f:
        dtypes = json.load(f).get("dtypes", {})
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(leaves_like)
    loaded = []
    for i in range(n):
        a = npz[f"leaf_{i}"]
        if dtypes.get(f"leaf_{i}") == "bfloat16":
            a = jnp.asarray(a).view(jnp.bfloat16)
        loaded.append(jnp.asarray(a))
    for got, want in zip(loaded, leaves_like):
        if hasattr(want, "shape") and tuple(got.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint leaf shape {got.shape} != template {want.shape}")
    return jax.tree.unflatten(treedef, loaded)


def metadata(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["metadata"]

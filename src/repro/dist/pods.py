"""Client pods: the training half of the distributed runtime.

A :class:`ClientPodRunner` serves TRAIN frames against its engine — it
decodes the round globals off the wire, runs the bucketed ``vmap(scan)``
training (``engine.build_round_batches`` + ``engine.train_clients``) for
exactly the client ids the frame names, and replies with one UPLOAD
frame holding a codec-encoded blob per client.  It is transport-agnostic
(same code serves a loopback queue pair and a TCP socket) and stateless
across rounds: everything a round needs arrives in the frame, so the
fusion pod can re-route any client to any live pod.

Client k homes on pod ``k % n_pods`` (:func:`shard_clients`), but homing
is only a routing default — re-dispatch after a pod death sends the same
ids elsewhere and the trajectory is unchanged, because per-client
training is a deterministic function of (round, client, globals),
independent of grouping (the PR 5 bucketing invariant).

``python -m repro.dist.pods`` is the TCP subprocess entry: it rebuilds
an engine from a serialized ExperimentSpec (identical by construction to
the fusion pod's) and serves until SHUTDOWN or socket close.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.dist import frames as fr
from repro.dist.transport import PodEndpoint


def shard_clients(client_ids: Sequence[int], n_pods: int) -> List[List[int]]:
    """Home pod assignment: pod j serves [k for k in ids if k % n_pods == j]."""
    out: List[List[int]] = [[] for _ in range(n_pods)]
    for k in client_ids:
        out[int(k) % n_pods].append(int(k))
    return out


class ClientPodRunner:
    """Serves TRAIN frames for one pod over a :class:`PodEndpoint`.

    ``lock`` serializes the jax work across loopback pod threads (one
    process, one device — contention would only interleave compilation);
    TCP pods own their process and pass no lock.  ``kill()`` stops the
    pod abruptly: a round in flight never uploads, heartbeats cease, and
    the fusion pod's liveness tracking must recover — the chaos tests'
    crash injection point.
    """

    def __init__(self, engine, pod: int, endpoint: PodEndpoint, *,
                 heartbeat_s: float = 5.0,
                 lock: Optional[threading.Lock] = None):
        import jax

        self.engine = engine
        self.pod = int(pod)
        self.endpoint = endpoint
        self.heartbeat_s = float(heartbeat_s)
        self.lock = lock if lock is not None else threading.Lock()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # shape/dtype templates per prototype for decoding wire globals
        self._templates, self._treedefs = [], []
        for net in engine.nets:
            leaves, treedef = jax.tree.flatten(net.init(jax.random.PRNGKey(0)))
            self._templates.append([np.asarray(l) for l in leaves])
            self._treedefs.append(treedef)

    # -- lifecycle -------------------------------------------------------

    def start(self) -> "ClientPodRunner":
        """Run serve + heartbeat as daemon threads (loopback transport)."""
        for target in (self.serve, self._heartbeat_loop):
            th = threading.Thread(target=target, daemon=True)
            th.start()
            self._threads.append(th)
        return self

    def serve_forever(self) -> None:
        """Heartbeat in a thread, serve inline (tcp subprocess entry)."""
        th = threading.Thread(target=self._heartbeat_loop, daemon=True)
        th.start()
        self._threads.append(th)
        self.serve()

    def kill(self) -> None:
        """Abrupt crash: stop serving and heartbeating immediately."""
        self._stop.set()

    @property
    def killed(self) -> bool:
        return self._stop.is_set()

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_s):
            try:
                self.endpoint.send(fr.encode_frame(fr.Frame(
                    kind=fr.HEARTBEAT, meta={"pod": self.pod})))
            except Exception:
                return

    # -- serving ---------------------------------------------------------

    def serve(self) -> None:
        while not self._stop.is_set():
            data = self.endpoint.recv(timeout=0.05)
            if data is None:
                continue
            try:
                frame = fr.decode_frame(data)
            except fr.FrameError:
                continue  # downlink garbage: the deadline re-dispatches
            if frame.kind == fr.SHUTDOWN:
                return
            if frame.kind != fr.TRAIN:
                continue
            reply = self._handle_train(frame)
            # check AFTER training: a pod killed mid-round never uploads
            if self._stop.is_set():
                return
            self.endpoint.send(reply)

    def _handle_train(self, frame: fr.Frame) -> bytes:
        import jax
        import jax.numpy as jnp

        eng = self.engine
        t = int(frame.round)
        ids = [int(k) for k in frame.client_ids]
        codec = fr.get_codec(frame.meta.get("codec", "fp32"))
        fp32 = fr.get_codec("fp32")
        # downlink globals are always fp32: decoding is exact, so the
        # pod trains from bit-identical params
        blobs = fr.unpack_blobs(frame.payload, len(eng.nets))
        globals_ = []
        for p, blob in enumerate(blobs):
            leaves = fp32.decode(blob, self._templates[p])
            globals_.append(jax.tree.unflatten(
                self._treedefs[p], [jnp.asarray(l) for l in leaves]))
        with self.lock:
            batches = eng.build_round_batches(t, np.asarray(ids, np.int64))
            groups = eng.train_clients(t, globals_, batches)
        per_client: Dict[int, bytes] = {}
        for g, rb in zip(groups, batches):
            if rb is None or g.stack is None:
                continue
            flat, _ = jax.tree.flatten(g.stack)
            host = [np.asarray(l) for l in flat]
            for i, k in enumerate(rb.ks):
                per_client[int(k)] = codec.encode([h[i] for h in host])
        reply = fr.Frame(
            kind=fr.UPLOAD, round=t, wave=int(frame.wave), client_ids=ids,
            codec_id=codec.codec_id,
            meta={"pod": self.pod, "req": frame.meta.get("req"),
                  "attempt": int(frame.meta.get("attempt", 0))},
            payload=fr.pack_blobs([per_client[k] for k in ids]))
        return fr.encode_frame(reply)


# ---------------------------------------------------------------------------
# tcp subprocess entry


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="repro client pod (tcp transport)")
    ap.add_argument("--spec", required=True,
                    help="path of the serialized ExperimentSpec")
    ap.add_argument("--pod", type=int, required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--heartbeat-s", type=float, default=5.0)
    args = ap.parse_args(argv)

    from repro.api.experiment import build_engine
    from repro.api.spec import ExperimentSpec
    from repro.dist.transport import TCPPodEndpoint

    spec = ExperimentSpec.load(args.spec)
    engine = build_engine(spec)
    endpoint = TCPPodEndpoint(args.host, args.port, args.pod)
    try:
        ClientPodRunner(engine, args.pod, endpoint,
                        heartbeat_s=args.heartbeat_s).serve_forever()
    finally:
        endpoint.close()


if __name__ == "__main__":
    main()

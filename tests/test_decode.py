"""Prefill + decode_step must reproduce the full forward pass exactly —
for every architecture family (GQA, sliding-window ring buffer, SSD state,
hybrid shared-attn, MoE)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.common.arch_config import reduced
from repro.models import transformer as T

ARCHS = ["qwen3-8b", "gemma3-4b", "mamba2-2.7b", "zamba2-1.2b",
         "granite-moe-1b-a400m", "minicpm-2b", "internvl2-1b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    # drop-free MoE capacity: capacity drops depend on total token count, so
    # prefill(S) vs forward(S+2) would differ at the drop boundary — that's
    # inherent to capacity dispatch, not a decode bug
    cfg = dataclasses.replace(reduced(configs.get(arch)), capacity_factor=8.0)
    key = jax.random.PRNGKey(2)
    params = T.init(cfg, key)
    b, s = 2, 40  # exceeds the smoke window (32) -> ring buffer exercised
    toks = jax.random.randint(key, (b, s + 2), 0, cfg.vocab_size)
    batch_full = {"tokens": toks}
    if cfg.frontend == "vision_patches":
        patches = jax.random.normal(key, (b, cfg.n_frontend_tokens,
                                          cfg.d_model)) * 0.02
        batch_full["patches"] = patches

    full, _ = T.forward(params, cfg, batch_full)

    pre_batch = {"tokens": toks[:, :s]}
    if cfg.frontend == "vision_patches":
        pre_batch["patches"] = patches
    npatch = cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0
    logits_pre, caches = T.prefill(params, cfg, pre_batch,
                                   max_seq=s + npatch + 4)
    assert jnp.allclose(full[:, : s + npatch], logits_pre,
                        rtol=2e-3, atol=2e-4), "prefill mismatch"

    cur = jnp.int32(s + npatch)
    for i in range(2):
        dec, caches = T.decode_step(
            params, cfg, {"tokens": toks[:, s + i : s + i + 1]}, caches, cur)
        want = full[:, s + npatch + i]
        err = float(jnp.max(jnp.abs(want - dec[:, 0])))
        assert err < 2e-3, f"decode step {i}: err={err}"
        cur = cur + 1


def test_unroll_matches_scan():
    cfg = reduced(configs.get("gemma3-4b"))
    key = jax.random.PRNGKey(3)
    params = T.init(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    a, _ = T.forward(params, cfg, {"tokens": toks}, unroll=False)
    b, _ = T.forward(params, cfg, {"tokens": toks}, unroll=True)
    assert jnp.allclose(a, b, rtol=1e-5, atol=1e-5)


def test_remat_matches_plain():
    cfg = reduced(configs.get("qwen3-8b"))
    key = jax.random.PRNGKey(4)
    params = T.init(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    a, _ = T.forward(params, cfg, {"tokens": toks}, remat=False)
    b, _ = T.forward(params, cfg, {"tokens": toks}, remat=True)
    assert jnp.allclose(a, b, rtol=1e-5, atol=1e-5)


def test_zamba_shared_attention_is_shared():
    """All shared-attn occurrences must use the SAME weights: perturbing the
    single shared block changes every repeat's output."""
    cfg = reduced(configs.get("zamba2-1.2b"))
    assert "shared" in T.param_specs(cfg)
    key = jax.random.PRNGKey(5)
    params = T.init(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    base, _ = T.forward(params, cfg, {"tokens": toks})
    params2 = jax.tree.map(lambda x: x, params)
    params2["shared"]["mixer"]["wq"] = params2["shared"]["mixer"]["wq"] * 0.0
    pert, _ = T.forward(params2, cfg, {"tokens": toks})
    assert not jnp.allclose(base, pert, atol=1e-4)
    # shared params exist ONCE (not stacked per repeat)
    assert params["shared"]["mixer"]["wq"].ndim == 3  # no leading layer dim

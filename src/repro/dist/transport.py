"""Transports of the distributed runtime: loopback queues and TCP.

Both expose the same two faces:

- the **fusion side** (:class:`LoopbackTransport` / :class:`TCPTransport`):
  ``send(pod, data)`` plus a single merged inbox ``recv(timeout)`` that
  yields ``(pod, data)`` — sender attribution is transport-level, not
  frame-level, so a corrupted frame can still be attributed and retried
  against the right pod;
- the **pod side** (:class:`PodEndpoint`): ``send(data)`` /
  ``recv(timeout)`` / ``close()``, identical for an in-process pod thread
  and a TCP subprocess, so :class:`repro.dist.pods.ClientPodRunner` is
  transport-agnostic.

TCP streams are length-prefixed (u32) raw frame bytes on localhost; pod
identity is established by the first HELLO frame on each connection.
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
from typing import List, Optional, Tuple

from repro.dist import frames as fr

_LEN = struct.Struct("<I")
# cap a single wire message at 1 GiB: a corrupted length prefix must not
# turn into an attempted giant allocation
_MAX_MSG = 1 << 30


class TransportError(Exception):
    pass


class PodEndpoint:
    """The pod-side half of a transport: one send/recv pair."""

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: float) -> Optional[bytes]:
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# loopback: in-process queue pairs


class _LoopbackEndpoint(PodEndpoint):
    def __init__(self, transport: "LoopbackTransport", pod: int):
        self._t = transport
        self._pod = pod

    def send(self, data: bytes) -> None:
        self._t._to_fusion.put((self._pod, data))

    def recv(self, timeout: float) -> Optional[bytes]:
        try:
            return self._t._to_pod[self._pod].get(timeout=timeout)
        except queue.Empty:
            return None


class LoopbackTransport:
    """Single-machine transport: pods are threads, links are queues."""

    def __init__(self, n_pods: int):
        self.n_pods = int(n_pods)
        self._to_pod: List[queue.Queue] = [queue.Queue() for _ in range(n_pods)]
        self._to_fusion: queue.Queue = queue.Queue()

    def endpoint(self, pod: int) -> PodEndpoint:
        return _LoopbackEndpoint(self, pod)

    # -- fusion side -----------------------------------------------------

    def send(self, pod: int, data: bytes) -> None:
        self._to_pod[pod].put(data)

    def recv(self, timeout: float) -> Optional[Tuple[int, bytes]]:
        try:
            return self._to_fusion.get(timeout=max(timeout, 1e-3))
        except queue.Empty:
            return None

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# tcp: localhost sockets, one subprocess per pod


def _send_msg(sock: socket.socket, data: bytes) -> None:
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None  # peer closed
        buf += chunk
    return buf


def _recv_msg(sock: socket.socket) -> Optional[bytes]:
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > _MAX_MSG:
        raise TransportError(f"wire message of {n} bytes exceeds cap")
    return _recv_exact(sock, n)


class TCPTransport:
    """Fusion-side TCP listener; pods dial in and HELLO with their id."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self.host, self.port = self._srv.getsockname()
        self._conns: dict = {}
        self._inbox: queue.Queue = queue.Queue()
        self._readers: List[threading.Thread] = []
        self._closed = threading.Event()

    def accept(self, n_pods: int, timeout: float = 60.0) -> None:
        """Block until all ``n_pods`` pods have dialed in and HELLO'd."""
        self._srv.settimeout(timeout)
        while len(self._conns) < n_pods:
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                raise TransportError(
                    f"only {len(self._conns)}/{n_pods} pods connected "
                    f"within {timeout}s")
            data = _recv_msg(conn)
            if data is None:
                conn.close()
                continue
            hello = fr.decode_frame(data)
            if hello.kind != fr.HELLO:
                conn.close()
                raise TransportError(
                    f"expected HELLO, got kind {hello.kind}")
            pod = int(hello.meta["pod"])
            self._conns[pod] = conn
            th = threading.Thread(target=self._reader, args=(pod, conn),
                                  daemon=True)
            th.start()
            self._readers.append(th)

    def _reader(self, pod: int, conn: socket.socket) -> None:
        try:
            while not self._closed.is_set():
                data = _recv_msg(conn)
                if data is None:
                    return
                self._inbox.put((pod, data))
        except (OSError, TransportError):
            return

    # -- fusion side -----------------------------------------------------

    def send(self, pod: int, data: bytes) -> None:
        conn = self._conns.get(pod)
        if conn is None:
            return  # pod never connected / already gone: deadline handles it
        try:
            _send_msg(conn, data)
        except OSError:
            pass  # dead peer: liveness tracking re-routes its clients

    def recv(self, timeout: float) -> Optional[Tuple[int, bytes]]:
        try:
            return self._inbox.get(timeout=max(timeout, 1e-3))
        except queue.Empty:
            return None

    def close(self) -> None:
        self._closed.set()
        for conn in self._conns.values():
            try:
                conn.close()
            except OSError:
                pass
        try:
            self._srv.close()
        except OSError:
            pass


class TCPPodEndpoint(PodEndpoint):
    """Pod-side TCP client; sends HELLO on connect."""

    def __init__(self, host: str, port: int, pod: int):
        self._sock = socket.create_connection((host, port), timeout=60.0)
        self._pod = int(pod)
        _send_msg(self._sock, fr.encode_frame(
            fr.Frame(kind=fr.HELLO, meta={"pod": self._pod})))

    def send(self, data: bytes) -> None:
        _send_msg(self._sock, data)

    def recv(self, timeout: float) -> Optional[bytes]:
        self._sock.settimeout(max(timeout, 1e-3))
        try:
            return _recv_msg(self._sock)
        except socket.timeout:
            return None

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

"""Pallas TPU kernel for the Mamba2 chunked SSD scan.

TPU adaptation of the CUDA selective-scan: instead of a warp-level
associative scan, the sequence is chunked (Q tokens) and each chunk becomes
dense matmul work for the MXU (intra-chunk kernel matrix + state outer
products); the only sequential part is a [H, N, P] running state carried in
VMEM scratch across the chunk grid dimension.

Grid: (B, H/bh, n_chunks) — chunks innermost ("arbitrary" semantics, the
state scratch persists across them); batch and head tiles parallel.

Per-invocation VMEM working set (fp32):
    x, y: 2*Q*bh*P   kernel matrix: Q*Q*bh   state: bh*N*P   B,C: 2*Q*N
e.g. Q=128, bh=8, P=64, N=128: ~1.3 MB — comfortably inside 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state, *,
                s_total: int, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0].astype(jnp.float32)      # [Q, bh, P]
    dt = dt_ref[0, 0].astype(jnp.float32)    # [Q, bh]
    a = -jnp.exp(a_ref[...].astype(jnp.float32))  # [bh]
    bm = b_ref[0, 0].astype(jnp.float32)     # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)     # [Q, N]

    # zero out the padded tail of the final chunk
    pos = ci * q + jax.lax.broadcasted_iota(jnp.int32, dt.shape, 0)
    valid = pos < s_total
    dt = jnp.where(valid, dt, 0.0)  # pad steps: decay=1, no input

    da = dt * a[None, :]                     # [Q, bh]
    cum = jnp.cumsum(da, axis=0)             # [Q, bh]

    # inter-chunk: y_q = exp(cum_q) * C_q . state_in
    y_inter = jnp.einsum("qn,hnp->qhp", cm, state[...]) * \
        jnp.exp(cum)[:, :, None]

    # intra-chunk: decay-masked kernel matrix
    seg = cum[:, None, :] - cum[None, :, :]  # [Q, Q, bh]
    tril = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(tril[:, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("qn,jn->qj", cm, bm)     # [Q, Q]
    kern = cb[:, :, None] * decay * dt[None, :, :]
    y_intra = jnp.einsum("qjh,jhp->qhp", kern, x)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(cum_end) S + sum_j exp(cum_end - cum_j) dt_j B_j x_j
    decay_end = jnp.exp(cum[-1][None, :] - cum) * dt  # [Q, bh]
    new_state = state[...] * jnp.exp(cum[-1])[:, None, None] + jnp.einsum(
        "qh,qn,qhp->hnp", decay_end, bm, x)
    state[...] = new_state


def ssd_scan_pallas(x, dt, a_log, bmat, cmat, chunk: int = 128,
                    block_h: int = 8, interpret: bool = True):
    """x:[B,S,H,P] dt:[B,S,H] a_log:[H] b/c:[B,S,N] -> y [B,S,H,P]."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, max(8, s))
    pad_s = (-s) % q
    bh = min(block_h, h)
    pad_h = (-h) % bh
    if pad_s or pad_h:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, pad_h)))
        a_log = jnp.pad(a_log, ((0, pad_h),))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad_s), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad_s), (0, 0)))
    sp, hp = s + pad_s, h + pad_h
    nc = sp // q

    xc = x.reshape(b, nc, q, hp, p)
    dtc = dt.reshape(b, nc, q, hp)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    kern = functools.partial(_ssd_kernel, s_total=s, q=q)
    y = pl.pallas_call(
        kern,
        grid=(b, hp // bh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, bh, p), lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, bh), lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((bh,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, bh, p),
                               lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, q, hp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((bh, n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xc, dtc, a_log, bc, cc)
    return y.reshape(b, sp, hp, p)[:, :s, :h]

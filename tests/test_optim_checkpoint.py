"""Optimizers, schedules, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.optim import adam, apply_updates, cosine, momentum_sgd, sgd, wsd
from repro.optim.schedules import constant, make_schedule


def _quadratic_steps(opt, steps=200):
    """Minimise ||x - 3||^2 and return the final x."""
    x = {"w": jnp.zeros((4,))}
    state = opt.init(x)
    for i in range(steps):
        g = jax.tree.map(lambda w: 2 * (w - 3.0), x)
        deltas, state = opt.update(g, state, x, jnp.int32(i))
        x = apply_updates(x, deltas)
    return x["w"]


@pytest.mark.parametrize("opt", [sgd(0.1), momentum_sgd(0.05, 0.9),
                                 adam(0.1)])
def test_optimizers_converge(opt):
    w = _quadratic_steps(opt)
    assert jnp.allclose(w, 3.0, atol=0.05)


def test_adam_states_fp32():
    opt = adam(1e-3)
    x = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(x)
    assert state.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    deltas, state = opt.update(g, state, x, jnp.int32(0))
    assert deltas["w"].dtype == jnp.bfloat16  # cast back to param dtype


def test_cosine_schedule_shape():
    s = cosine(1.0, 100)
    assert float(s(0)) == pytest.approx(1.0)
    assert float(s(50)) == pytest.approx(0.5, abs=0.02)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)


def test_wsd_schedule_shape():
    s = wsd(1.0, 1000, warmup_frac=0.1, decay_frac=0.2)
    assert float(s(0)) < 0.02                    # warmup start
    assert float(s(100)) == pytest.approx(1.0)   # end of warmup
    assert float(s(500)) == pytest.approx(1.0)   # stable plateau
    assert float(s(999)) < 0.1                   # decay tail
    # monotone within phases
    assert float(s(850)) > float(s(950))


def test_make_schedule_dispatch():
    assert float(make_schedule("constant", 0.5, 10)(7)) == pytest.approx(0.5)
    assert float(make_schedule("cosine", 1.0, 10)(10)) < 0.01
    assert float(make_schedule("wsd", 1.0, 100)(50)) == pytest.approx(1.0)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.full((1,), 7.0))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        ckpt.save(path, tree, metadata={"round": 3})
        like = jax.tree.map(jnp.zeros_like, tree)
        back = ckpt.restore(path, like)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert jnp.allclose(jnp.asarray(x, jnp.float32),
                                jnp.asarray(y, jnp.float32))
        assert ckpt.metadata(path)["round"] == 3


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        ckpt.save(path, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(path, {"w": jnp.ones((3, 3))})

"""Perf history as a contract: one versioned JSONL every bench appends to.

Each ``benchmarks/*_bench.py`` used to hand-roll its own ``BENCH_*.json``
shape; gates in CI then read six differently-keyed files and could only
check the path they knew about.  This module defines the single shared
record type — schema-versioned, machine- and config-fingerprinted —
appended to ``BENCH_history.jsonl`` via ``benchmarks/timing.
finish_bench``, and read back by ``benchmarks/check_history.py`` which
gates *all* benched paths in one pass.

Record shape (``SCHEMA_VERSION = 1``)::

    {"schema_version": 1,
     "bench": "driver",            # which *_bench.py produced it
     "case": "default",            # sub-case within the bench
     "created_unix": 1730000000.0,
     "machine": {"platform": ..., "python": ..., "cpus": ...,
                 "jax": ..., "backend": ...},
     "config": {...},              # bench knobs (rounds, K, dims, ...)
     "metrics": {...}}             # the gated numbers, flat-ish JSON

``load`` returns every record; ``latest`` the newest per (bench, case) —
what the gates run against, so the file can accumulate history without
stale entries masking a regression.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

#: default history path; benches override via env for CI artifacts.
DEFAULT_PATH = os.environ.get("BENCH_HISTORY_OUT", "BENCH_history.jsonl")

_REQUIRED = ("schema_version", "bench", "case", "created_unix", "machine",
             "config", "metrics")


def machine_fingerprint() -> dict:
    """Where the numbers came from — enough to explain cross-machine
    deltas without trying to be a full hardware inventory."""
    import platform
    fp = {"platform": platform.platform(),
          "python": platform.python_version(),
          "cpus": os.cpu_count()}
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
    except Exception:  # pragma: no cover - jax-less consumer
        pass
    return fp


def make_record(bench: str, metrics: dict, config: Optional[dict] = None,
                case: str = "default") -> dict:
    rec = {"schema_version": SCHEMA_VERSION, "bench": str(bench),
           "case": str(case), "created_unix": time.time(),
           "machine": machine_fingerprint(),
           "config": dict(config or {}), "metrics": dict(metrics)}
    validate_record(rec)
    return rec


def validate_record(rec: dict) -> None:
    """Raise ``ValueError`` on any shape violation (CI validates every
    line of the history file against this)."""
    if not isinstance(rec, dict):
        raise ValueError(f"history record must be a dict, got {type(rec)}")
    missing = [k for k in _REQUIRED if k not in rec]
    if missing:
        raise ValueError(f"history record missing keys: {missing}")
    extra = [k for k in rec if k not in _REQUIRED]
    if extra:
        raise ValueError(f"history record has unknown keys: {extra}")
    if rec["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"history schema_version {rec['schema_version']!r} != "
            f"{SCHEMA_VERSION}")
    for k in ("bench", "case"):
        if not isinstance(rec[k], str) or not rec[k]:
            raise ValueError(f"history record {k!r} must be a non-empty str")
    for k in ("machine", "config", "metrics"):
        if not isinstance(rec[k], dict):
            raise ValueError(f"history record {k!r} must be a dict")
    if not isinstance(rec["created_unix"], (int, float)):
        raise ValueError("history record created_unix must be numeric")
    json.dumps(rec)  # must be losslessly serializable


def append(rec: dict, path: Optional[str] = None) -> str:
    """Validate + append one record; returns the path written."""
    validate_record(rec)
    path = path or DEFAULT_PATH
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return path


def load(path: Optional[str] = None) -> List[dict]:
    """Every record in the file, validated; ``[]`` if absent."""
    path = path or DEFAULT_PATH
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
            try:
                validate_record(rec)
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: {e}") from e
            out.append(rec)
    return out


def latest(path: Optional[str] = None) -> Dict[Tuple[str, str], dict]:
    """Newest record per ``(bench, case)`` — the gate input."""
    by_key: Dict[Tuple[str, str], dict] = {}
    for rec in load(path):
        key = (rec["bench"], rec["case"])
        prev = by_key.get(key)
        if prev is None or rec["created_unix"] >= prev["created_unix"]:
            by_key[key] = rec
    return by_key

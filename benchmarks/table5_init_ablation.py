"""Table 5 (Appendix C.4.1): initialising the distillation student from the
round's weighted parameter AVERAGE beats initialising from the previous
round's fused model."""
from __future__ import annotations

import time

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(5, 12)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=0.3)
    net = mlp(2, 3, hidden=(48, 48))
    results = {}
    for init in ("average", "previous"):
        cfg = fl_cfg("feddf", rounds, seed=seed, feddf_init_from=init)
        res = run_federated(net, train, parts, val, test, cfg, source=src)
        results[init] = {"best_acc": res.best_acc,
                         "final_acc": res.final_acc}
    dt = time.time() - t0
    claims = {
        "average_init_wins": results["average"]["best_acc"]
        >= results["previous"]["best_acc"] - 0.01,
    }
    emit("table5_init_ablation", dt, f"claims_ok={sum(claims.values())}/1",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

"""The ``distributed`` driver: a fusion pod coordinating client pods.

The fusion pod owns everything the sync driver's loop owns — cohort
sampling (the sole rng consumer), ``fault_pipeline``, ``aggregate`` (and
with it the logit bank), ``guard_globals``, ``evaluate_round`` and the
checkpoint hook — while client training happens in client pods behind
the wire protocol of ``repro.dist.frames``:

    sample_cohort -> shard cohort over pods -> TRAIN frames (fp32
    globals downlink) -> collect UPLOAD frames (configured codec)
    against per-attempt deadlines -> assemble stacks in original cohort
    order -> fault_pipeline -> quorum -> aggregate -> guard -> evaluate

Robustness ladder, outermost first (docs/distributed.md has the
failure-matrix table):

- **CRC / version check** on every frame; a checksum failure triggers a
  re-dispatch with ``attempt + 1`` (a fresh fault draw, PR 8 semantics),
  and exhausted retries escalate to quarantine (``sampler.penalize``).
- **Per-upload deadlines** ``upload_deadline_s * backoff ** attempt``;
  a miss re-dispatches the missing clients to the request's pod if it
  still looks alive, else to the next live pod.
- **Heartbeat liveness**: a pod silent for ``3 * heartbeat_s`` is
  presumed dead; its clients re-route at dispatch time (per-client
  training is grouping-independent, so re-routing never changes the
  trajectory).
- **Quorum degradation**: wire losses count against
  ``faults.quorum`` exactly like screened-out uploads — below quorum
  the round skips fusion and carries frozen globals (sync semantics).
- **Wire log + atomic checkpoints**: accepted UPLOAD frames append to
  ``dist.wire_log``; a restarted fusion pod replays the resumed round's
  uploads instead of re-dispatching them.

The degenerate config — loopback transport, fp32 codec, zero fault
rates — is bit-identical to the ``sync`` driver (pinned in
``tests/test_dist.py``): every phase below is the same deterministic
function of the same inputs, and the wire round-trips are exact.
"""
from __future__ import annotations

import heapq
import os
import subprocess
import sys
import tempfile
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.engine import _UNSET, RoundEngine
from repro.dist import frames as fr
from repro.dist.config import DistConfig
from repro.dist.pods import ClientPodRunner, shard_clients
from repro.dist.transport import LoopbackTransport, TCPTransport
from repro.drivers.base import Driver, register_driver
from repro.obs import trace as _trace

# byte offset of the frame-kind field (magic + u16 version), used to
# classify a possibly-corrupted frame without decoding it
_KIND_OFF = len(fr.MAGIC) + 2


class _Runtime:
    """Pods + transport + cross-round liveness state of one run."""

    def __init__(self, transport, n_pods: int):
        self.transport = transport
        self.n_pods = n_pods
        now = time.monotonic()
        self.last_seen: Dict[int, float] = {j: now for j in range(n_pods)}
        self.runners: List[ClientPodRunner] = []  # loopback only
        self.procs: List[subprocess.Popen] = []   # tcp only
        self.tmpdir: Optional[str] = None

    def close(self) -> None:
        for j in range(self.n_pods):
            try:
                self.transport.send(j, fr.encode_frame(
                    fr.Frame(kind=fr.SHUTDOWN)))
            except Exception:
                pass
        for r in self.runners:
            r.kill()
        for p in self.procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
        self.transport.close()
        if self.tmpdir is not None:
            import shutil
            shutil.rmtree(self.tmpdir, ignore_errors=True)


@register_driver("distributed")
class DistributedDriver(Driver):
    """Fusion pod + client pods behind the versioned wire protocol."""

    def __init__(self, staleness: int = 0, prefetch: int = 1):
        if staleness != 0:
            raise ValueError(
                f"{type(self).__name__} runs sync-quorum semantics; "
                f"staleness={staleness} only applies to the "
                f"async_pipelined driver")
        super().__init__(staleness=staleness, prefetch=prefetch)

    # -- pod lifecycle ----------------------------------------------------

    def _start_pods(self, engine: RoundEngine, dcfg: DistConfig) -> _Runtime:
        if dcfg.transport == "loopback":
            transport = LoopbackTransport(dcfg.n_pods)
            rt = _Runtime(transport, dcfg.n_pods)
            # one process, one device: serialize the pods' jax work
            lock = threading.Lock()
            rt.runners = [
                ClientPodRunner(engine, j, transport.endpoint(j),
                                heartbeat_s=dcfg.heartbeat_s,
                                lock=lock).start()
                for j in range(dcfg.n_pods)]
            return rt
        if dcfg.spec_json is None:
            raise ValueError(
                "dist.transport='tcp' needs dist.spec_json (run through "
                "the Experiment/spec API so client pods can rebuild the "
                "engine)")
        transport = TCPTransport()
        rt = _Runtime(transport, dcfg.n_pods)
        rt.tmpdir = tempfile.mkdtemp(prefix="repro_dist_")
        spec_path = os.path.join(rt.tmpdir, "spec.json")
        with open(spec_path, "w") as f:
            f.write(dcfg.spec_json)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        for j in range(dcfg.n_pods):
            rt.procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.dist.pods",
                 "--spec", spec_path, "--pod", str(j),
                 "--host", transport.host, "--port", str(transport.port),
                 "--heartbeat-s", str(dcfg.heartbeat_s)],
                env=env))
        transport.accept(dcfg.n_pods, timeout=300.0)
        now = time.monotonic()
        for j in range(dcfg.n_pods):
            rt.last_seen[j] = now
        return rt

    # -- the loop ---------------------------------------------------------

    def run(self, engine: RoundEngine, *, log_fn=None, init_globals=None,
            init_state=_UNSET, start_round=1, init_logs=None,
            round_end_hook=None):
        dcfg: DistConfig = engine.cfg.dist
        dcfg.validate()
        codec = fr.get_codec(dcfg.wire_codec)
        faults = engine.cfg.faults
        wire_fm = None
        if faults.transport_enabled:
            from repro.population.faults import FaultModel
            wire_fm = FaultModel(faults, engine.cfg.seed, dcfg.n_pods)
        wlog = fr.WireLog(dcfg.wire_log) if dcfg.wire_log else None

        globals_, state, logs, rng = self._setup(
            engine, init_globals, init_state, init_logs, start_round)
        rounds_to_target = None
        rt = self._start_pods(engine, dcfg)
        try:
            for t in range(start_round, engine.cfg.rounds + 1):
                active = engine.sample_cohort(rng)
                received, st = self._collect(
                    engine, t, active, globals_, codec, wire_fm, dcfg,
                    wlog, rt, replay=(t == start_round))
                groups, ids_by_proto = self._assemble(
                    engine, active, received, globals_)
                fstats = engine.fault_pipeline(t, groups, ids_by_proto)
                # wire losses count against quorum exactly like screened
                # uploads: dispatched is the full cohort, not survivors
                qstats = fstats
                if qstats is not None:
                    qstats["dispatched"] = len(active)
                elif st["wire_lost"]:
                    qstats = {"dispatched": len(active),
                              "kept": len(active) - st["wire_lost"]}
                fuse = engine.quorum_met(qstats)
                prev = list(globals_)
                if fuse:
                    globals_, state, infos, dropped, ens_acc = \
                        engine.aggregate(t, groups, state)
                    globals_, rolled = engine.guard_globals(globals_, prev)
                else:  # quorum shortfall: carry the globals, skip fusion
                    infos = [{} for _ in range(engine.n_proto)]
                    dropped = [0] * engine.n_proto
                    ens_acc = None
                    rolled = [False] * engine.n_proto
                round_logs = engine.evaluate_round(t, globals_, groups,
                                                   infos, dropped, ens_acc)
                n_alive = sum(
                    1 for j in range(dcfg.n_pods) if self._alive(rt, j, dcfg))
                for p, log in enumerate(round_logs):
                    if fstats is not None:
                        log.n_corrupted = fstats["corrupted"]
                        log.n_quarantined = fstats["quarantined"]
                        log.n_retries = fstats["retries"]
                        log.rolled_back = bool(log.rolled_back or rolled[p])
                    if fstats is not None or qstats is not None:
                        log.fused = fuse
                    log.wire_bytes_up = st["bytes_up"]
                    log.wire_bytes_down = st["bytes_down"]
                    log.n_wire_retries = st["wire_retries"]
                    log.n_crc_failures = st["crc_failures"]
                    log.n_deadline_misses = st["deadline_misses"]
                    log.n_wire_lost = st["wire_lost"]
                    log.n_pods_alive = n_alive
                reached, stop_requested = self._emit_round(
                    engine, t, round_logs, logs, log_fn)
                if reached:
                    rounds_to_target = t

                if round_end_hook is not None:
                    round_end_hook(t, globals_, state, logs,
                                   rounds_to_target)

                if rounds_to_target is not None or stop_requested:
                    break
        finally:
            rt.close()

        return self._results(engine, logs, globals_, rounds_to_target)

    # -- liveness ---------------------------------------------------------

    @staticmethod
    def _alive(rt: _Runtime, pod: int, dcfg: DistConfig) -> bool:
        return (time.monotonic() - rt.last_seen[pod]
                <= max(3.0 * dcfg.heartbeat_s, 0.05))

    # -- wire collection --------------------------------------------------

    def _collect(self, engine: RoundEngine, t: int, active, globals_,
                 codec, wire_fm, dcfg: DistConfig, wlog, rt: _Runtime, *,
                 replay: bool):
        """Dispatch TRAIN frames and gather UPLOADs for round ``t``.

        Returns ``(received, stats)`` where ``received`` maps client id
        -> decoded flat leaf list and ``stats`` is the round's wire
        telemetry.
        """
        import jax

        from repro.obs.metrics import REGISTRY

        faults = engine.cfg.faults
        proto = engine.client_proto
        active_set = {int(k) for k in active}
        tmpl = [[np.asarray(l) for l in jax.tree.leaves(globals_[p])]
                for p in range(engine.n_proto)]
        received: Dict[int, List[np.ndarray]] = {}
        st = {k: 0 for k in (
            "bytes_up", "bytes_down", "crc_failures", "deadline_misses",
            "wire_retries", "wire_lost", "frames", "replayed",
            "dispatches")}

        def store_upload(frame: fr.Frame) -> int:
            """Decode an accepted UPLOAD into ``received``; returns the
            number of newly covered clients."""
            c = fr.codec_by_id(frame.codec_id)
            blobs = fr.unpack_blobs(frame.payload, len(frame.client_ids))
            fresh = 0
            for k, blob in zip(frame.client_ids, blobs):
                k = int(k)
                if k in active_set and k not in received:
                    received[k] = c.decode(blob, tmpl[proto[k]])
                    fresh += 1
            return fresh

        # -- fusion-pod restart: replay this round's logged uploads ------
        if replay and wlog is not None:
            with _trace.span("wire_replay", round=int(t)) as sp:
                for frame in wlog.replay(t):
                    try:
                        st["replayed"] += store_upload(frame)
                    except fr.FrameError:
                        continue
                sp.annotate(replayed=st["replayed"])
            REGISTRY.counter("dist.wirelog_replayed").add(st["replayed"])

        # -- downlink: all prototypes' globals, always fp32 (exact) ------
        fp32 = fr.get_codec("fp32")
        down_payload = fr.pack_blobs(
            [fp32.encode(tmpl[p]) for p in range(engine.n_proto)])

        reqs: Dict[int, dict] = {}
        next_rid = [0]
        dark: set = set()  # pods disconnect-faulted for this round

        def alive(j: int) -> bool:
            return j not in dark and self._alive(rt, j, dcfg)

        def pick_pod(home: int) -> Optional[int]:
            for j in [home] + [j for j in range(dcfg.n_pods) if j != home]:
                if alive(j):
                    return j
            return None

        def dispatch(ids: List[int], pod: int, attempt: int) -> None:
            rid = next_rid[0]
            next_rid[0] += 1
            data = fr.encode_frame(fr.Frame(
                kind=fr.TRAIN, round=t, wave=t, client_ids=ids,
                codec_id=codec.codec_id,
                meta={"req": rid, "attempt": attempt, "codec": codec.name},
                payload=down_payload))
            with _trace.span("wire_dispatch", round=int(t)) as sp:
                sp.annotate(pod=pod, attempt=attempt, n_clients=len(ids),
                            nbytes=len(data))
                rt.transport.send(pod, data)
            st["bytes_down"] += len(data)
            st["dispatches"] += 1
            deadline = time.monotonic() + (
                dcfg.upload_deadline_s * (faults.backoff ** attempt))
            reqs[rid] = {"pod": pod, "ids": list(ids), "attempt": attempt,
                         "deadline": deadline}

        def give_up(missing: List[int], why: str) -> None:
            st["wire_lost"] += len(missing)
            if why == "crc":
                # CRC-failure escalation: retries exhausted on a
                # corrupting link -> quarantine the clients' uploads
                engine.sampler.penalize([int(k) for k in missing], 0.5)

        def retry(rid: int, why: str) -> None:
            r = reqs.pop(rid, None)
            if r is None:
                return
            missing = [k for k in r["ids"] if k not in received]
            if not missing:
                return
            attempt = r["attempt"] + 1
            if attempt > faults.retries:
                give_up(missing, why)
                return
            # prefer the request's pod while it still heartbeats, else
            # the next live pod (re-routing never changes the trajectory:
            # per-client training is grouping-independent)
            target = pick_pod(r["pod"])
            if target is None:
                give_up(missing, why)
                return
            st["wire_retries"] += 1
            REGISTRY.counter("dist.wire_retries").add(1)
            dispatch(missing, target, attempt)

        def oldest_req_of(pod: int) -> Optional[int]:
            rids = [rid for rid, r in reqs.items() if r["pod"] == pod]
            return min(rids) if rids else None

        with _trace.span("wire_collect", round=int(t)) as sp:
            for home, ids in enumerate(shard_clients(
                    [k for k in active_set if k not in received],
                    dcfg.n_pods)):
                if not ids:
                    continue
                target = pick_pod(home)
                if target is None:
                    give_up(ids, "dead")
                    continue
                dispatch(sorted(ids), target, 0)

            # chaos hook: crash a pod right after this round's dispatch —
            # the killed pod trains but never uploads, and recovery must
            # flow through deadline + heartbeat-liveness re-routing
            if (rt.runners and dcfg.kill_pod is not None
                    and t == dcfg.kill_after_round
                    and 0 <= dcfg.kill_pod < len(rt.runners)):
                rt.runners[dcfg.kill_pod].kill()

            delayed: list = []  # (release_time, seq, pod, data)
            seq = 0
            while reqs:
                now = time.monotonic()
                msg = None
                if delayed and delayed[0][0] <= now:
                    _, _, pod, data = heapq.heappop(delayed)
                    msg, preprocessed = (pod, data), True
                else:
                    got = rt.transport.recv(0.05)
                    if got is not None:
                        msg, preprocessed = got, False
                if msg is not None:
                    pod, data = msg
                    rt.last_seen[pod] = time.monotonic()
                    st["frames"] += 1
                    is_upload = (len(data) > _KIND_OFF
                                 and data[_KIND_OFF] == fr.UPLOAD)
                    if is_upload and wire_fm is not None and not preprocessed:
                        req = oldest_req_of(pod)
                        attempt = reqs[req]["attempt"] if req is not None else 0
                        fault = wire_fm.transport_fault(t, pod, attempt)
                        if fault == "disconnect":
                            dark.add(pod)
                            continue  # frame lost; deadline re-routes
                        if fault == "drop":
                            continue
                        if fault == "corrupt":
                            data = wire_fm.corrupt_frame(t, pod, attempt,
                                                         data)
                        elif fault == "delay":
                            heapq.heappush(
                                delayed,
                                (now + faults.transport_delay_s, seq, pod,
                                 data))
                            seq += 1
                            continue
                    try:
                        frame = fr.decode_frame(
                            data, verify_crc=dcfg.verify_crc)
                    except fr.CRCError:
                        st["crc_failures"] += 1
                        REGISTRY.counter("dist.crc_failures").add(1)
                        rid = oldest_req_of(pod)
                        if rid is not None:
                            retry(rid, "crc")
                        continue
                    except fr.FrameError:
                        rid = oldest_req_of(pod)
                        if rid is not None:
                            retry(rid, "crc")
                        continue
                    if frame.kind == fr.HEARTBEAT:
                        continue
                    if frame.kind != fr.UPLOAD or frame.round != t:
                        continue  # stale round / unexpected kind
                    try:
                        store_upload(frame)
                    except (fr.FrameError, ValueError):
                        # structurally broken payload (possible with
                        # verify_crc off): treat like a checksum failure
                        st["crc_failures"] += 1
                        rid = oldest_req_of(pod)
                        if rid is not None:
                            retry(rid, "crc")
                        continue
                    st["bytes_up"] += len(data)
                    if wlog is not None:
                        wlog.append(data)
                    for rid in list(reqs):
                        if all(k in received for k in reqs[rid]["ids"]):
                            del reqs[rid]
                # deadline sweep
                now = time.monotonic()
                for rid in [r for r in list(reqs)
                            if reqs[r]["deadline"] <= now]:
                    st["deadline_misses"] += 1
                    REGISTRY.counter("dist.deadline_misses").add(1)
                    retry(rid, "deadline")
            sp.annotate(**st)

        REGISTRY.counter("dist.train_dispatches").add(st["dispatches"])
        REGISTRY.counter("dist.bytes_up").add(st["bytes_up"])
        REGISTRY.counter("dist.bytes_down").add(st["bytes_down"])
        REGISTRY.gauge("dist.pods_alive").set(sum(
            1 for j in range(dcfg.n_pods) if self._alive(rt, j, dcfg)))
        return received, st

    # -- stack assembly ---------------------------------------------------

    def _assemble(self, engine: RoundEngine, active, received, globals_):
        """Received leaf lists -> per-prototype GroupRounds in the
        cohort's original order — the exact inputs ``sync``'s
        ``train_clients`` would produce for the surviving clients."""
        import jax
        import jax.numpy as jnp

        from repro.core.strategies import GroupRound

        proto = engine.client_proto
        by_proto: List[List[int]] = [[] for _ in range(engine.n_proto)]
        for k in active:
            if int(k) in received:
                by_proto[proto[int(k)]].append(int(k))
        groups, ids_by_proto = [], []
        for p in range(engine.n_proto):
            ks = by_proto[p]
            if not ks:
                groups.append(GroupRound(engine.nets[p], globals_[p], None,
                                         np.zeros(0)))
                ids_by_proto.append(None)
                continue
            flat_t, treedef = jax.tree.flatten(globals_[p])
            stack = jax.tree.unflatten(treedef, [
                jnp.asarray(np.stack([received[k][li] for k in ks]))
                for li in range(len(flat_t))])
            weights = np.array([float(len(engine.parts[k])) for k in ks])
            groups.append(GroupRound(engine.nets[p], globals_[p], stack,
                                     weights))
            ids_by_proto.append(ks)
        return groups, ids_by_proto

"""Naive logits-averaging ensemble — the fused model's performance upper
bound (Theorem 5.1; the solid-vs-ensemble gap in Fig. 4)."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import stacked_logits_fn
from repro.core.nets import Net


def ensemble_accuracy(groups: Sequence[Tuple[Net, List[dict]]],
                      x: np.ndarray, y: np.ndarray,
                      batch_size: int = 512) -> float:
    """Average logits over every model in every (net, params-list) group."""
    fns = []
    for net, plist in groups:
        for p in plist:
            fns.append((net, p))
    correct = 0
    for s in range(0, len(y), batch_size):
        xb = jnp.asarray(x[s : s + batch_size])
        acc_logits = None
        for net, p in fns:
            lg = net.apply(p, xb, train=False).astype(jnp.float32)
            acc_logits = lg if acc_logits is None else acc_logits + lg
        pred = np.asarray(jnp.argmax(acc_logits, axis=-1))
        correct += int((pred == y[s : s + batch_size]).sum())
    return correct / len(y)


def ensemble_accuracy_stacked(groups: Sequence[Tuple[Net, object]],
                              x: np.ndarray, y: np.ndarray,
                              batch_size: int = 512) -> float:
    """Logits-averaging ensemble over stacked [K_g, ...] param pytrees —
    one vmapped forward per group instead of one per model."""
    correct = 0
    for s in range(0, len(y), batch_size):
        xb = jnp.asarray(x[s : s + batch_size])
        acc_logits = None
        for net, stack in groups:
            lg = jnp.sum(stacked_logits_fn(net)(stack, xb).astype(
                jnp.float32), axis=0)
            acc_logits = lg if acc_logits is None else acc_logits + lg
        pred = np.asarray(jnp.argmax(acc_logits, axis=-1))
        correct += int((pred == y[s : s + batch_size]).sum())
    return correct / len(y)

"""Minimal optax-style optimizers (built in-repo; no external deps).

An :class:`Optimizer` is an (init, update) pair over pytrees; ``update``
returns parameter *deltas* to be added.  Schedules are callables
``step -> lr`` (see ``repro.optim.schedules``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jax.Array], Tuple[Pytree, Pytree]]
    # update(grads, state, params, step) -> (deltas, new_state)


def apply_updates(params: Pytree, deltas: Pytree) -> Pytree:
    return jax.tree.map(lambda p, d: (p + d).astype(p.dtype), params, deltas)


def sgd(lr: Schedule | float) -> Optimizer:
    sched = (lambda s: jnp.asarray(lr)) if isinstance(lr, (int, float)) else lr

    def init(params):
        return ()

    def update(grads, state, params, step):
        eta = sched(step)
        return jax.tree.map(lambda g: -eta * g, grads), state

    return Optimizer(init, update)


def momentum_sgd(lr: Schedule | float, beta: float = 0.9,
                 nesterov: bool = False) -> Optimizer:
    sched = (lambda s: jnp.asarray(lr)) if isinstance(lr, (int, float)) else lr

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, vel, params, step):
        eta = sched(step)
        vel = jax.tree.map(lambda v, g: beta * v + g, vel, grads)
        if nesterov:
            deltas = jax.tree.map(lambda v, g: -eta * (beta * v + g), vel, grads)
        else:
            deltas = jax.tree.map(lambda v: -eta * v, vel)
        return deltas, vel

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree


def adam(lr: Schedule | float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam — the paper's server-side distillation optimizer (lr 1e-3,
    cosine annealing)."""
    sched = (lambda s: jnp.asarray(lr)) if isinstance(lr, (int, float)) else lr

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jax.tree.map(f32, params), jax.tree.map(f32, params))

    def update(grads, state, params, step):
        eta = sched(step)
        t = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        mh = 1.0 - b1 ** t
        nh = 1.0 - b2 ** t

        def delta(m, v, p):
            d = -eta * (m / mh) / (jnp.sqrt(v / nh) + eps)
            if weight_decay:
                d = d - eta * weight_decay * p.astype(jnp.float32)
            return d.astype(p.dtype)

        return (jax.tree.map(delta, mu, nu, params), AdamState(mu, nu))

    return Optimizer(init, update)

"""Distributed runtime: wire protocol + fusion/client pods
(docs/distributed.md).

 1. Wire format: frames round-trip through every payload codec; the CRC
    rejects in-flight corruption, the version field rejects foreign
    frames (checked BEFORE the CRC), truncation never crashes the
    decoder, and every codec's ``nbytes`` is an exact bytes-on-wire
    accounting (``len(encode(leaves)) == nbytes(templates)``, with
    binarize matching the ``core.quantize`` comm-bytes formula).
 2. Crash-safe record log: torn tails are dropped, never propagated;
    the wire log replays exactly one round's UPLOAD frames.
 3. Transport faults are counter-keyed draws — deterministic in
    ``(wave, pod, attempt)``, a retry is a fresh draw — and the
    transport domain deliberately does NOT arm the statistical
    defenses (``FaultConfig.enabled``).
 4. The degenerate distributed config (loopback, fp32, zero faults) is
    BIT-IDENTICAL to the ``sync`` driver — homogeneous and
    heterogeneous, any pod count.
 5. The robustness ladder: CRC failures retry without changing the
    trajectory, a killed pod re-routes through deadline + heartbeat
    liveness, quorum shortfall freezes the globals, and a restarted
    fusion pod replays in-flight uploads from the wire log.
 6. Spec/CLI surface: ``DistSpec`` validates and round-trips;
    ``launch/train.py`` flags compile to the same spec JSON that
    ``--config`` reloads; the tcp transport runs real subprocess pods.
"""
import dataclasses
import os
import struct

import jax
import numpy as np
import pytest

from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.dist import frames as fr
from repro.dist.config import DistConfig
from repro.dist.pods import shard_clients
from repro.population.config import FaultConfig
from repro.population.faults import FaultModel

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


def small_cfg(strategy="fedavg", rounds=2, **kw):
    return FLConfig(strategy=strategy, rounds=rounds, client_fraction=0.5,
                    local_epochs=3, local_batch_size=32, local_lr=0.05,
                    seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32), **kw)


def _assert_same_run(a, b):
    res_a, glob_a, rtt_a = a
    res_b, glob_b, rtt_b = b
    assert rtt_a == rtt_b
    for ra, rb in zip(res_a, res_b):
        assert [l.test_acc for l in ra.logs] == \
            [l.test_acc for l in rb.logs]
    for ga, gb in zip(glob_a, glob_b):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _leaves():
    rng = np.random.default_rng(0)
    return [rng.normal(size=(8, 16)).astype(np.float32),
            rng.normal(size=(16,)).astype(np.float32),
            np.arange(5, dtype=np.int64)]


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", ["fp32", "binarize", "int8"])
def test_frame_round_trip_all_codecs(codec_name):
    codec = fr.get_codec(codec_name)
    leaves = _leaves()
    frame = fr.Frame(kind=fr.UPLOAD, round=3, wave=7,
                     client_ids=[2, 5, 11],
                     codec_id=codec.codec_id,
                     meta={"pod": 1, "attempt": 0},
                     payload=codec.encode(leaves))
    out = fr.decode_frame(fr.encode_frame(frame))
    assert out.kind == fr.UPLOAD and out.round == 3 and out.wave == 7
    assert list(out.client_ids) == [2, 5, 11]
    assert out.meta == {"pod": 1, "attempt": 0}
    dec = fr.codec_by_id(out.codec_id).decode(out.payload, leaves)
    assert len(dec) == len(leaves)
    for d, l in zip(dec, leaves):
        assert d.shape == l.shape and d.dtype == l.dtype


def test_fp32_codec_exact():
    codec = fr.get_codec("fp32")
    leaves = _leaves()
    for d, l in zip(codec.decode(codec.encode(leaves), leaves), leaves):
        np.testing.assert_array_equal(d, l)


def test_int8_codec_close():
    codec = fr.get_codec("int8")
    leaves = _leaves()[:2]
    dec = codec.decode(codec.encode(leaves), leaves)
    for d, l in zip(dec, leaves):
        tol = np.abs(l).max() / 127 + 1e-7
        assert np.abs(d - l).max() <= tol


def test_binarize_codec_sign_scale():
    codec = fr.get_codec("binarize")
    w = np.random.default_rng(3).normal(size=(16, 32)).astype(np.float32)
    (d,) = codec.decode(codec.encode([w]), [w])
    scale = np.float32(np.mean(np.abs(w)))
    np.testing.assert_array_equal(np.abs(d), np.full_like(w, scale))
    np.testing.assert_array_equal(np.sign(d), np.where(w >= 0, 1.0, -1.0))


def test_codec_nbytes_is_exact_accounting():
    from repro.core.quantize import comm_bytes
    leaves = _leaves()
    for name in fr.available_codecs():
        codec = fr.get_codec(name)
        assert len(codec.encode(leaves)) == codec.nbytes(leaves), name
    # binarize on the wire = the quantizer registry's comm-bytes
    # formula: one fp32 scale + one packed sign bit per element for
    # binarizable leaves, raw fp32 for the rest
    w = leaves[0]
    assert fr.get_codec("binarize").nbytes([w]) == (w.size + 7) // 8 + 4
    assert comm_bytes({"w": w}, binarized=True) == (w.size + 7) // 8 + 4


def test_crc_corruption_detected():
    data = bytearray(fr.encode_frame(fr.Frame(
        kind=fr.UPLOAD, round=1, client_ids=[1], payload=b"x" * 64)))
    data[-10] ^= 0xFF  # flip a payload byte
    with pytest.raises(fr.CRCError):
        fr.decode_frame(bytes(data))
    # the undefended path accepts the same bytes
    frame = fr.decode_frame(bytes(data), verify_crc=False)
    assert frame.kind == fr.UPLOAD


def test_version_mismatch_rejected_before_crc():
    data = bytearray(fr.encode_frame(fr.Frame(kind=fr.HEARTBEAT)))
    off = len(fr.MAGIC)
    struct.pack_into("<H", data, off, fr.WIRE_VERSION + 1)
    # the version check fires first: a foreign frame is a protocol
    # error, not a checksum coincidence
    with pytest.raises(fr.VersionError):
        fr.decode_frame(bytes(data))
    with pytest.raises(fr.VersionError):
        fr.decode_frame(bytes(data), verify_crc=False)


def test_truncation_and_garbage_rejected():
    data = fr.encode_frame(fr.Frame(
        kind=fr.UPLOAD, round=1, client_ids=[1, 2], payload=b"y" * 32))
    for n in (0, 3, len(fr.MAGIC) + 1, len(data) - 5):
        with pytest.raises(fr.FrameError):
            fr.decode_frame(data[:n])
    with pytest.raises(fr.FrameError):
        fr.decode_frame(b"XX" + data[2:])  # wrong magic


def test_pack_unpack_blobs():
    blobs = [b"aa", b"", b"c" * 100]
    packed = fr.pack_blobs(blobs)
    assert fr.unpack_blobs(packed, 3) == blobs
    with pytest.raises(fr.FrameError):
        fr.unpack_blobs(packed, 2)        # trailing bytes
    with pytest.raises(fr.FrameError):
        fr.unpack_blobs(packed[:-1], 3)   # truncated


def test_codec_registry():
    assert fr.available_codecs() == sorted(fr.available_codecs())
    assert {"fp32", "binarize", "int8"} <= set(fr.available_codecs())
    assert fr.codec_by_id(fr.get_codec("int8").codec_id).name == "int8"
    with pytest.raises(KeyError, match="unknown wire codec"):
        fr.get_codec("no-such-codec")
    with pytest.raises(fr.FrameError, match="unknown wire codec id"):
        fr.codec_by_id(200)


# ---------------------------------------------------------------------------
# record log + wire log
# ---------------------------------------------------------------------------

def test_record_log_torn_tail(tmp_path):
    from repro.checkpoint.io import append_record, read_records
    path = str(tmp_path / "rec.log")
    assert read_records(path) == []
    append_record(path, b"first")
    append_record(path, b"second")
    assert read_records(path) == [b"first", b"second"]
    # a crash mid-append leaves a torn tail: drop it, keep the prefix
    with open(path, "ab") as f:
        f.write(struct.pack("<II", 100, 0) + b"partial")
    assert read_records(path) == [b"first", b"second"]


def test_wirelog_replays_one_round(tmp_path):
    wlog = fr.WireLog(str(tmp_path / "wire.log"))
    for t in (1, 1, 2):
        wlog.append(fr.encode_frame(fr.Frame(
            kind=fr.UPLOAD, round=t, client_ids=[t * 10],
            payload=b"p")))
    wlog.append(fr.encode_frame(fr.Frame(kind=fr.TRAIN, round=1)))
    got = wlog.replay(1)
    assert [list(f.client_ids) for f in got] == [[10], [10]]
    assert all(f.kind == fr.UPLOAD for f in got)
    assert wlog.replay(3) == []


# ---------------------------------------------------------------------------
# transport fault domain
# ---------------------------------------------------------------------------

def test_transport_fault_deterministic_and_attempt_keyed():
    cfg = FaultConfig(transport_drop=0.5, transport_corrupt=0.3)
    fm = FaultModel(cfg, 0, 4)
    draws = [fm.transport_fault(wave=2, pod=1, attempt=0)
             for _ in range(5)]
    assert len(set(draws)) == 1  # pure function of the key
    over_attempts = {fm.transport_fault(2, 1, a) for a in range(40)}
    assert len(over_attempts) > 1  # a retry is a fresh draw
    quiet = FaultModel(FaultConfig(), 0, 4)
    assert all(quiet.transport_fault(w, p, 0) is None
               for w in range(10) for p in range(4))
    always = FaultModel(FaultConfig(transport_drop=1.0), 0, 4)
    assert always.transport_fault(0, 0, 0) == "drop"


def test_corrupt_frame_flips_bytes_deterministically():
    cfg = FaultConfig(transport_corrupt=1.0)
    fm = FaultModel(cfg, 0, 4)
    data = bytes(range(64))
    a = fm.corrupt_frame(1, 0, 0, data)
    assert a == fm.corrupt_frame(1, 0, 0, data)
    assert a != data and len(a) == len(data)
    assert a != fm.corrupt_frame(1, 0, 1, data)


def test_transport_knobs_do_not_arm_param_defenses():
    cfg = FaultConfig(transport_drop=0.5)
    assert cfg.transport_enabled and not cfg.enabled
    assert FaultConfig(nan_rate=0.1).enabled
    with pytest.raises(ValueError, match="transport_drop"):
        FaultConfig(transport_drop=1.5).validate()
    with pytest.raises(ValueError, match="transport_delay_s"):
        FaultConfig(transport_delay_s=-1.0).validate()


# ---------------------------------------------------------------------------
# driver: degenerate bit-identity
# ---------------------------------------------------------------------------

def test_registry_has_distributed():
    from repro.drivers import DistributedDriver, available_drivers
    assert "distributed" in available_drivers()
    with pytest.raises(ValueError, match="staleness"):
        DistributedDriver(staleness=1)


def test_shard_clients_partition():
    shards = shard_clients([0, 1, 2, 3, 4, 7], 3)
    assert shards == [[0, 3], [1, 4, 7], [2]]
    assert shard_clients([], 2) == [[], []]


@pytest.mark.parametrize("strategy", ["fedavg", "feddf"])
def test_degenerate_matches_sync(problem, strategy):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16,))
    kw = dict(source=src) if strategy == "feddf" else {}
    ref = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(strategy), driver="sync", **kw)
    got = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(strategy, dist=DistConfig(n_pods=2)),
                     driver="distributed", **kw)
    _assert_same_run(ref, got)


def test_pod_count_invariance(problem):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16,))
    runs = [run_rounds([net], [0] * 6, train, parts, val, test,
                       small_cfg(dist=DistConfig(n_pods=n)),
                       driver="distributed")
            for n in (1, 3)]
    _assert_same_run(runs[0], runs[1])


def test_heterogeneous_degenerate_matches_sync(problem):
    train, val, test, parts, src = problem
    nets = [mlp(2, 3, hidden=(16,)), mlp(2, 3, hidden=(8, 8))]
    proto = [0, 1, 0, 1, 0, 1]
    ref = run_rounds(nets, proto, train, parts, val, test,
                     small_cfg("feddf"), source=src, heterogeneous=True,
                     driver="sync")
    got = run_rounds(nets, proto, train, parts, val, test,
                     small_cfg("feddf", dist=DistConfig(n_pods=2)),
                     source=src, heterogeneous=True, driver="distributed")
    _assert_same_run(ref, got)


def test_low_bit_codec_runs_close(problem):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    ref = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(), driver="sync")
    got = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(dist=DistConfig(n_pods=2,
                                               wire_codec="int8")),
                     driver="distributed")
    for x in jax.tree.leaves(got[1][0]):
        assert np.isfinite(np.asarray(x)).all()
    drift = abs(got[0][0].final_acc - ref[0][0].final_acc)
    assert drift <= 0.2  # lossy uplink, same problem: stays in range
    # telemetry: int8 uplink is measurably smaller than the downlink
    log = got[0][0].logs[-1]
    assert 0 < log.wire_bytes_up < log.wire_bytes_down


# ---------------------------------------------------------------------------
# driver: robustness ladder
# ---------------------------------------------------------------------------

def test_pod_kill_reroutes_and_trajectory_holds(problem):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    ref = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(), driver="sync")
    got = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(dist=DistConfig(
                         n_pods=2, heartbeat_s=0.05,
                         upload_deadline_s=0.5,
                         kill_pod=1, kill_after_round=1)),
                     driver="distributed")
    # a killed pod trains but never uploads: recovery flows through the
    # deadline + heartbeat liveness, and re-trained clients are
    # deterministic, so the trajectory is unchanged
    _assert_same_run(ref, got)
    logs = got[0][0].logs
    assert sum(l.n_deadline_misses for l in logs) >= 1
    assert logs[-1].n_pods_alive == 1


def test_crc_retry_keeps_trajectory(problem):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    ref = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(), driver="sync")
    got = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(dist=DistConfig(n_pods=2),
                               faults=FaultConfig(transport_corrupt=0.2,
                                                  retries=6)),
                     driver="distributed")
    # every corrupted frame is caught by the CRC and re-dispatched with
    # a fresh fault draw — the fused parameters never see garbage
    _assert_same_run(ref, got)
    logs = got[0][0].logs
    assert sum(l.n_crc_failures for l in logs) > 0
    assert sum(l.n_wire_retries for l in logs) > 0


def test_quorum_shortfall_freezes_globals(problem):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    init = net.init(jax.random.PRNGKey(0))
    results, globals_, _ = run_rounds(
        [net], [0] * 6, train, parts, val, test,
        small_cfg(dist=DistConfig(n_pods=2, upload_deadline_s=0.2),
                  faults=FaultConfig(transport_drop=1.0, quorum=0.5,
                                     retries=1, backoff=1.0)),
        driver="distributed", init_globals=[init])
    logs = results[0].logs
    assert all(l.fused is False for l in logs)
    assert all(l.n_wire_lost > 0 for l in logs)
    # below quorum every round: the globals never move
    for x, y in zip(jax.tree.leaves(init), jax.tree.leaves(globals_[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fusion_pod_restart_replays_wire_log(problem, tmp_path):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    wl = str(tmp_path / "wire.log")
    snap = {}

    def hook(t, globals_, state, logs, rtt):
        if t == 1:
            snap.update(globals_=list(globals_), state=state,
                        logs=[list(g) for g in logs])

    cfg = lambda: small_cfg(rounds=3, dist=DistConfig(n_pods=2,
                                                      wire_log=wl))
    full = run_rounds([net], [0] * 6, train, parts, val, test, cfg(),
                      driver="distributed", round_end_hook=hook)
    resumed = run_rounds([net], [0] * 6, train, parts, val, test, cfg(),
                         driver="distributed",
                         init_globals=snap["globals_"],
                         init_state=snap["state"],
                         init_logs=snap["logs"], start_round=2)
    _assert_same_run(full, resumed)
    # the restarted round re-dispatched nothing: its uploads came off
    # the wire log (zero uplink bytes on the wire)
    assert resumed[0][0].logs[1].wire_bytes_up == 0
    assert resumed[0][0].logs[2].wire_bytes_up > 0  # next round is live


def test_undefended_crc_off_accepts_garbage(problem):
    train, val, test, parts, _ = problem
    net = mlp(2, 3, hidden=(16,))
    got = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(dist=DistConfig(n_pods=2,
                                               verify_crc=False),
                               faults=FaultConfig(transport_corrupt=0.9)),
                     driver="distributed")
    ref = run_rounds([net], [0] * 6, train, parts, val, test,
                     small_cfg(), driver="sync")
    # with the CRC off the corrupted frames fuse; the run completes but
    # the trajectory visibly departs from the clean one
    assert [l.test_acc for l in got[0][0].logs] != \
        [l.test_acc for l in ref[0][0].logs] or not all(
            np.isfinite(np.asarray(x)).all()
            for x in jax.tree.leaves(got[1][0]))


# ---------------------------------------------------------------------------
# spec + experiment + CLI surface
# ---------------------------------------------------------------------------

def test_dist_spec_validation_and_round_trip():
    from repro.api import DistSpec, ExperimentSpec
    spec = ExperimentSpec()
    spec.dist = DistSpec(transport="loopback", wire_codec="binarize",
                         n_pods=3, heartbeat_s=0.5, upload_deadline_s=2.0)
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec and again.dist.n_pods == 3
    for bad in (DistSpec(transport="carrier-pigeon"),
                DistSpec(wire_codec="fp64"),
                DistSpec(n_pods=0),
                DistSpec(heartbeat_s=0.0),
                DistSpec(upload_deadline_s=-1.0)):
        spec.dist = bad
        with pytest.raises(ValueError, match="dist\\."):
            spec.validate()
    with pytest.raises(ValueError, match="unknown field"):
        DistSpec.from_dict({"transport": "tcp", "kill_pod": 1})


def test_faultspec_mirrors_faultconfig_fields():
    from repro.api import FaultSpec
    spec_fields = {f.name for f in dataclasses.fields(FaultSpec)}
    cfg_fields = {f.name for f in dataclasses.fields(FaultConfig)}
    # spec.validate() round-trips FaultSpec through FaultConfig, so the
    # two layers must never drift apart
    assert spec_fields == cfg_fields


def test_dist_summary_section(problem):
    from repro.api import (DistSpec, DriverSpec, Experiment,
                           ExperimentSpec, FusionSpec, PartitionSpec,
                           StrategySpec, TaskSpec)

    def mk(kind):
        return ExperimentSpec(
            task=TaskSpec(name="blobs", n_samples=400),
            partition=PartitionSpec(n_clients=4, alpha=1.0),
            strategy=StrategySpec(name="fedavg", fusion=FusionSpec(
                max_steps=40, patience=40, eval_every=20, batch_size=32)),
            driver=DriverSpec(kind=kind), dist=DistSpec(n_pods=2),
            rounds=2, client_fraction=0.5, local_epochs=2, seed=0)

    dist = Experiment(mk("distributed")).run().summary()
    assert dist["dist"]["bytes_up"] > 0
    assert dist["dist"]["bytes_down"] > 0
    assert dist["dist"]["min_pods_alive"] == 2
    sync = Experiment(mk("sync")).run().summary()
    assert "dist" not in sync  # historic shapes stay intact


def test_cli_flags_compile_and_round_trip(tmp_path):
    from repro.api import ExperimentSpec
    from repro.launch.train import build_parser, spec_from_args
    args = build_parser().parse_args([
        "--driver", "distributed", "--transport", "loopback",
        "--wire-codec", "int8", "--n-pods", "3",
        "--heartbeat-s", "0.5", "--upload-deadline-s", "2.5",
        "--wire-log", "w.log", "--faults-transport-corrupt", "0.05",
        "--faults-transport-drop", "0.01", "--rounds", "2"])
    spec = spec_from_args(args)
    assert spec.driver.kind == "distributed"
    assert spec.dist.transport == "loopback"
    assert spec.dist.wire_codec == "int8" and spec.dist.n_pods == 3
    assert spec.dist.heartbeat_s == 0.5
    assert spec.dist.upload_deadline_s == 2.5
    assert spec.dist.verify_crc is True and spec.dist.wire_log == "w.log"
    assert spec.faults.transport_corrupt == 0.05
    assert spec.faults.transport_drop == 0.01
    spec.validate()
    # --dump-config -> --config round trip is lossless
    path = str(tmp_path / "spec.json")
    spec.save(path)
    assert ExperimentSpec.load(path) == spec
    undef = spec_from_args(build_parser().parse_args(["--no-verify-crc"]))
    assert undef.dist.verify_crc is False


def test_tcp_transport_end_to_end():
    """Real subprocess pods over localhost TCP, bit-identical to sync."""
    from repro.api import (DistSpec, DriverSpec, Experiment,
                           ExperimentSpec, FusionSpec, PartitionSpec,
                           StrategySpec, TaskSpec)

    def mk(kind, dist=None):
        return ExperimentSpec(
            task=TaskSpec(name="blobs", n_samples=400),
            partition=PartitionSpec(n_clients=4, alpha=1.0),
            strategy=StrategySpec(name="fedavg", fusion=FusionSpec(
                max_steps=40, patience=40, eval_every=20, batch_size=32)),
            driver=DriverSpec(kind=kind), dist=dist or DistSpec(),
            rounds=2, client_fraction=0.5, local_epochs=2, seed=0)

    ref = Experiment(mk("sync")).run()
    got = Experiment(mk("distributed", DistSpec(
        transport="tcp", n_pods=2, upload_deadline_s=300.0))).run()
    assert [l.test_acc for l in got.results[0].logs] == \
        [l.test_acc for l in ref.results[0].logs]
    for x, y in zip(jax.tree.leaves(ref.global_params[0]),
                    jax.tree.leaves(got.global_params[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

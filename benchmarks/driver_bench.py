"""Round-driver benchmark (ISSUE 4 acceptance).

Wall-clock ROUND throughput of the async-pipelined driver against the
serial sync driver on the homogeneous K=8 toy config — the pipeline
dispatches round t+1's batched client training while round t's
FedDF/logit-bank fusion runs, so the client phase hides inside the
fusion phase (docs/drivers.md).  The config balances the two phases the
way the paper's real workloads are balanced (local training comparable
to server distillation); throughput is MARGINAL between a short and a
long run of the same config (min over reps each), so the per-run jit
compiles cancel in the difference — the distill_bench idiom.

Also recorded: the async(staleness=0) run, which must reproduce the sync
per-round accuracy log EXACTLY (the bench asserts it — prefetch alone
never changes the trajectory), and the staleness=1 final-accuracy drift.

Writes ``BENCH_driver.json`` (override with ``BENCH_DRIVER_OUT``) so
CI's driver-smoke job records the perf trajectory; emits the usual CSV
lines via ``benchmarks.common.emit``.  Timing idioms live in
``benchmarks/timing.py`` (shared with ``round_engine_bench``).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench, marginal_rate
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.drivers import make_driver

K = 8
DIM, CLASSES = 16, 10
POOL_N = 2048
OUT = os.environ.get("BENCH_DRIVER_OUT", "BENCH_driver.json")


def _problem(seed=0):
    ds = gaussian_mixture(4000, n_classes=CLASSES, dim=DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, K, 1.0, seed=seed)
    src = UnlabeledDataset(np.random.default_rng(seed + 1).uniform(
        -3, 3, (POOL_N, DIM)).astype(np.float32))
    return train, val, test, parts, src


def _config(rounds, steps):
    # local training and fusion deliberately comparable: that is the
    # regime the pipeline targets (client phase hides inside fusion)
    return FLConfig(
        strategy="feddf", rounds=rounds, client_fraction=1.0,
        local_epochs=25, local_batch_size=32, local_lr=0.05, seed=0,
        fusion=FusionConfig(max_steps=steps, patience=10 * steps,
                            eval_every=100, batch_size=128,
                            use_fused_kernel=False))


def run() -> None:
    r_short = 2
    r_long = scale(5, 8)
    steps = scale(300, 400)
    train, val, test, parts, src = _problem()
    net = mlp(DIM, CLASSES, hidden=(128, 128))

    def measure(driver_fn):
        # each run_rounds builds a fresh engine (fresh client-update jit);
        # marginal_rate's short-vs-long difference cancels the identical
        # compile cost, leaving the steady-state round throughput
        def one_run(rounds):
            cfg = _config(rounds, steps)
            results, globals_, _ = run_rounds(
                [net], [0] * K, train, parts, val, test, cfg,
                source=src, driver=driver_fn())
            jax.block_until_ready(jax.tree.leaves(globals_[0])[0])
            return results[0]

        stats, result = marginal_rate(one_run, r_short, r_long, reps=2)
        return {"wall_short_s": stats["wall_short_s"],
                "wall_long_s": stats["wall_long_s"],
                "rounds_per_s": stats["per_s"],
                "final_acc": result.final_acc}, result

    sync, r_sync = measure(lambda: "sync")
    async0, r_async0 = measure(
        lambda: make_driver("async_pipelined", staleness=0, prefetch=2))
    async1, r_async = measure(
        lambda: make_driver("async_pipelined", staleness=1, prefetch=2))

    assert [l.test_acc for l in r_async0.logs] == \
        [l.test_acc for l in r_sync.logs], \
        "async(staleness=0) must reproduce the sync trajectory exactly"
    async0["trajectory_equal"] = True

    speedup = async1["rounds_per_s"] / sync["rounds_per_s"]
    drift = abs(r_sync.final_acc - r_async.final_acc)
    rec = {
        "K": K, "dim": DIM, "classes": CLASSES, "hidden": [128, 128],
        "rounds_short": r_short, "rounds_long": r_long,
        "local_epochs": 25, "distill_steps": steps, "distill_batch": 128,
        "sync": sync, "async_staleness0": async0,
        "async_staleness1": async1,
        "speedup": speedup,
        "final_acc_drift": drift,
    }
    emit("driver_round_throughput", 1.0 / async1["rounds_per_s"],
         f"speedup_x{speedup:.2f}", record=rec)
    finish_bench("driver", rec, out=OUT,
                 config={"K": K, "dim": DIM, "classes": CLASSES,
                         "rounds_short": r_short, "rounds_long": r_long})
    print(f"wrote {OUT}: async_pipelined(staleness=1) x{speedup:.2f} over "
          f"sync ({sync['rounds_per_s']:.2f} -> "
          f"{async1['rounds_per_s']:.2f} rounds/s marginal), "
          f"final-acc drift {drift:.4f}")


if __name__ == "__main__":
    run()

"""End-to-end driver example: federated fine-tuning of a ~100k-param
transformer classifier on synthetic non-iid TEXT (the paper's
DistilBERT/AG-News setting, Figure 3) via the declarative experiment API,
plus greedy decoding with a reduced LLM config afterwards.

    PYTHONPATH=src python examples/train_e2e.py
"""
import dataclasses

from repro.api import (CohortSpec, Experiment, ExperimentSpec, FusionSpec,
                       ModelSpec, PartitionSpec, SourceSpec, StrategySpec,
                       TaskSpec)

# --- 4-class synthetic news-like token classification; the paper's Fig.3
# protocol distills on held-out unlabeled text (same manifold, no labels)
spec = ExperimentSpec(
    task=TaskSpec(name="tokens", n_samples=6000),
    partition=PartitionSpec(n_clients=10, alpha=1.0),
    cohort=CohortSpec(prototypes=[
        ModelSpec("tiny_transformer", {"d_model": 64, "n_layers": 2})]),
    strategy=StrategySpec(name="feddf",
                          fusion=FusionSpec(max_steps=400, patience=200,
                                            eval_every=50, batch_size=64)),
    source=SourceSpec(name="unlabeled", params={"n": 4000}),
    rounds=6, client_fraction=1.0, local_epochs=5, local_batch_size=32,
    local_lr=0.05, local_optimizer="adam", seed=3)

for strategy in ("fedavg", "feddf"):
    s = dataclasses.replace(
        spec, strategy=dataclasses.replace(spec.strategy, name=strategy),
        source=spec.source if strategy == "feddf" else None)
    res = Experiment(s).run()
    curve = " ".join(f"{l.test_acc:.3f}" for l in res.result.logs)
    print(f"{strategy:7s} best={res.best_acc:.3f}  rounds: {curve}")

# --- inference path: greedy decode with a reduced assigned-arch config
print("\nserving demo (gemma3-4b reduced config, ring-buffer SWA cache):")
from repro.launch.serve import main as serve_main
serve_main(["--arch", "gemma3-4b-smoke", "--batch", "2",
            "--prompt-len", "40", "--gen", "8"])

"""CI perf-regression gate over the schema'd bench history.

Every ``*_bench.py`` appends one validated record per run to
``BENCH_history.jsonl`` through :func:`benchmarks.timing.finish_bench`
(schema: ``repro.obs.history``).  This module is the single place the
acceptance thresholds live: it reads the LATEST record per
``(bench, case)`` and applies the same gates CI used to inline next to
each bench invocation — identical keys, identical thresholds, so
migrating the workflow onto this checker loosened nothing.

    PYTHONPATH=src python -m benchmarks.check_history \
        --require driver --require bucketing

``--require`` fails the run when a bench has no record at all (without
it, only benches present in the history are gated — useful locally
where you typically ran one bench).  Exit status is non-zero on any
failure; each gate prints one PASS/SKIP/FAIL line.

Gates receive the record's **machine fingerprint** next to its metrics:
thresholds that measure thread overlap (driver speedup, buffered-async
upload throughput) are physically unreachable on a single core, so on a
``cpus < 2`` record those sub-gates report *skipped* — visibly, never
silently folded into PASS — while the correctness sub-gates of the same
record still apply.
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List, Tuple

from repro.obs import history

# (errors, skips): errors fail CI, skips are sub-gates whose premise the
# record's machine can't meet (they print, they never pass silently)
GateResult = Tuple[List[str], List[str]]


def _one_core(machine: dict) -> bool:
    """True when the record was measured without thread-level parallelism
    (overlap speedups are unobtainable, not regressed)."""
    cpus = machine.get("cpus")
    return isinstance(cpus, int) and cpus < 2


def _distill(m: dict, machine: dict) -> GateResult:
    errs = []
    h, g = m["homogeneous"], m["heterogeneous"]
    if not h["speedup"] >= 1.5:
        errs.append(f"bank speedup regressed: {h['speedup']}")
    if not g["forward_reduction_x"] >= g["G"]:
        errs.append(f"hetero forward reduction {g['forward_reduction_x']} "
                    f"< G={g['G']}")
    return errs, []


def _distill_quant(m: dict, machine: dict) -> GateResult:
    errs = []
    if not m["bank_bytes_reduction_x"] >= 3.5:
        errs.append(f"int8 bank shrink regressed: "
                    f"{m['bank_bytes_reduction_x']}")
    if not m["teacher_agreement_drift"] <= 0.005:
        errs.append(f"int8 distill drift {m['teacher_agreement_drift']} "
                    f"> 0.5pt")
    if not m["marginal_steps_per_s_ratio"] >= 0.9:
        errs.append(f"int8 bank slowed distill: "
                    f"{m['marginal_steps_per_s_ratio']}")
    if len(m["roofline_records"]) != 4:  # fused/unfused x dtype
        errs.append(f"expected 4 roofline records, "
                    f"got {len(m['roofline_records'])}")
    return errs, []


def _bucketing(m: dict, machine: dict) -> GateResult:
    errs = []
    if not m["waste_reduction_x"] >= 2.0:
        errs.append(f"padding-waste reduction regressed: "
                    f"{m['waste_reduction_x']}")
    if m["trajectory_equal"] is not True:
        errs.append("bucketed trajectory drifted from unbucketed "
                    "(must be exact)")
    if not m["marginal_steps_per_s_speedup"] >= 1.1:
        errs.append(f"bucketing speedup regressed: "
                    f"{m['marginal_steps_per_s_speedup']}")
    return errs, []


def _driver(m: dict, machine: dict) -> GateResult:
    errs, skips = [], []
    if _one_core(machine):
        # training/fusion overlap needs a second core to run on; on one
        # core the speedup is definitionally ~1.0 and says nothing
        skips.append("overlap speedup (1-core machine)")
    elif not m["speedup"] >= 1.1:
        # local acceptance is >= 1.2x; shared-runner gate keeps slack
        errs.append(f"overlap speedup regressed: {m['speedup']}")
    if not m["async_staleness0"]["trajectory_equal"]:
        errs.append("async(staleness=0) trajectory drifted from sync")
    return errs, skips


def _population(m: dict, machine: dict) -> GateResult:
    errs, skips = [], []
    if m["buffered_degenerate"]["trajectory_equal"] is not True:
        errs.append("degenerate buffered_async drifted from sync "
                    "(must be exact)")
    if _one_core(machine):
        skips.append("buffered upload throughput (1-core machine)")
    elif not m["uploads_ratio"] >= 1.3:
        errs.append(f"buffered upload throughput regressed: "
                    f"{m['uploads_ratio']}")
    if not m["final_acc_drift"] <= 0.005:
        errs.append(f"buffered drift {m['final_acc_drift']} > 0.5pt")
    return errs, skips


def _robustness(m: dict, machine: dict) -> GateResult:
    errs = []
    if not abs(m["screened"]["drift"]) <= 0.01:
        errs.append(f"screened drift {m['screened']['drift']} > 1pt")
    if not (m["screened"]["finite"] and m["trimmed_mean"]["finite"]):
        errs.append("non-finite globals under faults")
    if not m["screened"]["quarantined"] > 0:
        errs.append("quarantine telemetry empty under chaos")
    # armed-but-idle fault seam costs <= 5% wall time (local
    # acceptance; CI slack for shared-runner noise)
    if not m["idle_overhead_frac"] <= 0.15:
        errs.append(f"idle fault-seam overhead {m['idle_overhead_frac']}")
    return errs, []


def _obs(m: dict, machine: dict) -> GateResult:
    errs = []
    if not m["overhead_frac"] <= 0.02:
        errs.append(f"armed flight-recorder overhead "
                    f"{m['overhead_frac']} > 2%")
    if m["trajectory_equal"] is not True:
        errs.append("armed trajectory drifted from disarmed "
                    "(must be bit-identical)")
    return errs, []


def _dist(m: dict, machine: dict) -> GateResult:
    """Distributed-runtime acceptance (benchmarks/dist_bench.py;
    docs/distributed.md)."""
    errs = []
    if m["degenerate"]["trajectory_equal"] is not True:
        errs.append("degenerate distributed drifted from sync "
                    "(must be bit-identical)")
    if not abs(m["chaos"]["drift"]) <= 0.01:
        errs.append(f"defended chaos drift {m['chaos']['drift']} > 1pt")
    if not (m["chaos"]["wire_retries"] > 0
            or m["chaos"]["deadline_misses"] > 0):
        errs.append("chaos telemetry empty (no retries/deadline misses "
                    "recorded — did the faults fire?)")
    if not m["chaos"]["min_pods_alive"] < m["chaos"]["n_pods"]:
        errs.append("chaos pod kill not observed by liveness tracking")
    if not m["undefended"]["degraded"]:
        errs.append("undefended run did not degrade (the defense gates "
                    "are not being exercised)")
    if not m["wire"]["int8_reduction_x"] >= 3.0:
        errs.append(f"int8 bytes-on-wire reduction "
                    f"{m['wire']['int8_reduction_x']} < 3x vs fp32")
    if m["restart"]["trajectory_equal"] is not True:
        errs.append("restarted fusion pod drifted from uninterrupted run")
    if not m["restart"]["replayed"] > 0:
        errs.append("restart replayed nothing from the wire log")
    return errs, []


def _paper(m: dict, machine: dict) -> GateResult:
    """Paper-table records (benchmarks/common.emit): presence + sanity —
    accuracy thresholds stay with each table's own acceptance docs.
    The timing slot may carry a derived scalar (some benches emit a
    drift there), so the gate only requires a finite non-negative
    number."""
    errs = []
    w = m.get("wall_s")
    if not (isinstance(w, (int, float)) and w >= 0 and w == w):
        errs.append(f"invalid wall_s: {w!r}")
    if not m.get("name"):
        errs.append("record has no table name")
    return errs, []


GATES: Dict[str, Callable[[dict, dict], GateResult]] = {
    "distill": _distill,
    "distill_quant": _distill_quant,
    "bucketing": _bucketing,
    "driver": _driver,
    "population": _population,
    "robustness": _robustness,
    "obs": _obs,
    "dist": _dist,
    "paper": _paper,
}


def check(path=None, require=()) -> List[str]:
    """Gate the latest record per (bench, case); returns failure strings."""
    latest = history.latest(path)
    by_bench = {}
    for (bench, case), rec in latest.items():
        by_bench.setdefault(bench, {})[case] = rec
    failures = []
    for bench in require:
        if bench not in by_bench:
            failures.append(f"{bench}: required but no history record")
    for bench in sorted(by_bench):
        gate = GATES.get(bench)
        if gate is None:
            print(f"SKIP {bench}: no gate registered")
            continue
        for case, rec in sorted(by_bench[bench].items()):
            try:
                errs, skips = gate(rec["metrics"],
                                   rec.get("machine") or {})
            except (KeyError, TypeError) as e:
                errs, skips = [f"malformed metrics: {e!r}"], []
            for e in errs:
                failures.append(f"{bench}[{case}]: {e}")
            status = "FAIL" if errs else ("SKIP" if skips else "PASS")
            print(f"{status} {bench}[{case}]"
                  + "".join(f"\n  - {e}" for e in errs)
                  + "".join(f"\n  ~ skipped: {s}" for s in skips))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", default=None,
                    help="history path (default: $BENCH_HISTORY_OUT or "
                         "BENCH_history.jsonl)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="BENCH",
                    help="fail unless this bench has a record "
                         "(repeatable)")
    args = ap.parse_args(argv)
    failures = check(args.history, args.require)
    if failures:
        print(f"{len(failures)} gate failure(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("all gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Pallas kernel validation: shape/dtype sweeps + allclose vs ref.py oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.ensemble_kl import (ensemble_kl, ensemble_kl_bank,
                                       ensemble_kl_pre)
from repro.kernels.ops import (ensemble_kl_loss, ensemble_kl_loss_bank,
                               ensemble_kl_loss_pre, ssd_scan,
                               swa_attention)
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.swa_attn import swa_attn_pallas

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ensemble_kl
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,b,v", [(1, 1, 64), (4, 8, 512), (3, 5, 300),
                                   (8, 16, 4096), (2, 3, 131)])
@pytest.mark.parametrize("temp", [1.0, 3.0])
def test_ensemble_kl_forward(k, b, v, temp):
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (b, v)) * 3
    t = jax.random.normal(k2, (k, b, v)) * 3
    got = ensemble_kl(s, t, temp)
    want = ref.ensemble_kl(s, t, temp)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k,b,v", [(4, 8, 512), (3, 5, 300)])
def test_ensemble_kl_grad(k, b, v):
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (b, v)) * 2
    t = jax.random.normal(k2, (k, b, v)) * 2
    got = jax.grad(lambda x: ensemble_kl(x, t, 1.0))(s)
    want = ref.ensemble_kl_grad(s, t, 1.0)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ensemble_kl_dtypes(dtype):
    k1, k2 = jax.random.split(KEY)
    s = (jax.random.normal(k1, (4, 256)) * 2).astype(dtype)
    t = (jax.random.normal(k2, (3, 4, 256)) * 2).astype(dtype)
    got = ensemble_kl(s, t, 1.0)
    want = ref.ensemble_kl(s, t, 1.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(got, want, rtol=tol, atol=tol)


def test_ensemble_kl_zero_when_student_equals_teacher():
    s = jax.random.normal(KEY, (4, 128))
    t = jnp.broadcast_to(s, (3, 4, 128))
    assert float(ensemble_kl(s, t, 1.0)) < 1e-6


def test_ensemble_kl_ops_wrapper_3d():
    """[B,S,V] logits path used by the LLM distill step."""
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (2, 8, 256))
    t = jax.random.normal(k2, (3, 2, 8, 256))
    got = ensemble_kl_loss(s, t)
    want = ref.ensemble_kl(s.reshape(-1, 256), t.reshape(3, -1, 256))
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ensemble_kl_pre: pre-averaged teacher rows (logit-bank fast path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,v", [(1, 64), (8, 512), (5, 300), (3, 131)])
@pytest.mark.parametrize("temp", [1.0, 3.0])
def test_ensemble_kl_pre_forward(b, v, temp):
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (b, v)) * 3
    t_avg = jax.random.normal(k2, (b, v)) * 3
    got = ensemble_kl_pre(s, t_avg, temp)
    want = ref.ensemble_kl(s, t_avg[None], temp)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-6)


def test_ensemble_kl_pre_equals_kernel_on_averaged_teachers():
    """Feeding the kernel t_avg rows == feeding it the raw [K, B, V]."""
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (6, 384)) * 2
    t = jax.random.normal(k2, (4, 6, 384)) * 2
    t_avg = jnp.mean(t, axis=0)
    assert jnp.allclose(ensemble_kl_pre(s, t_avg, 2.0),
                        ensemble_kl(s, t, 2.0), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,v", [(8, 512), (5, 300), (3, 131)])
def test_ensemble_kl_pre_grad_vs_autodiff(b, v):
    """Fused backward vs jax.grad of the jnp loss, incl. padded V tails
    (300 -> 512 lanes, 131 -> 256 lanes: the mask must keep the tail out
    of both the loss and the gradient)."""
    from repro.core.feddf import avg_logits_kl_pre
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (b, v)) * 2
    t_avg = jax.random.normal(k2, (b, v)) * 2
    got = jax.grad(lambda x: ensemble_kl_pre(x, t_avg, 1.0))(s)
    want = jax.grad(lambda x: avg_logits_kl_pre(x, t_avg, 1.0))(s)
    assert got.shape == (b, v) and not jnp.any(jnp.isnan(got))
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("v", [131, 300])
def test_ensemble_kl_grad_vs_avg_logits_kl_autodiff(v):
    """K-teacher kernel backward vs jax.grad(avg_logits_kl) at odd V."""
    from repro.core.feddf import avg_logits_kl
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (5, v)) * 2
    t = jax.random.normal(k2, (3, 5, v)) * 2
    got = jax.grad(lambda x: ensemble_kl(x, t, 2.0))(s)
    want = jax.grad(lambda x: avg_logits_kl(x, t, 2.0))(s)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-7)


def test_ensemble_kl_pre_wrapper_consistent_at_odd_v():
    """2-D entry point and the reshaping ops wrapper agree at a V that
    forces internal lane padding (131 -> 256); grad keeps the true shape.
    (Pad-region *values* can't be injected from outside — the wrappers
    zero-pad internally; value-level masking is covered by the vs-ref
    forward/grad cases at V=131/300 above.)"""
    v = 131
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (4, v))
    t_avg = jax.random.normal(k2, (4, v))
    base = ensemble_kl_pre(s, t_avg, 1.0)
    g = jax.grad(lambda x: ensemble_kl_pre(x, t_avg, 1.0))(s)
    # same rows re-padded by the wrapper to a different tile boundary
    got3d = ensemble_kl_loss_pre(s[:, None, :], t_avg[:, None, :])
    assert jnp.allclose(base, got3d, rtol=1e-5, atol=1e-6)
    assert g.shape == (4, v)


def test_ensemble_kl_pre_ops_wrapper_3d():
    """[B, S, V] bank-row path used by the LLM distill step."""
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (2, 8, 256))
    t_avg = jax.random.normal(k2, (2, 8, 256))
    got = ensemble_kl_loss_pre(s, t_avg)
    want = ref.ensemble_kl(s.reshape(-1, 256), t_avg.reshape(-1, 256)[None])
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ensemble_kl_pre_bank_dtypes(dtype):
    """bf16 bank rows stream through the kernel (fp32 math inside)."""
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (4, 256)) * 2
    t_avg = (jax.random.normal(k2, (4, 256)) * 2).astype(dtype)
    got = ensemble_kl_pre(s, t_avg, 1.0)
    want = ref.ensemble_kl(s, t_avg.astype(jnp.float32)[None], 1.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(got, want, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ensemble_kl_bank: fused gather + dequantize + log-softmax + KL
# ---------------------------------------------------------------------------

def _bank_case(b, n, v, dtype_name, seed=0):
    """(student, bank_rows, row_scale, idx) with the bank stored in
    ``dtype_name`` via the real build-pass quantizer."""
    from repro.core.logit_bank import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = jax.random.normal(ks[0], (b, v)) * 3
    bank_f32 = jax.random.normal(ks[1], (n, v)) * 3
    idx = jax.random.randint(ks[2], (b,), 0, n)
    if dtype_name == "float32":
        rows, scales = bank_f32, jnp.ones((n,), jnp.float32)
    else:
        rows, scales = quantize_rows(bank_f32, dtype_name)
    return s, rows, scales[idx], idx


# odd B, non-128-multiple V (padded vocab tail), temperature != 1
@pytest.mark.parametrize("b,n,v", [(1, 4, 64), (8, 64, 512), (5, 37, 300),
                                   (3, 16, 131), (7, 50, 2048)])
@pytest.mark.parametrize("temp", [1.0, 3.0])
@pytest.mark.parametrize("dtype_name", ["float32", "int8"])
def test_ensemble_kl_bank_forward(b, n, v, temp, dtype_name):
    s, rows, row_scale, idx = _bank_case(b, n, v, dtype_name)
    got = ensemble_kl_bank(s, rows, row_scale, idx, temp)
    want = ref.ensemble_kl_bank(s, rows, row_scale, idx, temp)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,n,v", [(8, 64, 512), (5, 37, 300), (3, 16, 131)])
@pytest.mark.parametrize("temp", [1.0, 2.0])
@pytest.mark.parametrize("dtype_name", ["float32", "int8"])
def test_ensemble_kl_bank_backward_vs_ref_autodiff(b, n, v, temp,
                                                   dtype_name):
    """Fused backward == autodiff of the jnp reference on padded/odd
    shapes (the acceptance-criteria check)."""
    s, rows, row_scale, idx = _bank_case(b, n, v, dtype_name)
    got = jax.grad(
        lambda x: ensemble_kl_bank(x, rows, row_scale, idx, temp))(s)
    want = jax.grad(
        lambda x: ref.ensemble_kl_bank(x, rows, row_scale, idx, temp))(s)
    assert got.shape == (b, v)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-6)


def test_ensemble_kl_bank_fp8_when_supported():
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no float8_e4m3fn in this jax build")
    s, rows, row_scale, idx = _bank_case(5, 20, 300, "fp8_e4m3")
    assert rows.dtype == jnp.float8_e4m3fn
    got = ensemble_kl_bank(s, rows, row_scale, idx, 2.0)
    want = ref.ensemble_kl_bank(s, rows, row_scale, idx, 2.0)
    assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5)
    g = jax.grad(
        lambda x: ensemble_kl_bank(x, rows, row_scale, idx, 2.0))(s)
    gw = jax.grad(
        lambda x: ref.ensemble_kl_bank(x, rows, row_scale, idx, 2.0))(s)
    assert jnp.allclose(g, gw, rtol=1e-4, atol=1e-6)


def test_ensemble_kl_bank_equals_pre_on_gathered_rows():
    """The fused kernel == the unfused pipeline it replaces (gather,
    dequantize, then ensemble_kl_pre)."""
    from repro.core.logit_bank import dequantize_rows, quantize_rows
    ks = jax.random.split(KEY, 3)
    s = jax.random.normal(ks[0], (6, 257)) * 2
    bank = jax.random.normal(ks[1], (40, 257)) * 4
    idx = jax.random.randint(ks[2], (6,), 0, 40)
    rows, scales = quantize_rows(bank, "int8")
    fused = ensemble_kl_bank(s, rows, scales[idx], idx, 1.0)
    unfused = ensemble_kl_pre(s, dequantize_rows(rows[idx], scales[idx]),
                              1.0)
    assert jnp.allclose(fused, unfused, rtol=1e-5, atol=1e-6)


def test_ensemble_kl_bank_ops_wrapper_jit_grad():
    """ops dispatch: scales=None (fp32 bank) and quantized banks both jit
    and differentiate through the wrapper; int idx gets no cotangent."""
    from repro.core.logit_bank import quantize_rows
    ks = jax.random.split(KEY, 3)
    s = jax.random.normal(ks[0], (4, 131))
    bank = jax.random.normal(ks[1], (12, 131)) * 3
    idx = jax.random.randint(ks[2], (4,), 0, 12)
    rows, scales = quantize_rows(bank, "int8")

    @jax.jit
    def loss_q(s):
        return ensemble_kl_loss_bank(s, rows, scales, idx, 2.0)

    @jax.jit
    def loss_f(s):
        return ensemble_kl_loss_bank(s, bank, None, idx, 2.0)

    want_q = ref.ensemble_kl_bank(s, rows, scales[idx], idx, 2.0)
    want_f = ref.ensemble_kl_bank(s, bank, jnp.ones(4), idx, 2.0)
    assert jnp.allclose(loss_q(s), want_q, rtol=1e-5, atol=1e-6)
    assert jnp.allclose(loss_f(s), want_f, rtol=1e-5, atol=1e-6)
    g = jax.grad(loss_q)(s)
    assert g.shape == s.shape and bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

SSD_CASES = [(2, 32, 4, 16, 8, 8), (1, 50, 3, 8, 16, 16), (2, 64, 8, 16, 8, 32),
             (1, 17, 2, 8, 4, 8)]


def _ssd_inputs(b, s, h, p, n):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("b,s,h,p,n,q", SSD_CASES)
def test_ssd_kernel_vs_sequential(b, s, h, p, n, q):
    x, dt, a_log, bm, cm = _ssd_inputs(b, s, h, p, n)
    want = ref.ssd_scan_sequential(x, dt, a_log, bm, cm)
    got = ssd_scan_pallas(x, dt, a_log, bm, cm, chunk=q, block_h=2)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("b,s,h,p,n,q", SSD_CASES[:2])
def test_ssd_chunked_ref_vs_sequential(b, s, h, p, n, q):
    """The model's jnp chunked path agrees with the step recurrence."""
    x, dt, a_log, bm, cm = _ssd_inputs(b, s, h, p, n)
    want = ref.ssd_scan_sequential(x, dt, a_log, bm, cm)
    got = ref.ssd_scan(x, dt, a_log, bm, cm, q)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ssd_ops_wrapper():
    x, dt, a_log, bm, cm = _ssd_inputs(1, 32, 2, 8, 4)
    got = ssd_scan(x, dt, a_log, bm, cm, chunk=8)
    want = ref.ssd_scan_sequential(x, dt, a_log, bm, cm)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# swa_attn
# ---------------------------------------------------------------------------

SWA_CASES = [
    (1, 2, 64, 16, 16, 16), (2, 2, 64, 16, None, 16), (1, 1, 100, 8, 24, 16),
    (2, 4, 128, 32, 32, 32), (1, 2, 48, 16, 200, 16), (1, 1, 16, 8, 4, 8),
]


@pytest.mark.parametrize("b,h,s,d,w,blk", SWA_CASES)
def test_swa_kernel(b, h, s, d, w, blk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    want = ref.swa_attn(q, k, v, w)
    got = swa_attn_pallas(q, k, v, w, block=blk)
    assert jnp.allclose(got, want, rtol=1e-4, atol=1e-5)


def test_swa_window_restricts_reads():
    """Windowed output must differ from full-causal when S > window."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 1, 64, 8))
    k = jax.random.normal(ks[1], (1, 1, 64, 8))
    v = jax.random.normal(ks[2], (1, 1, 64, 8))
    full = swa_attn_pallas(q, k, v, None, block=16)
    win = swa_attn_pallas(q, k, v, 8, block=16)
    assert not jnp.allclose(full, win, atol=1e-3)
    # first `window` tokens see identical context
    assert jnp.allclose(full[:, :, :8], win[:, :, :8], atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 32, 16)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 32, 16)).astype(dtype)
    want = ref.swa_attn(q, k, v, 8)
    got = swa_attn_pallas(q, k, v, 8, block=8)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert jnp.allclose(got.astype(jnp.float32), want.astype(jnp.float32),
                        rtol=tol, atol=tol)

"""Low-bit federated learning (paper §4.3, Table 4): clients train 1-bit
binarized models with the straight-through estimator; the server fuses the
low-precision ensemble into a full-precision model via distillation.

The upload quantizer is a registry name in ``PrivacySpec`` — one spec
field turns any experiment into its low-bit variant.

    PYTHONPATH=src python examples/lowbit_fl.py
"""
import dataclasses

import jax

from repro.api import (CohortSpec, Experiment, ExperimentSpec, FusionSpec,
                       ModelSpec, PartitionSpec, PrivacySpec, SourceSpec,
                       StrategySpec, TaskSpec, build_task_bundle, get_model)
from repro.core.quantize import comm_bytes

spec = ExperimentSpec(
    task=TaskSpec(name="blobs", n_samples=5000),
    partition=PartitionSpec(n_clients=10, alpha=1.0),
    cohort=CohortSpec(prototypes=[ModelSpec("mlp", {"hidden": [64, 64]})]),
    strategy=StrategySpec(name="feddf",
                          fusion=FusionSpec(max_steps=400, patience=200,
                                            eval_every=50, batch_size=64)),
    source=SourceSpec(name="unlabeled", params={"n": 3000}),
    privacy=PrivacySpec(quantizer="binarize"),
    rounds=8, client_fraction=0.4, local_epochs=20, local_batch_size=32,
    local_lr=0.1, seed=2)

# a 2-sample bundle is enough to derive the model's I/O dims for the
# uplink-size printout (the real dataset is built inside Experiment.run)
tiny = dataclasses.replace(spec, task=dataclasses.replace(spec.task,
                                                          n_samples=2))
net = get_model("mlp")(build_task_bundle(tiny), hidden=[64, 64])
p0 = net.init(jax.random.PRNGKey(0))
print(f"uplink per round: fp32={comm_bytes(p0)/1e3:.1f}kB  "
      f"binary={comm_bytes(p0, binarized=True)/1e3:.1f}kB  "
      f"({comm_bytes(p0)/comm_bytes(p0, True):.1f}x compression)")

for strategy in ("fedavg", "feddf"):
    s = dataclasses.replace(
        spec, strategy=dataclasses.replace(spec.strategy, name=strategy),
        source=spec.source if strategy == "feddf" else None)
    res = Experiment(s).run()
    print(f"{strategy:7s} (1-bit clients) best={res.best_acc:.3f}")

"""Figure 5: robustness to the distillation data source — out-of-domain
unlabeled data ≈ generator >> random noise (abrupt decline on a
'dramatically different manifold')."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated
from repro.data import (GeneratorSource, RandomNoiseSource, UnlabeledDataset)


def run(seed: int = 0) -> dict:
    rounds = scale(5, 12)
    t0 = time.time()
    train, val, test, parts, _ = default_problem(seed=seed, alpha=1.0)
    net = mlp(2, 3, hidden=(48, 48))
    # in-domain unlabeled, out-of-domain unlabeled, frozen generator, noise
    sources = {
        "in_domain": UnlabeledDataset(train.x),
        "out_of_domain": UnlabeledDataset(
            np.random.default_rng(seed + 7).uniform(-3, 3, (3000, 2))
            .astype(np.float32)),
        "generator": GeneratorSource((2,), mean=0.0, std=2.0, seed=seed),
        # noise from a *wildly* different manifold (tiny range — off-support)
        "noise_offmanifold": RandomNoiseSource((2,), low=50.0, high=60.0),
    }
    results = {}
    for name, src in sources.items():
        cfg = fl_cfg("feddf", rounds, seed=seed)
        res = run_federated(net, train, parts, val, test, cfg, source=src)
        results[name] = res.best_acc
    dt = time.time() - t0
    claims = {
        "generator_close_to_unlabeled":
            results["generator"] >= results["out_of_domain"] - 0.06,
        "offmanifold_noise_declines":
            results["noise_offmanifold"] <= results["out_of_domain"] + 0.02,
        "in_domain_best_or_close":
            results["in_domain"] >= results["out_of_domain"] - 0.03,
    }
    emit("fig5_distill_sources", dt, f"claims_ok={sum(claims.values())}/3",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

"""FedBuff-style buffered-asynchronous round driver.

Instead of one synchronized cohort per round, client training is
dispatched in WAVES over a registered population (``repro.population``):
each wave's uploads land in a virtual-time buffer after a traffic-drawn
latency, and the server aggregates as soon as ``M = buffer_size``
usable uploads have arrived — stragglers from earlier waves fuse late
with a FedAsync importance ``(1 + s)^-a`` (``s`` = fusions completed
since the upload's training base, ``a = staleness_exponent``), and
uploads older than ``max_staleness`` are discarded with telemetry
instead of poisoning the average.

Degenerate equality (pinned in tests + the population bench): with
``buffer_size == n_active``, zero latency, the uniform sampler and
``staleness=0``, every round is exactly one wave whose uploads all fuse
fresh — the trajectory is bit-identical to the ``sync`` driver.

Staleness knob (bounded <= 1 here — upload-level staleness is governed
by ``max_staleness``, not this knob):

  staleness=0  fill-then-fuse: each round's waves train from the newest
               fused globals (sync-gated; the degenerate-equality mode).
  staleness=1  the round's waves train from the PREVIOUS fusion while
               the current one runs on a worker thread — client training
               overlaps server-side distillation, at the cost of one
               extra round of upload staleness.

Quorum semantics (docs/robustness.md): with ``FaultSpec.quorum`` set, a
round whose wave dispatch cannot buffer ``M`` usable uploads (screening
quarantined too many, or the population ran out of dispatchable clients)
fuses PARTIALLY when at least ``ceil(quorum * M)`` usable uploads are
buffered, and otherwise SKIPS fusion for the round — the globals carry
over, the round is still evaluated/logged (``RoundLog.fused=False``) and
checkpointed.  ``quorum=None`` keeps the historic strict behavior: a
fill shortfall raises.

Checkpoint/resume: ``round_end_hook(t)`` state is wrapped
(``drivers.base.wrap_state``) with the full population snapshot — the
registry arrays, virtual clock, pending uploads (trained params
included) and the cohort rng's bit-generator state.  Waves-per-round is
traffic-dependent, so the rng cannot be replayed by round count like the
sync drivers do; restoring its exact state makes a resumed run's wave
schedule — and therefore its trajectory — identical to an uninterrupted
one (pinned in ``tests/test_population.py``).
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import numpy as np

from repro.common.pytree import tree_cat
from repro.core.engine import _UNSET, RoundEngine
from repro.core.strategies import GroupRound
from repro.drivers.base import Driver, register_driver, wrap_state
from repro.obs.trace import span


@register_driver("buffered_async")
class BufferedAsyncDriver(Driver):
    def __init__(self, staleness: int = 0, prefetch: int = 1):
        if staleness not in (0, 1):
            raise ValueError(
                f"buffered_async bounds the training-overlap staleness "
                f"knob to 0 or 1 (got {staleness}); upload staleness is "
                f"governed by PopulationSpec.max_staleness instead")
        super().__init__(staleness=staleness, prefetch=prefetch)

    def run(self, engine: RoundEngine, *, log_fn=None, init_globals=None,
            init_state=_UNSET, start_round=1, init_logs=None,
            round_end_hook=None):
        globals_, state, logs, rng = self._setup(
            engine, init_globals, init_state, init_logs, start_round)
        pop = engine.population()
        if self._resume_population is not None:
            pop.load_state(self._resume_population["manager"])
            # waves-per-round varies with traffic, so the cohort rng is
            # restored by exact state, not replayed by round count
            rng.bit_generator.state = _plain(
                self._resume_population["rng"])
        m = pop.buffer_size
        a = float(engine.cfg.population.staleness_exponent)
        rounds = engine.cfg.rounds
        rounds_to_target = None
        stopped = False
        fused = start_round - 1      # completed fusions (= base version)

        agg_ex = ThreadPoolExecutor(max_workers=1)
        agg_fut = None
        agg_round: Optional[int] = None
        agg_tele: Optional[dict] = None

        def aggregate_task(t, groups, st):
            out = engine.aggregate(t, groups, st)
            return (groups,) + out

        quorum = engine.cfg.faults.quorum

        def fill(t: int) -> bool:
            """Dispatch waves until M usable uploads are buffered.

            Returns False on a shortfall when a quorum is configured
            (the caller then partially fuses or skips the round); with
            ``quorum=None`` a shortfall raises, as it always has."""
            # each wave yields >= n_active * (1 - dropout) expected
            # uploads; the cap only trips on pathological configs
            max_waves = 64 + 16 * (-(-m // max(1, pop.n_active)))
            waves = 0
            while pop.usable_pending(t) < m:
                if waves >= max_waves:
                    if quorum is not None:
                        return False
                    raise RuntimeError(
                        f"round {t}: {waves} waves did not buffer "
                        f"{m} usable uploads; lower traffic.dropout / "
                        f"buffer_size or raise max_staleness")
                waves += 1
                try:
                    w, cohort = pop.next_wave(rng)
                except RuntimeError:
                    if quorum is not None:  # population exhausted
                        return False
                    raise
                # wave spans nest under the round's fill span; the
                # engine phases inside carry round=w (the WAVE number)
                with span("wave", round=t, wave=w):
                    parts = pop.registry.partition[np.asarray(cohort)]
                    batches = engine.build_round_batches(w, parts)
                    groups = engine.train_clients(w, globals_, batches)
                    pop.push_wave(w, cohort, groups, base_version=fused)
            return True

        try:
            for t in range(start_round, rounds + 1):
                if self.staleness == 0 and agg_fut is not None:
                    # sync-gated: fuse before dispatching new waves
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, pop, rng, agg_fut, agg_round, agg_tele,
                        logs, log_fn, round_end_hook)
                    agg_fut = None
                    fused = agg_round
                    if rounds_to_target is not None or stop:
                        stopped = True
                        break

                with span("fill", round=t):
                    filled = fill(t)

                if agg_fut is not None:  # staleness=1: overlap fill/fuse
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, pop, rng, agg_fut, agg_round, agg_tele,
                        logs, log_fn, round_end_hook)
                    agg_fut = None
                    fused = agg_round
                    if rounds_to_target is not None or stop:
                        stopped = True
                        break

                m_t = m
                if not filled:  # quorum semantics: partial fuse or skip
                    need = max(1, int(np.ceil(quorum * m - 1e-9)))
                    usable = pop.usable_pending(t)
                    if usable >= need:
                        m_t = usable
                    else:
                        rounds_to_target, stop = self._skip_round(
                            engine, pop, rng, t, globals_, state, logs,
                            log_fn, round_end_hook)
                        if rounds_to_target is not None or stop:
                            stopped = True
                            break
                        continue

                uploads, tele = pop.pop(t, m_t)
                groups = self._build_groups(engine, globals_,
                                            pop.regroup(uploads), a)
                agg_fut = agg_ex.submit(aggregate_task, t, groups, state)
                agg_round, agg_tele = t, tele

            if agg_fut is not None and not stopped:
                globals_, state, rounds_to_target, _ = self._finish(
                    engine, pop, rng, agg_fut, agg_round, agg_tele,
                    logs, log_fn, round_end_hook)
        finally:
            agg_ex.shutdown(wait=True, cancel_futures=True)

        return self._results(engine, logs, globals_, rounds_to_target)

    # -- helpers ----------------------------------------------------------

    @staticmethod
    def _build_groups(engine, globals_, per_proto, a) -> List[GroupRound]:
        """Consumed uploads -> per-prototype GroupRounds.  All-fresh
        rounds keep ``importance=None`` so aggregation stays on the
        historic bit-identical path."""
        groups: List[GroupRound] = []
        for p in range(engine.n_proto):
            e = per_proto.get(p)
            if e is None:
                groups.append(GroupRound(engine.nets[p], globals_[p], None,
                                         np.zeros(0)))
                continue
            stack = tree_cat(e["params"])
            weights = np.asarray(e["weights"], np.float64)
            s = np.asarray(e["staleness"], np.float64)
            imp = None if not s.any() else (1.0 + s) ** (-a)
            groups.append(GroupRound(engine.nets[p], globals_[p], stack,
                                     weights, importance=imp))
        return groups

    def _skip_round(self, engine, pop, rng, t, globals_, state, logs,
                    log_fn, round_end_hook):
        """Quorum shortfall: evaluate the carried globals without fusing,
        stamp ``fused=False`` + fault telemetry, checkpoint as usual."""
        groups = [GroupRound(engine.nets[p], globals_[p], None, np.zeros(0))
                  for p in range(engine.n_proto)]
        round_logs = engine.evaluate_round(
            t, globals_, groups, [{} for _ in range(engine.n_proto)],
            [0] * engine.n_proto, None)
        fc = pop.fault_counters(reset=True)
        for log in round_logs:
            log.fused = False
            log.n_corrupted = fc["n_corrupted"]
            log.n_quarantined = fc["n_quarantined"]
            log.n_retries = fc["n_retries"]
        reached, stop_requested = self._emit_round(engine, t, round_logs,
                                                   logs, log_fn)
        rounds_to_target = t if reached else None
        if round_end_hook is not None:
            hook_state = wrap_state(
                state, globals_,
                population={"manager": pop.state_dict(),
                            "rng": rng.bit_generator.state})
            round_end_hook(t, globals_, hook_state, logs, rounds_to_target)
        return rounds_to_target, stop_requested

    def _finish(self, engine, pop, rng, agg_fut, t, tele, logs, log_fn,
                round_end_hook):
        """Join round t's fusion, stamp population telemetry onto its
        logs, and checkpoint with the full population snapshot."""
        # idle gap: the driver thread blocked on the fusion worker
        with span("join_fusion", round=t):
            groups, globals_, state, infos, dropped, ens_acc = \
                agg_fut.result()
        globals_, rolled = engine.guard_globals(
            globals_, [g.prev_global for g in groups])
        round_logs = engine.evaluate_round(t, globals_, groups, infos,
                                           dropped, ens_acc)
        for p, log in enumerate(round_logs):
            log.staleness_hist = list(tele["staleness_hist"])
            log.buffer_fill = int(tele["buffer_fill"])
            log.n_straggling = int(tele["n_straggling"])
            log.n_dropped_uploads = int(tele["n_dropped_uploads"])
            log.n_stale_dropped = int(tele["n_stale_dropped"])
            log.eff_participants = float(tele["eff_participants"])
            log.n_corrupted = int(tele.get("n_corrupted", 0))
            log.n_quarantined = int(tele.get("n_quarantined", 0))
            log.n_retries = int(tele.get("n_retries", 0))
            log.rolled_back = bool(log.rolled_back or rolled[p])
        reached, stop_requested = self._emit_round(engine, t, round_logs,
                                                   logs, log_fn)
        rounds_to_target = t if reached else None
        if round_end_hook is not None:
            hook_state = wrap_state(
                state, globals_,
                population={"manager": pop.state_dict(),
                            "rng": rng.bit_generator.state})
            round_end_hook(t, globals_, hook_state, logs, rounds_to_target)
        return globals_, state, rounds_to_target, stop_requested


def _plain(rng_state):
    """Bit-generator state with checkpoint-roundtripped numpy scalars
    coerced back to builtin ints (numpy requires exact types here)."""
    if isinstance(rng_state, dict):
        return {k: _plain(v) for k, v in rng_state.items()}
    if isinstance(rng_state, np.ndarray):
        return rng_state
    if isinstance(rng_state, (np.integer,)):
        return int(rng_state)
    return rng_state

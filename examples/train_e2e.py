"""End-to-end driver example: federated fine-tuning of a ~100k-param
transformer classifier on synthetic non-iid TEXT (the paper's
DistilBERT/AG-News setting, Figure 3) for a few hundred total local steps,
plus greedy decoding with a reduced LLM config afterwards.

    PYTHONPATH=src python examples/train_e2e.py
"""
import numpy as np

from repro.core import (FLConfig, FusionConfig, run_federated,
                        tiny_transformer)
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        token_sequences, train_val_test_split)

# --- 4-class synthetic news-like token classification
ds = token_sequences(6000, n_classes=4, vocab=64, seq_len=16, seed=3)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, n_clients=10, alpha=1.0, seed=3)
net = tiny_transformer(vocab=64, n_classes=4, seq_len=16, d_model=64,
                       n_layers=2)

# the paper's Fig.3 protocol: held-out unlabeled text as distillation data
pool = token_sequences(4000, n_classes=4, vocab=64, seq_len=16, seed=11).x
source = UnlabeledDataset(pool)

for strategy in ("fedavg", "feddf"):
    cfg = FLConfig(strategy=strategy, rounds=6, client_fraction=1.0,
                   local_epochs=5, local_batch_size=32, local_lr=0.05,
                   local_optimizer="adam", seed=3,
                   fusion=FusionConfig(max_steps=400, patience=200,
                                       eval_every=50, batch_size=64))
    res = run_federated(net, train, parts, val, test, cfg,
                        source=source if strategy == "feddf" else None)
    curve = " ".join(f"{l.test_acc:.3f}" for l in res.logs)
    print(f"{strategy:7s} best={res.best_acc:.3f}  rounds: {curve}")

# --- inference path: greedy decode with a reduced assigned-arch config
print("\nserving demo (gemma3-4b reduced config, ring-buffer SWA cache):")
from repro.launch.serve import main as serve_main
serve_main(["--arch", "gemma3-4b-smoke", "--batch", "2",
            "--prompt-len", "40", "--gen", "8"])

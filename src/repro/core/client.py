"""Client-side local training (Algorithm 2).

One jit-compiled ``lax.scan`` runs all local steps of a round: the batches
for every epoch are materialised as arrays [n_steps, B, ...] outside and
scanned inside — orders of magnitude faster than a python loop on CPU, and
the compiled function is reused across clients and rounds (same shapes).

Supports: plain SGD (FedAvg), proximal term (FedProx, Appendix B), arbitrary
optimizers (the paper's Adam-local-training ablation, Table 6), BatchNorm
running-stats maintenance, and a quantize transform for low-bit clients
(Table 4, straight-through estimator).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import tree_sq_dist
from repro.core.nets import Net
from repro.optim.optimizers import Optimizer, apply_updates


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))


def make_local_update(net: Net, opt: Optimizer, *, prox_mu: float = 0.0,
                      quantize: Optional[Callable] = None):
    """Returns jit'd fn(params, xb [n,B,...], yb [n,B], anchor) -> params.

    ``anchor`` is the round's global model (FedProx pulls towards it; pass
    the initial params when prox_mu == 0, it is ignored).
    """

    def loss_fn(params, x, y):
        p = quantize(params) if quantize is not None else params
        logits, stats = net.apply_with_stats(p, x)
        loss = softmax_xent(logits, y)
        return loss, stats

    @jax.jit
    def run(params, xb, yb, anchor):
        state = opt.init(params)
        mask = net.trainable_mask(params)

        def step(carry, batch):
            params, state, i = carry
            x, y = batch

            def total_loss(p):
                loss, stats = loss_fn(p, x, y)
                if prox_mu > 0.0:
                    loss = loss + 0.5 * prox_mu * tree_sq_dist(p, anchor)
                return loss, stats

            grads, stats = jax.grad(total_loss, has_aux=True)(params)
            grads = jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                                 grads, mask)
            deltas, state = opt.update(grads, state, params, i)
            new_params = apply_updates(params, deltas)
            # take BN running stats from the forward pass (non-trainable)
            new_params = jax.tree.map(
                lambda new, st, m: new if m else st.astype(new.dtype),
                new_params, stats, mask)
            return (new_params, state, i + 1), None

        (params, _, _), _ = jax.lax.scan(step, (params, state, jnp.int32(0)),
                                         (xb, yb))
        return params

    return run


def build_batches(x: np.ndarray, y: np.ndarray, batch_size: int, epochs: int,
                  seed: int):
    """[n_steps, B, ...] arrays for the scanned local update."""
    rng = np.random.default_rng(seed)
    n = len(y)
    steps_per_epoch = max(1, n // batch_size)
    xs, ys = [], []
    for _ in range(epochs):
        if n >= batch_size:
            order = rng.permutation(n)[: steps_per_epoch * batch_size]
        else:
            order = rng.choice(n, size=batch_size, replace=True)
        xe = x[order].reshape(steps_per_epoch, batch_size, *x.shape[1:])
        ye = y[order].reshape(steps_per_epoch, batch_size)
        xs.append(xe)
        ys.append(ye)
    return np.concatenate(xs), np.concatenate(ys)


_EVAL_CACHE: dict = {}


def _eval_fn(net: Net):
    fn = _EVAL_CACHE.get(id(net))
    if fn is None:
        fn = jax.jit(lambda pp, xx: jnp.argmax(net.apply(pp, xx, train=False),
                                               axis=-1))
        _EVAL_CACHE[id(net)] = fn
    return fn


def evaluate(net: Net, params: dict, x: np.ndarray, y: np.ndarray,
             batch_size: int = 512, quantize: Optional[Callable] = None
             ) -> float:
    """Top-1 accuracy in eval mode (BN uses running stats)."""
    p = quantize(params) if quantize is not None else params
    apply = _eval_fn(net)
    correct = 0
    for s in range(0, len(y), batch_size):
        xb = jnp.asarray(x[s : s + batch_size])
        yb = y[s : s + batch_size]
        pred = np.asarray(apply(p, xb))
        correct += int((pred == yb).sum())
    return correct / len(y)

"""LR schedules: constant (paper's local training), cosine (paper's
server-side distillation), WSD warmup-stable-decay (MiniCPM,
arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32)
    return sched


def cosine(lr: float, total_steps: int, final_frac: float = 0.0):
    def sched(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * (final_frac + (1 - final_frac) * cos),
                           jnp.float32)
    return sched


def wsd(lr: float, total_steps: int, warmup_frac: float = 0.03,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long stable plateau, sharp
    exponential-ish (linear here) decay tail."""
    w = max(int(total_steps * warmup_frac), 1)
    d = max(int(total_steps * decay_frac), 1)
    stable_end = total_steps - d

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / w
        tail = 1.0 - (1.0 - final_frac) * (step - stable_end) / d
        val = jnp.where(step < w, warm,
                        jnp.where(step < stable_end, 1.0, tail))
        return jnp.asarray(lr * jnp.clip(val, final_frac, 1.0), jnp.float32)

    return sched


def make_schedule(kind: str, lr: float, total_steps: int):
    if kind == "cosine":
        return cosine(lr, total_steps)
    if kind == "wsd":
        return wsd(lr, total_steps)
    return constant(lr)

"""Step builders: per (architecture x input-shape) jittable programs with
their sharding specs and ShapeDtypeStruct input stand-ins.

  train_4k     -> train_step   (fwd + next-token loss + grad + Adam update)
  prefill_32k  -> prefill_step (full-prompt forward, returns caches)
  decode_32k   -> serve_step   (ONE new token against a seq_len KV cache)
  long_500k    -> serve_step   (sub-quadratic archs only)
  (extra)      -> distill_step (FedDF server fusion: K teachers + student)
  (extra)      -> fed_round_step (K clients' local-SGD loops, client axis
                  sharded over the data axes — the round engine's batched
                  client path at production scale; driven round-over-round
                  by ``repro.drivers.multihost.drive_fed_rounds``)

Everything here is allocation-free: inputs and parameters are
ShapeDtypeStructs; `repro.launch.dryrun` lowers + compiles the result.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.arch_config import ArchConfig
from repro.common import sharding as shd
from repro.configs.shapes import InputShape
from repro.kernels import ref as kref
from repro.models import transformer as T
from repro.optim.optimizers import AdamState, adam, apply_updates


@dataclasses.dataclass
class StepBundle:
    """A jittable fn + the arg structure needed to lower it."""

    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        """The jitted step with this bundle's shardings + donation.
        Driver loops (``repro.drivers.multihost.drive_fed_rounds``) call
        this once and reuse the result every round; inputs must be
        ``jax.device_put`` to ``in_shardings`` (``lower`` remains the
        allocation-free AOT inspection path)."""
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self, mesh: Mesh):
        with mesh:
            return self.jit().lower(*self.args)


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape,
                act_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one batch (weak-type-correct,
    shardable, no device allocation)."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dtype)
    else:
        n_text = s
        if cfg.frontend == "vision_patches" and shape.kind != "decode":
            n_text = max(s - cfg.n_frontend_tokens, 1)
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), act_dtype)
        batch["tokens"] = jax.ShapeDtypeStruct((b, n_text), jnp.int32)
    if shape.kind == "train":
        lab_s = s if cfg.frontend != "vision_patches" else s  # full positions
        batch["labels"] = jax.ShapeDtypeStruct((b, lab_s), jnp.int32)
    return batch


def batch_pspecs(cfg: ArchConfig, shape: InputShape, rules: shd.Rules
                 ) -> Dict[str, P]:
    bsp = shd.logical_to_pspec(("batch", None), rules)
    b3 = shd.logical_to_pspec(("batch", None, None), rules)
    out = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = b3
    else:
        out["tokens"] = bsp
        if cfg.frontend == "vision_patches" and shape.kind != "decode":
            out["patches"] = b3
    if shape.kind == "train":
        out["labels"] = bsp
    return out


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def token_xent_naive(logits: jax.Array, labels: jax.Array,
                     cfg: ArchConfig) -> jax.Array:
    """v0 loss kept for the §Perf record: slices logits and gathers the
    label logit with take_along_axis — both break SPMD locality on a
    vocab-sharded tensor (measured: ~40 GB/device logits all-gathers)."""
    if cfg.frontend == "vision_patches":
        logits = logits[:, cfg.n_frontend_tokens:]
        labels = labels[:, : logits.shape[1]]
    if cfg.is_decoder:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
    return jnp.mean(nll)


def token_xent(logits: jax.Array, labels: jax.Array,
               cfg: ArchConfig) -> jax.Array:
    """Next-token LM loss for decoders; per-frame classification for
    encoders.  VLM: the prepended patch positions are masked out.

    Written SHARD-AWARE in both the vocab and sequence dimensions:
    (1) ``take_along_axis`` on vocab-sharded logits makes XLA all-gather the
    full [B,S,V] fp32 logits; the one-hot-select + logsumexp form keeps all
    reductions shard-local.  (2) slicing the sequence (``logits[:, :-1]``)
    de-aligns the unembed backward contraction and triggers a global-batch
    all-gather of the logits (~40 GB/device for qwen3-8b, measured — see
    EXPERIMENTS §Perf); rolling the LABELS and masking keeps logits intact.
    """
    b, s = logits.shape[0], logits.shape[1]
    pos = jnp.arange(s)[None, :]
    if cfg.is_decoder:
        targets = jnp.roll(labels, -1, axis=1)
        mask = (pos < s - 1).astype(jnp.float32)
    else:
        targets = labels
        mask = jnp.ones((1, s), jnp.float32)
    if cfg.frontend == "vision_patches":
        mask = mask * (pos >= cfg.n_frontend_tokens)
    lg = logits.astype(jnp.float32)
    z = jax.nn.logsumexp(lg, axis=-1)                       # [B,S]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_ids == targets[..., None], lg, 0.0),
                     axis=-1)                               # [B,S]
    return jnp.sum((z - picked) * mask) / jnp.sum(mask * jnp.ones((b, 1)))


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def _param_structs(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: T.init(cfg, jax.random.PRNGKey(0), dtype))


def _opt_structs(params):
    return jax.eval_shape(lambda p: adam(1e-3).init(p), params)


def _shardings(mesh: Mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def make_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                    fsdp: bool = True, remat: bool = True,
                    use_moe_shard_map: bool = True, unroll: bool = False,
                    naive_xent: bool = False, layout: str = "tp",
                    constrain_acts: bool = False, microbatch: int = 1,
                    param_dtype=jnp.bfloat16) -> StepBundle:
    multi_pod = "pod" in mesh.axis_names
    rules = shd.make_rules(multi_pod=multi_pod, fsdp=fsdp, layout=layout)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    moe_mesh = mesh if use_moe_shard_map else None
    act_sh = (NamedSharding(mesh, shd.logical_to_pspec(
        ("batch", None, None), rules)) if constrain_acts else None)

    params = _param_structs(cfg, param_dtype)
    opt_state = _opt_structs(params)
    batch = input_specs(cfg, shape)
    step = jax.ShapeDtypeStruct((), jnp.int32)

    opt = adam(3e-4)

    def loss_for(params, mb):
        def loss_fn(p):
            logits, aux = T.forward(p, cfg, mb, mesh=moe_mesh,
                                    dp_axes=dp_axes, remat=remat,
                                    unroll=unroll, act_sharding=act_sh)
            xent = token_xent_naive if naive_xent else token_xent
            loss = xent(logits, mb["labels"], cfg)
            return loss + cfg.router_aux_coef * aux, (loss, aux)
        return loss_fn

    def train_step(params, opt_state, step, batch):
        if microbatch == 1:
            grads, (loss, aux) = jax.grad(loss_for(params, batch),
                                          has_aux=True)(params)
        else:
            # gradient accumulation: scan over microbatch slices so the
            # live activation set is 1/microbatch of the global batch
            # (the HBM-fit lever for dp_heavy layouts — §Perf-A4)
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc, a_acc = carry
                g, (l, a) = jax.grad(loss_for(params, mb),
                                     has_aux=True)(params)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss, aux = loss / microbatch, aux / microbatch
        deltas, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, deltas)
        return params, opt_state, step + 1, {"loss": loss, "moe_aux": aux}

    p_specs = shd.fit_pspecs(shd.tree_pspecs(T.logical(cfg), rules),
                             params, mesh)
    o_specs = AdamState(p_specs, p_specs)
    b_specs = shd.fit_pspecs(batch_pspecs(cfg, shape, rules), batch, mesh)
    in_shardings = (_shardings(mesh, p_specs), _shardings(mesh, o_specs),
                    NamedSharding(mesh, P()), _shardings(mesh, b_specs))
    out_shardings = (in_shardings[0], in_shardings[1],
                     NamedSharding(mesh, P()),
                     {"loss": NamedSharding(mesh, P()),
                      "moe_aux": NamedSharding(mesh, P())})
    return StepBundle(train_step, (params, opt_state, step, batch),
                      in_shardings, out_shardings, donate_argnums=(0, 1))


def make_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                      fsdp: bool = True, unroll: bool = False,
                      layout: str = "tp", constrain_acts: bool = False,
                      param_dtype=jnp.bfloat16) -> StepBundle:
    multi_pod = "pod" in mesh.axis_names
    rules = shd.make_rules(multi_pod=multi_pod, fsdp=fsdp, layout=layout)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    act_sh = (NamedSharding(mesh, shd.logical_to_pspec(
        ("batch", None, None), rules)) if constrain_acts else None)

    params = _param_structs(cfg, param_dtype)
    batch = input_specs(cfg, shape)
    max_seq = shape.seq_len

    def prefill_step(params, batch):
        # mesh routes MoE blocks through the expert-parallel shard_map
        # (without it the global capacity path lowers to ~34 GB/layer of
        # partitioner-chosen gathers — see EXPERIMENTS §Perf-MoE)
        logits, caches = T.prefill(params, cfg, batch, max_seq,
                                   unroll=unroll, act_sharding=act_sh,
                                   mesh=mesh, dp_axes=dp_axes)
        return logits[:, -1:], caches  # next-token logits + state

    p_specs = shd.fit_pspecs(shd.tree_pspecs(T.logical(cfg), rules),
                             params, mesh)
    b_specs = shd.fit_pspecs(batch_pspecs(cfg, shape, rules), batch, mesh)
    cache_rules = shd.kv_cache_rules(
        rules, batch=shape.global_batch, data_size=mesh.shape["data"])
    cache_structs = jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, max_seq, jnp.bfloat16))
    c_specs = shd.fit_pspecs(
        shd.tree_pspecs(T.cache_logical(cfg), cache_rules), cache_structs,
        mesh)
    logits_spec = shd.fit_pspec(
        shd.logical_to_pspec(("batch", None, "vocab"), rules),
        (shape.global_batch, 1, cfg.vocab_size), mesh)
    in_shardings = (_shardings(mesh, p_specs), _shardings(mesh, b_specs))
    out_shardings = (NamedSharding(mesh, logits_spec),
                     _shardings(mesh, c_specs))
    return StepBundle(prefill_step, (params, batch), in_shardings,
                      out_shardings)


def make_serve_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                    fsdp: bool = True, unroll: bool = False,
                    param_dtype=jnp.bfloat16,
                    cache_dtype=jnp.bfloat16) -> StepBundle:
    """One-token decode against a populated cache of shape.seq_len tokens."""
    multi_pod = "pod" in mesh.axis_names
    rules = shd.make_rules(multi_pod=multi_pod, fsdp=fsdp)

    params = _param_structs(cfg, param_dtype)
    batch = input_specs(cfg, shape)
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len,
                              cache_dtype))
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, batch, caches, cur_len):
        # NOTE: mesh is deliberately NOT passed — routing decode through the
        # expert-parallel shard_map FSDP-gathers every local expert's
        # weights per layer (measured: collective 0.029 s -> 1.18 s on
        # qwen3-moe decode_32k, §Perf-MoE); the partitioner path touches
        # only the experts the 1-token batch routes to.
        logits, new_caches = T.decode_step(params, cfg, batch, caches,
                                           cur_len, unroll=unroll)
        return logits, new_caches

    cache_rules = shd.kv_cache_rules(
        rules, batch=shape.global_batch, data_size=mesh.shape["data"])
    p_specs = shd.fit_pspecs(shd.tree_pspecs(T.logical(cfg), rules),
                             params, mesh)
    b_specs = shd.fit_pspecs(batch_pspecs(cfg, shape, cache_rules), batch,
                             mesh)
    c_specs = shd.fit_pspecs(
        shd.tree_pspecs(T.cache_logical(cfg), cache_rules), caches, mesh)
    logits_spec = shd.fit_pspec(
        shd.logical_to_pspec(("batch", None, "vocab"), cache_rules),
        (shape.global_batch, 1, cfg.vocab_size), mesh)
    in_shardings = (_shardings(mesh, p_specs), _shardings(mesh, b_specs),
                    _shardings(mesh, c_specs), NamedSharding(mesh, P()))
    out_shardings = (NamedSharding(mesh, logits_spec),
                     _shardings(mesh, c_specs))
    return StepBundle(serve_step, (params, batch, caches, cur_len),
                      in_shardings, out_shardings, donate_argnums=(2,))


def make_distill_step(cfg: ArchConfig, mesh: Mesh, *, n_teachers: int = 4,
                      batch_size: int = 128, seq_len: int = 512,
                      fsdp: bool = True, unroll: bool = False,
                      constrain_acts: bool = False, remat: bool = True,
                      param_dtype=jnp.bfloat16) -> StepBundle:
    """FedDF's server-fusion hot loop on the pod: K stacked teacher forwards
    (vmapped over a leading "clients" axis) + one student AVGLOGITS update.

    The loss is the jnp reference (the Pallas kernel targets real TPU; its
    interpret-mode HLO would distort the roofline terms)."""
    multi_pod = "pod" in mesh.axis_names
    rules = shd.make_rules(multi_pod=multi_pod, fsdp=fsdp)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    act_sh = (NamedSharding(mesh, shd.logical_to_pspec(
        ("batch", None, None), rules)) if constrain_acts else None)

    student = _param_structs(cfg, param_dtype)
    teachers = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_teachers,) + s.shape, s.dtype),
        student)
    opt_state = _opt_structs(student)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)}
    opt = adam(1e-3)

    def distill_step(student, teachers, opt_state, step, batch):
        t_logits, _ = jax.vmap(
            lambda p: T.forward(p, cfg, batch, unroll=unroll,
                                act_sharding=act_sh))(teachers)

        def loss_fn(p):
            s_logits, aux = T.forward(p, cfg, batch, mesh=None,
                                      dp_axes=dp_axes,
                                      remat=remat and not unroll,
                                      unroll=unroll, act_sharding=act_sh)
            v = s_logits.shape[-1]
            loss = kref.ensemble_kl(
                s_logits.reshape(-1, v),
                t_logits.reshape(n_teachers, -1, v))
            return loss + cfg.router_aux_coef * aux, loss

        grads, loss = jax.grad(loss_fn, has_aux=True)(student)
        deltas, opt_state = opt.update(grads, opt_state, student, step)
        student = apply_updates(student, deltas)
        return student, opt_state, step + 1, loss

    p_specs = shd.fit_pspecs(shd.tree_pspecs(T.logical(cfg), rules),
                             student, mesh)
    # teachers: leading clients axis replicated, inner dims like the student
    t_specs = jax.tree.map(lambda s: P(None, *tuple(s)), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    o_specs = AdamState(p_specs, p_specs)
    b_specs = shd.fit_pspecs(
        {"tokens": shd.logical_to_pspec(("batch", None), rules)}, batch,
        mesh)
    in_shardings = (_shardings(mesh, p_specs), _shardings(mesh, t_specs),
                    _shardings(mesh, o_specs), NamedSharding(mesh, P()),
                    _shardings(mesh, b_specs))
    out_shardings = (in_shardings[0], in_shardings[2],
                     NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    return StepBundle(distill_step, (student, teachers, opt_state, step,
                                     batch), in_shardings, out_shardings,
                      donate_argnums=(0, 2))


def make_fed_round_step(cfg: ArchConfig, mesh: Mesh, *, n_clients: int = 8,
                        local_steps: int = 4, batch_size: int = 8,
                        seq_len: int = 512, remat: bool = True,
                        unroll: bool = False, lr: float = 3e-4,
                        param_dtype=jnp.bfloat16) -> StepBundle:
    """One federated round's client phase on the pod: K clients' stacked
    params [K, ...] run ``local_steps`` of local SGD in a vmapped scan,
    with the leading client axis sharded over the data axes
    (``shard_clients`` rules) — the production-mesh counterpart of
    ``core/client.make_batched_local_update``.

    fsdp is off: each client's full replica lives on its data-axis slice;
    tensor parallelism over "model" still applies within a client."""
    multi_pod = "pod" in mesh.axis_names
    rules = shd.make_rules(multi_pod=multi_pod, fsdp=False,
                           shard_clients=True)

    params = _param_structs(cfg, param_dtype)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, s.dtype),
        params)
    batch = {
        "tokens": jax.ShapeDtypeStruct(
            (n_clients, local_steps, batch_size, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (n_clients, local_steps, batch_size, seq_len), jnp.int32),
    }

    def fed_round_step(stacked_params, batch):
        def one_client(p0, toks, labels):
            def step(p, tl):
                t, l = tl

                def loss_fn(pp):
                    logits, aux = T.forward(
                        pp, cfg, {"tokens": t, "labels": l},
                        remat=remat and not unroll, unroll=unroll)
                    return (token_xent(logits, l, cfg)
                            + cfg.router_aux_coef * aux)

                g = jax.grad(loss_fn)(p)
                p = jax.tree.map(
                    lambda w, gw: (w - lr * gw.astype(jnp.float32)
                                   ).astype(w.dtype), p, g)
                return p, None

            p, _ = jax.lax.scan(step, p0, (toks, labels))
            return p

        return jax.vmap(one_client)(stacked_params, batch["tokens"],
                                    batch["labels"])

    p_specs = shd.fit_pspecs(shd.tree_pspecs(T.logical(cfg), rules),
                             params, mesh)
    client_axes = shd.logical_to_pspec(("clients",), rules)[0]
    s_specs = jax.tree.map(lambda s: P(client_axes, *tuple(s)), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    s_specs = shd.fit_pspecs(s_specs, stacked, mesh)
    b_specs = jax.tree.map(
        lambda s: shd.fit_pspec(P(client_axes), s.shape, mesh), batch)
    in_shardings = (_shardings(mesh, s_specs), _shardings(mesh, b_specs))
    out_shardings = in_shardings[0]
    return StepBundle(fed_round_step, (stacked, batch), in_shardings,
                      out_shardings, donate_argnums=(0,))


def make_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
              **kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, **kw)
    kw.pop("remat", None)
    kw.pop("use_moe_shard_map", None)
    kw.pop("naive_xent", None)
    kw.pop("microbatch", None)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh, **kw)
    kw.pop("constrain_acts", None)  # decode: cache rules govern layout
    kw.pop("layout", None)
    return make_serve_step(cfg, shape, mesh, **kw)

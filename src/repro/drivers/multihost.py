"""Multi-host round driver: sync semantics, client axis sharded over a
device/host mesh.

Two entry points at two scales:

* :class:`MultiHostDriver` — the experiment path.  Attaches a 1-D client
  mesh (``launch/mesh.py:make_client_mesh``) to the
  :class:`~repro.core.engine.RoundEngine` so the K active clients of the
  batched vmap-over-clients update train data-parallel across devices
  (``shard_map``).  Unbucketed homogeneous runs require K to divide the
  device count; heterogeneous and bucketed runs pad their run-fixed
  per-(prototype, bucket) client capacities up to mesh divisibility
  instead (padded lanes carry all-False step masks and are sliced off),
  so skewed hetero cohorts shard too — see docs/bucketing.md.  Runs on
  real accelerators or a
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` simulated host
  mesh.  Round semantics are exactly the sync driver's.

* :func:`drive_fed_rounds` — the production-scale path.
  ``launch/steps.py:make_fed_round_step`` lowers one federated round's
  client phase (K transformer clients' local-SGD scans, client axis
  sharded over the mesh's data axes) but historically had NO driver loop.
  This is that loop: compile the step once, then per round broadcast the
  global model to the stacked client axis, run the local-SGD step on the
  mesh, and FedAvg the uploads back into the global.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.drivers.base import register_driver
from repro.drivers.sync import SyncDriver


@register_driver("multihost")
class MultiHostDriver(SyncDriver):
    """Sync driver over a client-sharded mesh.  Heterogeneous / bucketed
    engines pad their run-fixed per-bucket client capacities up to mesh
    divisibility (``RoundEngine.attach_mesh``), so they shard exactly
    like homogeneous cohorts."""

    def __init__(self, staleness: int = 0, prefetch: int = 1, mesh=None):
        super().__init__(staleness=staleness, prefetch=prefetch)
        self._mesh = mesh

    def run(self, engine, **kw):
        if engine.mesh is None:
            from repro.launch.mesh import make_client_mesh
            mesh = self._mesh if self._mesh is not None else \
                make_client_mesh()
            engine.attach_mesh(mesh, client_axis=engine.client_axis)
        return super().run(engine, **kw)


def drive_fed_rounds(cfg, mesh, *, rounds: int = 2, n_clients: int = 4,
                     local_steps: int = 2, batch_size: int = 2,
                     seq_len: int = 32, lr: float = 3e-4, seed: int = 0,
                     vocab: Optional[int] = None, param_dtype=None
                     ) -> Tuple[dict, List[dict]]:
    """Driver loop for the production fed-round step on a mesh.

    ``cfg`` is an :class:`~repro.common.arch_config.ArchConfig`; the step
    is compiled ONCE and reused every round.  Returns ``(final global
    params, per-round stats)`` where each stats dict records the round's
    global-update L2 norm (the convergence signal a coordinator would
    ship to monitoring).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.steps import make_fed_round_step
    from repro.models import transformer as T

    if param_dtype is None:
        param_dtype = jnp.float32
    bundle = make_fed_round_step(cfg, mesh, n_clients=n_clients,
                                 local_steps=local_steps,
                                 batch_size=batch_size, seq_len=seq_len,
                                 lr=lr, param_dtype=param_dtype)
    step = bundle.jit()  # compiled once, reused every round
    params = T.init(cfg, jax.random.PRNGKey(seed), param_dtype)
    v = vocab if vocab is not None else cfg.vocab_size
    rng = np.random.default_rng(seed)
    stats: List[dict] = []
    with mesh:
        for t in range(1, rounds + 1):
            # broadcast the global to the stacked client axis ([K, ...])
            # and place it on the mesh per the step's specs; the step
            # donates this buffer, so a fresh stack is materialised per
            # round (exactly the coordinator's per-round model push)
            stacked = jax.device_put(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p[None],
                                               (n_clients,) + p.shape),
                    params),
                bundle.in_shardings[0])
            toks = rng.integers(
                0, v, (n_clients, local_steps, batch_size, seq_len),
                dtype=np.int32)
            batch = jax.device_put(
                {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)},
                bundle.in_shardings[1])
            new_stack = step(stacked, batch)
            new_params = jax.tree.map(
                lambda s: jnp.mean(s.astype(jnp.float32), axis=0
                                   ).astype(s.dtype), new_stack)
            delta = sum(
                float(jnp.sum((jnp.asarray(a, jnp.float32)
                               - jnp.asarray(b, jnp.float32)) ** 2))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params))) ** 0.5
            params = new_params
            stats.append({"round": t, "update_norm": delta})
    return params, stats

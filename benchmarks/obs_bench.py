"""Flight-recorder overhead benchmark (observability acceptance).

Armed-vs-disarmed wall clock of the sync toy config: the ISSUE budget
is <= 2% wall overhead with tracing armed, and EXACT bit-identity of
the trajectory (disarmed spans are one ``is None`` check per phase, so
disarmed must be free; armed appends one JSONL record per span).

The measurement is built for a tight 2% gate: the true recorder cost
(~6 span records/round, ~100us) is far below run-to-run CPU noise on a
short run, so instead of the short-vs-long marginal idiom (whose
subtraction AMPLIFIES noise) this bench times LONG runs — the per-run
jit compile amortizes to a few percent of wall, diluting the ratio far
less than noise would corrupt a marginal — interleaving disarmed/armed
pairs so load drift hits both arms alike, and takes the min wall per
arm over reps.  Both gates are asserted in-bench AND re-checked by
``benchmarks/check_history.py`` from the history record.

Also recorded: the armed run's per-phase wall breakdown
(``recorder().summary()`` — train/aggregate/eval per round), which is
the artifact CI surfaces for "where did this round's time go".

Writes ``BENCH_obs.json`` (override with ``BENCH_OBS_OUT``) and appends
the schema'd record to ``BENCH_history.jsonl``.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench
from repro.core import FLConfig, mlp, run_rounds
from repro.data import (dirichlet_partition, gaussian_mixture,
                        train_val_test_split)
from repro.drivers import make_driver
from repro.obs import trace

K = 8
DIM, CLASSES = 16, 10
OUT = os.environ.get("BENCH_OBS_OUT", "BENCH_obs.json")


def _problem(seed=0):
    ds = gaussian_mixture(4000, n_classes=CLASSES, dim=DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, K, 1.0, seed=seed)
    return train, val, test, parts


def _config(rounds):
    return FLConfig(strategy="fedavg", rounds=rounds, client_fraction=1.0,
                    local_epochs=25, local_batch_size=32, local_lr=0.05,
                    seed=0)


def run() -> None:
    rounds = scale(20, 40)
    reps = 4
    train, val, test, parts = _problem()
    net = mlp(DIM, CLASSES, hidden=(64, 64))
    tmp = tempfile.mkdtemp(prefix="obs_bench_")

    summary = {}

    def one_run(armed, rep):
        if armed:
            trace.arm(path=os.path.join(tmp, f"spans_rep{rep}.jsonl"))
        try:
            t0 = time.time()
            results, globals_, _ = run_rounds(
                [net], [0] * K, train, parts, val, test,
                _config(rounds), driver=make_driver("sync"))
            jax.block_until_ready(jax.tree.leaves(globals_[0])[0])
            wall = time.time() - t0
            if armed:
                summary.update(trace.recorder().summary())
        finally:
            if armed:
                trace.disarm()
        return wall, results[0]

    walls = {False: [], True: []}
    r_off = r_on = None
    for rep in range(reps):  # interleaved: load drift hits both arms
        w, r_off = one_run(False, rep)
        walls[False].append(w)
        w, r_on = one_run(True, rep)
        walls[True].append(w)

    trajectory_equal = (
        [l.test_acc for l in r_on.logs] == [l.test_acc for l in r_off.logs])
    assert trajectory_equal, \
        "armed flight recorder must not perturb the trajectory"

    overhead = min(walls[True]) / min(walls[False]) - 1.0
    rec = {
        "K": K, "dim": DIM, "classes": CLASSES, "hidden": [64, 64],
        "rounds": rounds, "reps": reps, "local_epochs": 25,
        "disarmed": {"wall_s": min(walls[False]),
                     "rounds_per_s": rounds / min(walls[False])},
        "armed": {"wall_s": min(walls[True]),
                  "rounds_per_s": rounds / min(walls[True])},
        "overhead_frac": overhead,
        "trajectory_equal": trajectory_equal,
        "phase_totals_s": summary.get("phase_totals_s", {}),
        "idle_gap_s": summary.get("idle_gap_s", 0.0),
        "per_round": summary.get("per_round", {}),
    }
    assert overhead <= 0.02, \
        f"armed flight-recorder overhead {overhead:.4f} > 2%"
    emit("obs_recorder_overhead", min(walls[True]) / rounds,
         f"overhead_{overhead * 100:+.2f}%", record=rec)
    finish_bench("obs", rec, out=OUT,
                 config={"K": K, "rounds": rounds, "reps": reps})
    print(f"wrote {OUT}: armed {min(walls[True]):.2f}s vs disarmed "
          f"{min(walls[False]):.2f}s over {rounds} rounds "
          f"(overhead {overhead * 100:+.2f}%), trajectory_equal="
          f"{trajectory_equal}")


if __name__ == "__main__":
    run()

"""FedDF ensemble-distillation model fusion (the paper's core contribution).

AVGLOGITS (paper eq. in §3):

    x_{t,j} = x_{t,j-1} - eta * d/dx KL( sigma(mean_k f(x_k, d)),
                                         sigma(f(x_{t,j-1}, d)) )

Implementation notes:

* Teachers of one prototype are stacked along a leading "clients" axis and
  evaluated with a single ``jax.vmap``-ed forward — one fused program per
  prototype instead of |S_t| sequential forwards.
* Teachers are FROZEN during fusion, so for sources with a finite pool the
  averaged teacher logits are precomputed ONCE into a device-resident
  **logit bank** (``core/logit_bank.py``) and the scan *gathers* bank rows
  by the sampled indices instead of re-forwarding the K teachers per step
  (K×steps forwards → K×(N/chunk)); heterogeneous fusion builds the bank
  once and shares it across all G group-students.  ``FusionConfig.
  logit_bank`` controls this (``auto``/``on``/``off``); generator / noise
  sources have no pool and keep the on-the-fly path.
* The student update runs in jit'd chunks of ``eval_every`` steps
  (lax.scan) with ``params``/``opt_state`` donated where the backend
  supports it; between chunks a jitted validation pass tracks
  best-params / patience ON DEVICE (``lax.cond`` keep/replace — only
  scalar accuracies cross to the host), implementing the paper's early
  stopping (plateau patience 1e3 steps, cap 1e4, Adam lr 1e-3 with cosine
  annealing — §4.1 "model fusion procedure").
* The distillation batch is drawn inside the scan from the
  :class:`~repro.data.distill_sources.DistillSource` (unlabeled data /
  generator / noise), keyed by a threaded PRNG; the bank path draws the
  *same indices* via ``source.sample_indices``, so both trajectories
  match.
* ``use_fused_kernel`` routes the loss through the Pallas ``ensemble_kl``
  kernel: ``True`` always, ``"auto"`` (default) on TPU only.  The bank
  path uses the pre-averaged variant that streams [B, V] bank rows.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import (tree_isfinite, tree_leading_dim, tree_stack,
                                 tree_weighted_mean_stacked)
from repro.common.sharding import donation_supported
from repro.obs.metrics import REGISTRY
from repro.core.logit_bank import (TEACHER_FORWARDS, LogitBank,
                                   _ForwardCounter, dequantize_rows,
                                   resolve_bank)
from repro.core.nets import Net
from repro.data.distill_sources import DistillSource
from repro.optim.optimizers import adam, apply_updates
from repro.optim.schedules import cosine


def avg_logits_kl_pre(student_logits: jax.Array,
                      teacher_avg_logits: jax.Array,
                      temperature: float = 1.0) -> jax.Array:
    """KL( softmax(teacher_avg), softmax(student) ), mean over batch.

    teacher_avg_logits: [B, C] already averaged over teachers (logit-bank
    rows); student_logits: [B, C].
    """
    t = teacher_avg_logits.astype(jnp.float32) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    logp_t = jax.nn.log_softmax(t, axis=-1)
    logp_s = jax.nn.log_softmax(s, axis=-1)
    p_t = jnp.exp(logp_t)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1)
    return jnp.mean(kl) * temperature ** 2


def avg_logits_kl(student_logits: jax.Array, teacher_logits: jax.Array,
                  temperature: float = 1.0,
                  teacher_weights: Optional[jax.Array] = None) -> jax.Array:
    """KL( softmax(mean_k teacher), softmax(student) ), mean over batch.

    teacher_logits: [K, B, C] (raw, un-averaged); student_logits: [B, C].
    ``teacher_weights`` ([K], normalized) replaces the uniform mean with a
    weighted consensus — the FedAsync staleness-importance path
    (docs/population.md); None keeps the historic uniform mean bitwise.
    """
    t = teacher_logits.astype(jnp.float32)
    if teacher_weights is None:
        t_avg = jnp.mean(t, axis=0)
    else:
        t_avg = jnp.tensordot(teacher_weights.astype(jnp.float32), t,
                              axes=([0], [0]))
    return avg_logits_kl_pre(student_logits, t_avg, temperature)


def normalize_teacher_weights(weights) -> Optional[jnp.ndarray]:
    """Importance weights -> normalized [K] jnp.float32 (None passthrough)."""
    if weights is None:
        return None
    w = np.asarray(weights, np.float64)
    s = w.sum()
    if s <= 0:
        raise ValueError(f"teacher weights must have a positive sum, got {w}")
    return jnp.asarray(w / s, jnp.float32)


@dataclasses.dataclass
class FusionConfig:
    """Paper defaults (§4.1): Adam 1e-3 + cosine, 1e4 step cap, 1e3 patience.

    ``optimizer``/``swag_samples`` reproduce the Table 7 ablation: server
    distillation with SGD, Adam (default), or Adam + SWAG-sampled extra
    teachers (the FedDistill [10] variant; see ``core/swag.py``).

    ``logit_bank``: ``auto`` precomputes the teacher-logit bank whenever
    the source exposes an indexable pool, ``on`` insists (warns + falls
    back if it cannot), ``off`` keeps per-step teacher forwards.
    ``bank_dtype`` trades bank memory against trajectory fidelity:
    ``float32`` is bitwise-identical to on-the-fly, ``bfloat16`` halves
    the rows, ``int8`` / ``fp8_e4m3`` store ~4x-smaller quantized rows
    plus one fp32 scale per row, dequantized inside the fused kernel
    (docs/distill_fast_path.md).

    ``batch_sizes`` (heterogeneous fusion only) gives each prototype
    group its own distillation batch size; ``distill_bucket`` buckets
    those sizes into run-fixed padded capacities exactly like the client
    axis (``core/client.py:bucket_capacities`` — ``none`` pads every
    group to the largest size, ``pow2``/``quantile`` give small students
    intermediate capacities so they stop padding to the largest
    student's batch shape).  Padded rows are sliced off before the loss,
    so trajectories are identical across kinds."""

    max_steps: int = 10_000
    patience: int = 1_000
    eval_every: int = 100
    batch_size: int = 128
    lr: float = 1e-3
    temperature: float = 1.0
    use_fused_kernel: Union[bool, str] = "auto"  # True | False | "auto"
    optimizer: str = "adam"  # adam | sgd   (Table 7)
    swag_samples: int = 0    # extra SWAG teachers (Table 7 "SWAG" row)
    swag_scale: float = 0.5
    logit_bank: str = "auto"       # auto | on | off
    bank_dtype: str = "float32"    # float32 | bfloat16 | int8 | fp8_e4m3
    # per-group distill batch sizes (heterogeneous fusion; None = uniform
    # batch_size) and their bucketing into padded capacities
    batch_sizes: Optional[Tuple[int, ...]] = None
    distill_bucket: str = "none"   # none | pow2 | quantile
    distill_max_buckets: int = 4
    # internal: the run-fixed padded capacity this distill's batches are
    # padded to (set per group by heterogeneous fusion, not by users)
    batch_capacity: Optional[int] = None
    # divergence guard (docs/robustness.md): check the student params for
    # non-finite values after every compiled chunk and roll back to the
    # last-good params instead of distilling on.  Off by default — the
    # per-chunk finiteness check costs a device reduction, and fault-free
    # configs must stay bit-identical in behavior AND step count.
    divergence_guard: bool = False


def make_teacher_logits_fn(net: Net, teacher_stack):
    """Stacked homogeneous teachers -> fn(x) -> [K, B, C].

    The stamped ``net``/``stack`` attributes let the distill loop pass the
    stack as an ARGUMENT to one cross-round cached compiled chunk instead
    of baking it into a fresh closure (and recompiling) every round."""

    def fn(x):
        return jax.vmap(lambda p: net.apply(p, x, train=False))(teacher_stack)

    fn.n_teachers = tree_leading_dim(teacher_stack)
    fn.net = net
    fn.stack = teacher_stack
    return fn


def expected_distill_steps(fusion: FusionConfig, have_val: bool) -> int:
    """A-priori estimate of how many distillation steps a fusion will run
    — the logit bank's ``auto`` break-even input (docs/distill_fast_path.md).

    Without validation (no early stopping) the loop runs ``max_steps``
    exactly.  With validation, the EARLIEST possible plateau stop is one
    patience window past the first eval (the first eval always improves on
    the ``-1.0`` initial best), rounded up to the ``eval_every`` chunk
    grid; a small ``patience`` therefore bounds the whole run well below
    ``max_steps`` and the bank build may no longer amortize."""
    if not have_val:
        return fusion.max_steps
    ee = max(1, int(fusion.eval_every))
    earliest_stop = ee * -(-(ee + int(fusion.patience)) // ee)
    return min(int(fusion.max_steps), earliest_stop)


# info["bank_decision"] / RoundLog.bank values per resolve_bank reason
_BANK_DECISIONS = {"built": "bank", "reused": "bank_reused",
                   "skipped_small_run": "skipped_small_run"}


def _bank_decision(reason: str) -> str:
    return _BANK_DECISIONS.get(reason, "on_the_fly")


def _resolve_fused(flag):
    """use_fused_kernel -> bool without importing Pallas when it's off."""
    if flag is False or flag is None:
        return False
    from repro.kernels.ops import use_pallas
    return use_pallas(flag)


def _count_teachers(teacher_logit_fns, source, batch_size) -> int:
    """Total K across groups, for the forward-call accounting.  Derived by
    shape evaluation (same ground truth as the bank builder) so plain
    callables count correctly too; falls back to the ``n_teachers``
    attribute stamped by :func:`make_teacher_logits_fn` when the source
    or a fn cannot be abstractly traced."""
    if not teacher_logit_fns:
        return 0
    try:
        x = jax.eval_shape(lambda k: source.sample(k, batch_size),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(jax.eval_shape(f, x).shape[0])
                   for f in teacher_logit_fns)
    except Exception:  # counting is informational — never fail the fusion
        return sum(int(getattr(f, "n_teachers", 1))
                   for f in teacher_logit_fns)


# Counts TRACES of the compiled distill chunk: the counter bumps via a
# python side effect inside the traced body, so it only moves when jax
# actually re-traces/compiles — the tests' evidence that fusion no longer
# recompiles every round.  Same process-wide counter type as
# TEACHER_FORWARDS (imported above); registered in the unified metrics
# registry under a dotted name, aliased here for the historic interface.
CHUNK_COMPILES = REGISTRY.counter("core.feddf.chunk_compiles")

# Cross-round compiled-program caches, weakly keyed by the student Net
# (id()-keyed dicts could hand back a stale program once ids are reused
# after GC — see core/client.py's eval caches for the idiom).  Values
# close over the teacher nets / source / plain teacher callables, pinning
# them alive, so the id()s inside the inner keys stay valid for exactly
# as long as their entries exist.
_CHUNK_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_VAL_EVAL_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _fusion_chunk_key(fusion: FusionConfig, fused: bool,
                      weighted: bool = False) -> tuple:
    return (fusion.optimizer, float(fusion.lr), int(fusion.max_steps),
            int(fusion.eval_every), int(fusion.batch_size),
            int(fusion.batch_capacity or fusion.batch_size),
            float(fusion.temperature), bool(fused), bool(weighted))


def _make_distill_opt(fusion: FusionConfig):
    if fusion.optimizer == "sgd":  # Table 7: same cosine schedule, SGD rule
        from repro.optim.optimizers import sgd as _sgd
        return _sgd(cosine(fusion.lr, fusion.max_steps))
    return adam(cosine(fusion.lr, fusion.max_steps))


def _build_chunk(student_net: Net, source, fusion: FusionConfig,
                 fused: bool, donate: bool, *, mode: str,
                 teacher_nets: Tuple[Net, ...] = (),
                 teacher_fns: Sequence[Callable] = (),
                 weighted: bool = False):
    """One jit'd ``eval_every``-step distillation chunk.

    ``mode`` selects what crosses the call boundary as ARGUMENTS (so the
    compiled program is reusable across rounds):

      bank     extra = (pool, bank_logits, scales) — gather rows by
               sampled index; ``scales`` are the per-row fp32 dequant
               scales of a quantized bank (None otherwise)
      stacked  extra = one [K_g, ...] teacher pytree per teacher net
      plain    extra = () — legacy closure over arbitrary callables

    ``weighted`` (stacked/plain only; a bank pre-weights its rows at
    build) appends one normalized [K] teacher-weight vector to ``extra``
    and replaces the uniform teacher-logit mean with the weighted
    consensus — the staleness-importance path (docs/population.md).

    ``fusion.batch_capacity`` (distill-axis bucketing) pads the sampled
    batch from ``batch_size`` up to the group's run-fixed capacity so G
    heterogeneous students share compiled shapes; the padded rows are
    sliced off before the loss, so the update is identical to the
    unpadded one.
    """
    opt = _make_distill_opt(fusion)
    if fused:
        from repro.kernels.ops import (ensemble_kl_loss,
                                       ensemble_kl_loss_bank,
                                       ensemble_kl_loss_pre)
    bsz = int(fusion.batch_size)
    cap = int(fusion.batch_capacity or bsz)
    if cap < bsz:
        raise ValueError(f"batch_capacity {cap} < batch_size {bsz}")

    def chunk(params, opt_state, key, step0, *extra):
        CHUNK_COMPILES.add(1)  # trace-time side effect: counts compiles
        if weighted and mode != "bank":
            t_extra, tw = extra[:-1], extra[-1]
        else:
            t_extra, tw = extra, None
        mask = student_net.trainable_mask(params)

        def body(carry, _):
            params, opt_state, key, step = carry
            key, k1 = jax.random.split(key)
            if mode == "bank":
                # fast path: gather pool rows + precomputed averaged
                # teacher logits by the SAME indices sample() would draw
                pool, bank_logits, scales = extra
                idx = source.sample_indices(k1, bsz)
                idx_x = (jnp.concatenate(
                    [idx, jnp.zeros((cap - bsz,), idx.dtype)])
                    if cap > bsz else idx)
                x = pool[idx_x]
                if not fused:
                    t_avg = dequantize_rows(
                        bank_logits[idx],
                        None if scales is None else scales[idx])
            else:
                x = source.sample(k1, bsz)
                if cap > bsz:
                    x = jnp.concatenate(
                        [x, jnp.zeros((cap - bsz,) + x.shape[1:], x.dtype)])
                if mode == "stacked":
                    t_logits = jnp.concatenate(
                        [jax.vmap(lambda p: net.apply(p, x, train=False)
                                  )(stack)
                         for net, stack in zip(teacher_nets, t_extra)],
                        axis=0)
                else:
                    t_logits = jnp.concatenate(
                        [jnp.asarray(f(x)) for f in teacher_fns], axis=0)
                if cap > bsz:
                    t_logits = t_logits[:, :bsz]

            def loss_fn(p):
                s_logits = student_net.apply(p, x, train=True)
                if cap > bsz:
                    s_logits = s_logits[:bsz]
                if mode == "bank":
                    if fused:
                        # gather + dequantize + KL fused in one kernel:
                        # neither the gathered nor the dequantized [B, C]
                        # teacher rows materialize in HBM
                        return ensemble_kl_loss_bank(
                            s_logits, bank_logits, scales, idx,
                            temperature=fusion.temperature)
                    return avg_logits_kl_pre(s_logits, t_avg,
                                             fusion.temperature)
                if fused:
                    if tw is None:
                        return ensemble_kl_loss(
                            s_logits, t_logits,
                            temperature=fusion.temperature)
                    t_consensus = jnp.tensordot(
                        tw.astype(jnp.float32),
                        t_logits.astype(jnp.float32), axes=([0], [0]))
                    return ensemble_kl_loss_pre(
                        s_logits, t_consensus,
                        temperature=fusion.temperature)
                return avg_logits_kl(s_logits, t_logits, fusion.temperature,
                                     teacher_weights=tw)

            grads = jax.grad(loss_fn)(params)
            grads = jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                                 grads, mask)
            deltas, opt_state2 = opt.update(grads, opt_state, params, step)
            params = apply_updates(params, deltas)
            return (params, opt_state2, key, step + 1), None

        (params, opt_state, key, step), _ = jax.lax.scan(
            body, (params, opt_state, key, step0), None,
            length=fusion.eval_every)
        return params, opt_state, key, step

    return jax.jit(chunk, donate_argnums=(0, 1) if donate else ())


def _get_chunk(student_net: Net, teacher_logit_fns: Sequence[Callable],
               source, fusion: FusionConfig, fused: bool,
               bank: Optional[LogitBank], donate: bool,
               teacher_weights=None):
    """The cross-round cached chunk for this (student, teachers, source,
    fusion) configuration plus its per-call extra arguments.  Cached so
    round t+1's fusion reuses round t's compiled program instead of
    re-jitting a fresh closure (the ROADMAP-flagged residual overhead);
    jax's own signature cache handles shape changes (e.g. rng-driven
    heterogeneous cohort sizes).

    ``teacher_weights`` (normalized [K] over all teachers; None =
    uniform) selects the weighted-consensus chunk variant — the weights
    cross the jit boundary as an argument, so weighted rounds share one
    compiled program too.  Bank mode ignores it: a weighted bank already
    folded the weights into its rows at build time."""
    if bank is not None:
        mode = "bank"
    elif all(hasattr(f, "net") and hasattr(f, "stack")
             for f in teacher_logit_fns):
        mode = "stacked"
    else:
        mode = "plain"
    weighted = teacher_weights is not None and mode != "bank"
    w_extra = (jnp.asarray(teacher_weights, jnp.float32),) if weighted \
        else ()
    if mode == "plain":
        # arbitrary callables are usually built fresh per call — caching
        # by their ids would grow one pinned compiled program per round
        # with zero hits, so keep the historic per-call jit for them
        return _build_chunk(student_net, source, fusion, fused, donate,
                            mode="plain", weighted=weighted,
                            teacher_fns=tuple(teacher_logit_fns)), w_extra
    teacher_nets = (tuple(f.net for f in teacher_logit_fns)
                    if mode == "stacked" else ())
    per = _CHUNK_CACHE.get(student_net)
    if per is None:
        per = {}
        _CHUNK_CACHE[student_net] = per
    key = (_fusion_chunk_key(fusion, fused, weighted), mode, id(source),
           tuple(id(n) for n in teacher_nets), bool(donate))
    fn = per.get(key)
    if fn is None:
        fn = _build_chunk(student_net, source, fusion, fused, donate,
                          mode=mode, teacher_nets=teacher_nets,
                          weighted=weighted)
        per[key] = fn
    if mode == "bank":
        # scales is None for fp32/bf16 banks — jit treats it as an empty
        # pytree arg, so one cached chunk covers both layouts per shape
        extra = (bank.pool, bank.logits, bank.scales)
    else:
        extra = tuple(f.stack for f in teacher_logit_fns) + w_extra
    return fn, extra


def _get_val_eval(student_net: Net, val_x, val_y):
    """Cached jitted eval_update for this (net, val set) — the
    between-chunk validation pass used to re-jit per distill() call."""
    per = _VAL_EVAL_CACHE.get(student_net)
    if per is None:
        per = {}
        _VAL_EVAL_CACHE[student_net] = per
    key = (id(val_x), id(val_y))
    entry = per.get(key)
    if entry is None:
        acc_fn = _make_acc_fn(student_net, val_x, val_y)

        @jax.jit
        def eval_update(params, step, best):
            best_params, best_acc, best_step = best
            acc = acc_fn(params)
            best = jax.lax.cond(
                acc > best_acc,
                lambda: (params, acc, step),
                lambda: (best_params, best_acc, best_step))
            return acc, best

        # pin the CALLER's arrays: acc_fn closes over device copies, so
        # without these refs the originals could be GC'd and their ids
        # reused by different data
        entry = (eval_update, (val_x, val_y))
        per[key] = entry
    return entry[0]


def _make_acc_fn(net: Net, x, y, batch_size: int = 512):
    """Jitted top-1 accuracy over fixed padded batches — the distill
    loop's validation eval stays on device (only the scalar crosses)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    n = int(x.shape[0])
    bs = min(batch_size, n)
    nb = -(-n // bs)
    pad = nb * bs - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad,), y.dtype)])
    valid = (jnp.arange(nb * bs) < n).reshape(nb, bs)
    xs = x.reshape((nb, bs) + x.shape[1:])
    ys = y.reshape(nb, bs)

    @jax.jit
    def acc(params):
        def body(c, inp):
            xb, yb, mb = inp
            pred = jnp.argmax(net.apply(params, xb, train=False), axis=-1)
            return c + jnp.sum(jnp.where(mb, pred == yb, False)), None

        c, _ = jax.lax.scan(body, jnp.int32(0), (xs, ys, valid))
        return c.astype(jnp.float32) / n

    return acc


def distill(
    student_net: Net,
    student_params,
    teacher_logit_fns: Sequence[Callable],
    source: DistillSource,
    fusion: FusionConfig,
    val_x: Optional[np.ndarray] = None,
    val_y: Optional[np.ndarray] = None,
    seed: int = 0,
    bank: Optional[LogitBank] = None,
    teacher_weights=None,
) -> Tuple[dict, dict]:
    """Run server-side ensemble distillation; returns (params, info).

    ``teacher_logit_fns``: callables x -> [K_g, B, C]; logits are averaged
    over *all* teachers across groups (Algorithm 3 line 14).  Pass a
    prebuilt ``bank`` to share one teacher-logit bank across students
    (heterogeneous fusion); with ``bank=None`` and ``fusion.logit_bank``
    != 'off' the bank is built here when the source has a pool.

    ``teacher_weights`` ([sum K_g] over all teachers in concat order, any
    positive scale; None = uniform) replaces the AVGLOGITS uniform mean
    with a weighted teacher consensus — the buffered-async driver's
    FedAsync staleness importance (docs/population.md).  It folds into
    the bank rows at build time, or crosses the jit boundary as a chunk
    argument on the on-the-fly path; None keeps every historic trajectory
    bitwise-identical.
    """
    opt = _make_distill_opt(fusion)

    fused = _resolve_fused(fusion.use_fused_kernel)
    teacher_weights = normalize_teacher_weights(teacher_weights)

    built_here = False
    decision = "bank" if bank is not None else "on_the_fly"
    if bank is None and fusion.logit_bank != "off" and teacher_logit_fns:
        bank, reason = resolve_bank(
            teacher_logit_fns, source, fusion,
            expected_steps=expected_distill_steps(fusion,
                                                  val_x is not None),
            teacher_weights=teacher_weights)
        decision = _bank_decision(reason)
        built_here = bank is not None and not bank.reused
    n_teachers = _count_teachers(teacher_logit_fns, source,
                                 fusion.batch_size)

    donate = donation_supported()
    # the compiled chunk is cached ACROSS rounds (teacher stacks / bank
    # rows cross the call boundary as arguments): round t+1 reuses round
    # t's program instead of re-jitting a fresh closure per call
    chunk, extra = _get_chunk(student_net, teacher_logit_fns, source,
                              fusion, fused, bank, donate,
                              teacher_weights=teacher_weights)

    # the first chunk call donates its params buffer: never donate the
    # caller's — copy once, reuse for 10k steps
    params = (jax.tree.map(jnp.copy, student_params) if donate
              else student_params)
    opt_state = opt.init(params)

    have_val = val_x is not None
    if have_val:
        eval_update = _get_val_eval(student_net, val_x, val_y)
        best = (student_params, jnp.float32(-1.0), jnp.int32(0))

    key = jax.random.PRNGKey(seed)
    step = jnp.int32(0)
    history = []
    guard = bool(getattr(fusion, "divergence_guard", False))
    diverged = False
    while int(step) < fusion.max_steps:
        params, opt_state, key, step = chunk(params, opt_state, key, step,
                                             *extra)
        if bank is None and n_teachers:
            TEACHER_FORWARDS.add(fusion.eval_every * n_teachers)
        if guard and not bool(tree_isfinite(params)):
            # divergence guard: a non-finite distill state can only get
            # worse — stop and roll back to the last-good params (the
            # best-val snapshot, or the pre-distill student)
            diverged = True
            break
        if have_val:
            acc, best = eval_update(params, step, best)
            history.append((int(step), float(acc)))
            if int(step) - int(best[2]) >= fusion.patience:
                break  # early stopping: validation plateau (paper §4.1)

    if have_val:
        best_params, best_acc, best_step = (best[0], float(best[1]),
                                            int(best[2]))
    else:
        best_params = student_params if diverged else params
        best_acc, best_step = -1.0, 0
    fwd_count = (bank.n_teacher_batch_forwards if built_here
                 else (0 if bank is not None else int(step) * n_teachers))
    cap = int(fusion.batch_capacity or fusion.batch_size)
    info = {"steps": int(step), "best_val_acc": best_acc,
            "best_step": best_step, "val_history": history,
            "diverged": diverged,
            "logit_bank": bank is not None,
            "bank_decision": decision,
            "bank_dtype": bank.dtype_name if bank is not None else "",
            "bank_nbytes": bank.nbytes if bank is not None else 0,
            "bank_build_s": bank.build_time_s if built_here else 0.0,
            "teacher_batch_forwards": fwd_count,
            # distill-axis bucketing accounting: rows computed but sliced
            # off before the loss, per step (0 = unbucketed/exact-fit)
            "batch_capacity": cap,
            "padded_rows_per_step": cap - int(fusion.batch_size)}
    return best_params, info


def filter_teacher_stack(net: Net, stack, probe_x,
                         sigma: float = 6.0) -> Tuple[np.ndarray, int]:
    """Teacher-consensus filter (docs/robustness.md): which teachers of a
    stacked [K, ...] ensemble may vote?

    Each teacher's logits on one probe batch are compared against the
    element-wise median over finite teachers; a teacher is dropped when
    its logits are non-finite anywhere, or when its mean absolute
    deviation from the median robust-z-scores beyond ``sigma`` among its
    peers.  Runs BEFORE the logit-bank rows are built, so a poisoned
    teacher never contaminates the distillation targets.

    Returns ``(kept_indices, n_dropped)``; ``kept_indices`` may be empty
    when every teacher is non-finite (callers should then skip fusion).
    """
    logits = np.asarray(
        jax.vmap(lambda p: net.apply(p, probe_x, train=False))(stack),
        np.float64)                                   # [K, B, C]
    k = logits.shape[0]
    finite = np.isfinite(logits).all(axis=(1, 2))
    if not finite.any():
        return np.empty(0, np.int64), k
    med = np.median(logits[finite], axis=0)           # [B, C]
    dist = np.full(k, np.inf)
    dist[finite] = np.mean(np.abs(logits[finite] - med), axis=(1, 2))
    fd = dist[finite]
    center = float(np.median(fd))
    mad = float(np.median(np.abs(fd - center)))
    # same robust-z floor as the upload screen: a collapsed MAD must not
    # flag honest teachers over sub-percent logit jitter
    denom = 1.4826 * mad + 0.05 * abs(center) + 1e-12
    ok = finite & (np.abs(dist - center) / denom <= sigma)
    if not ok.any():  # degenerate: keep the single most central teacher
        ok[int(np.argmin(dist))] = True
    kept = np.flatnonzero(ok)
    return kept.astype(np.int64), int(k - kept.size)


def feddf_fuse_stacked(
    net: Net,
    teacher_stack,
    weights: Sequence[float],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
    student: Optional[dict] = None,
    teacher_weights=None,
) -> Tuple[dict, dict]:
    """Algorithm 1 on an ALREADY-STACKED [K, ...] teacher pytree — the round
    engine hands its batched-training output straight in, no per-round
    ``tree_stack`` re-copy.  ``student=None`` initialises from the weighted
    average (line 6).  ``teacher_weights`` (per-teacher importance, e.g.
    the buffered-async ``(1+s)^-a`` staleness weights) biases the teacher
    consensus; None keeps the paper's uniform AVGLOGITS bitwise."""
    if student is None:
        student = tree_weighted_mean_stacked(teacher_stack, weights)
    if fusion.swag_samples > 0:  # Table 7: FedDistill/SWAG teacher pool
        from repro.core.swag import swag_teachers_stacked
        teacher_stack = swag_teachers_stacked(
            teacher_stack, fusion.swag_samples, scale=fusion.swag_scale,
            seed=seed)
        if teacher_weights is not None:
            # SWAG samples are drawn from the whole ensemble's posterior:
            # give each appended sample the ensemble-average importance
            tw = np.asarray(teacher_weights, np.float64)
            teacher_weights = np.concatenate(
                [tw, np.full(fusion.swag_samples, tw.mean())])
    tfn = make_teacher_logits_fn(net, teacher_stack)
    return distill(net, student, [tfn], source, fusion, val_x, val_y, seed,
                   teacher_weights=teacher_weights)


def feddf_fuse_homogeneous(
    net: Net,
    client_params: List[dict],
    client_weights: Sequence[float],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
    init_from: str = "average",
    prev_global: Optional[dict] = None,
) -> Tuple[dict, dict]:
    """List-of-pytrees wrapper over :func:`feddf_fuse_stacked`.
    ``init_from='previous'`` reproduces the Table 5 ablation (initialise
    from last round's fused model instead of the weighted average)."""
    student = (None if init_from == "average" or prev_global is None
               else prev_global)
    return feddf_fuse_stacked(net, tree_stack(client_params), client_weights,
                              source, fusion, val_x, val_y, seed,
                              student=student)


def feddf_fuse_heterogeneous_stacked(
    prototypes: List[Tuple[Net, Optional[dict], Sequence[float]]],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
    importances: Optional[List[Optional[np.ndarray]]] = None,
) -> Tuple[List[Optional[dict]], List[dict]]:
    """Algorithm 3 on stacked per-group teacher pytrees: every group's
    student distills against the ALL-groups teacher ensemble.

    ``importances`` (one optional [K_g] array per group, aligned with
    ``prototypes``) weights each teacher's vote in the shared consensus
    — groups without importance contribute uniformly.  All-None keeps
    the historic uniform path bitwise.

    ``prototypes``: per group (net, stacked params [K_g, ...] or None,
    data weights).  Returns (fused params per group, info per group).
    The teacher-logit bank is built ONCE here and shared by every group's
    student — the G× redundant re-forwarding of the same all-groups
    ensemble collapses into a single pass over the pool.

    ``fusion.batch_sizes`` gives each group its own distillation batch
    size; the sizes are bucketed into run-fixed padded capacities
    (``fusion.distill_bucket``: ``none`` pads every group to the largest
    size, ``pow2``/``quantile`` give small students intermediate
    capacities) exactly like the client axis in docs/bucketing.md.
    Trajectories are identical across kinds — padded rows never reach
    the loss.
    """
    bsizes = getattr(fusion, "batch_sizes", None)
    caps_of = None
    if bsizes is not None:
        if len(bsizes) != len(prototypes):
            raise ValueError(
                f"fusion.batch_sizes has {len(bsizes)} entries for "
                f"{len(prototypes)} prototype groups")
        from repro.core.client import assign_buckets, bucket_capacities
        bsizes = [int(b) for b in bsizes]
        caps = bucket_capacities(bsizes, fusion.distill_bucket,
                                 fusion.distill_max_buckets)
        which = assign_buckets(bsizes, caps)
        caps_of = [int(caps[w]) for w in which]
    teacher_fns = [make_teacher_logits_fn(net, stack)
                   for net, stack, _ in prototypes if stack is not None]
    # per-teacher importance in teacher_fns' concat order (groups without
    # importance vote uniformly); all-None stays on the uniform path
    teacher_weights = None
    if importances is not None and any(i is not None for i in importances):
        pieces = []
        for (net_, stack, _), imp in zip(prototypes, importances):
            if stack is None:
                continue
            k_g = tree_leading_dim(stack)
            pieces.append(np.ones(k_g, np.float64) if imp is None
                          else np.asarray(imp, np.float64))
        teacher_weights = normalize_teacher_weights(np.concatenate(pieces))
    # the bank is shared by every group-student, so the break-even input
    # is the G-fold TOTAL expected rows, not one student's
    n_students = len(teacher_fns)
    bank, reason = resolve_bank(
        teacher_fns, source, fusion,
        expected_steps=(expected_distill_steps(fusion, val_x is not None)
                        * max(1, n_students)),
        teacher_weights=teacher_weights)
    decision = _bank_decision(reason)
    if bank is None and fusion.logit_bank != "off":
        # resolution already happened (and warned, for 'on') here at the
        # fuse level — stop each group's distill from re-trying it
        fusion = dataclasses.replace(fusion, logit_bank="off")

    fused, infos = [], []
    build_attributed = bank is not None and bank.reused  # reuse: no build
    for gi, (net, stack, weights) in enumerate(prototypes):
        if stack is None:
            fused.append(None)
            infos.append({"skipped": True})
            continue
        student = tree_weighted_mean_stacked(stack, weights)  # Alg.3 line 11
        fusion_g = fusion
        if caps_of is not None:
            fusion_g = dataclasses.replace(
                fusion, batch_size=bsizes[gi], batch_capacity=caps_of[gi],
                batch_sizes=None)
        p, info = distill(net, student, teacher_fns, source, fusion_g,
                          val_x, val_y, seed + gi, bank=bank,
                          teacher_weights=teacher_weights)
        info["bank_decision"] = decision
        if bank is not None and not build_attributed:
            # charge the one-time build to the first fused group so the
            # round's total teacher-forward cost shows up in the logs
            info = dict(info, bank_build_s=bank.build_time_s,
                        teacher_batch_forwards=bank.n_teacher_batch_forwards)
            build_attributed = True
        fused.append(p)
        infos.append(info)
    return fused, infos


def feddf_fuse_heterogeneous(
    prototypes: List[Tuple[Net, List[dict], Sequence[float]]],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
) -> Tuple[List[Optional[dict]], List[dict]]:
    """List-of-pytrees wrapper over
    :func:`feddf_fuse_heterogeneous_stacked`."""
    stacked = [(net, tree_stack(plist) if plist else None, weights)
               for net, plist, weights in prototypes]
    return feddf_fuse_heterogeneous_stacked(stacked, source, fusion,
                                            val_x, val_y, seed)

"""Chunked (flash-pattern) attention vs the naive materialised oracle.

`_sdpa_chunked` is the §Perf variant that never materialises [S,T] scores;
it must match `_sdpa` bit-for-bit up to fp accumulation error, including
gradients, for causal / bidirectional / sliding-window masks and ragged
chunk boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _sdpa, _sdpa_chunked

CASES = [
    # b, s, h, kv, d, causal, window, chunk
    (2, 64, 4, 2, 16, True, None, 16),
    (1, 48, 4, 4, 8, False, None, 32),
    (2, 64, 8, 2, 16, True, 24, 16),
    (1, 33, 2, 1, 8, True, None, 16),   # ragged: 33 % 16 != 0
    (1, 16, 2, 2, 8, True, 4, 16),      # single chunk, tiny window
]


def _mask(s, causal, window):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = (j <= i) if causal else jnp.ones((s, s), bool)
    if window:
        m = m & (i - j < window)
    return m[None, None]


def test_chunked_bf16_carry_dtypes():
    """bf16 inputs must not break the scan carry (acc accumulates in f32)
    and must match the naive path within bf16 tolerance."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 32, 8, 8)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.bfloat16)
    ref = _sdpa(q, k, v, _mask(32, True, None), 8)
    out = _sdpa_chunked(q, k, v, 8, causal=True, window=None, chunk=16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32), ref.astype(np.float32),
                               atol=2e-2)


@pytest.mark.parametrize("b,s,h,kv,d,causal,window,chunk", CASES)
def test_chunked_matches_naive(b, s, h, kv, d, causal, window, chunk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    ref = _sdpa(q, k, v, _mask(s, causal, window), d)
    out = _sdpa_chunked(q, k, v, d, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=5e-6)

    def loss(fn):
        return lambda q: jnp.sum(fn(q) ** 2)

    g_ref = jax.grad(loss(lambda q: _sdpa(q, k, v, _mask(s, causal, window),
                                          d)))(q)
    g_out = jax.grad(loss(lambda q: _sdpa_chunked(
        q, k, v, d, causal=causal, window=window, chunk=chunk)))(q)
    np.testing.assert_allclose(g_out, g_ref, atol=2e-5)

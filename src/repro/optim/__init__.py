from repro.optim.optimizers import (Optimizer, adam, sgd, momentum_sgd,
                                    apply_updates)
from repro.optim.schedules import (constant, cosine, wsd, make_schedule)

"""Declarative experiment API (see docs/experiment_api.md).

    from repro.api import Experiment, ExperimentSpec, TaskSpec, ...

    spec = ExperimentSpec(task=TaskSpec(name="blobs", n_samples=6000),
                          strategy=StrategySpec(name="feddf"))
    result = Experiment(spec).run()

Specs are JSON-round-trippable (``spec.to_json()`` / ``from_json``);
components resolve by name through the registries; ``Experiment.run``
serves both homogeneous and heterogeneous cohorts and
``Experiment.resume`` continues a checkpointed run.
"""
from repro.api.experiment import (Experiment, RoundEvent, RunResult,
                                  build_cohort, build_engine, build_mesh,
                                  build_source, build_splits,
                                  build_task_bundle, to_fl_config)
from repro.api.registries import (TaskBundle, available_models,
                                  available_quantizers, available_sources,
                                  available_tasks, default_prototype_ladder,
                                  get_model, get_quantizer, get_source,
                                  get_task, register_model,
                                  register_quantizer, register_source,
                                  register_task)
from repro.api.spec import (BucketSpec, CohortSpec, DistSpec, DriverSpec,
                            ExperimentSpec, FaultSpec, FusionSpec,
                            ModelSpec, ObsSpec, PartitionSpec,
                            PopulationSpec, PrivacySpec, ShardingSpec,
                            SourceSpec, StrategySpec, TaskSpec,
                            TrafficSpec)

__all__ = [
    "Experiment", "RoundEvent", "RunResult",
    "ExperimentSpec", "TaskSpec", "PartitionSpec", "CohortSpec",
    "ModelSpec", "SourceSpec", "StrategySpec", "FusionSpec",
    "PrivacySpec", "ShardingSpec", "DriverSpec", "BucketSpec",
    "PopulationSpec", "TrafficSpec", "FaultSpec", "ObsSpec", "DistSpec",
    "TaskBundle", "register_task", "register_model", "register_source",
    "register_quantizer", "get_task", "get_model", "get_source",
    "get_quantizer", "available_tasks", "available_models",
    "available_sources", "available_quantizers",
    "default_prototype_ladder",
    "build_task_bundle", "build_splits", "build_cohort", "build_source",
    "build_mesh", "build_engine", "to_fl_config",
]

"""Differentially-private client uploads (paper §3, "privacy-preserving
extension"; Geyer et al. 2017 [16]).

Client-level DP in the local-DP flavour: every uploaded model UPDATE
(delta from the round's global model) is

  1. clipped to L2 norm <= ``clip``  (bounds one client's influence), then
  2. perturbed with Gaussian noise  N(0, (noise_multiplier * clip)^2)
     per coordinate.

Noising each upload (rather than only the server aggregate) keeps the
guarantee intact when FedDF also uses the uploads as distillation
*teachers* — with aggregate-only noise the raw client models would leak
through the ensemble logits.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_add, tree_scale, tree_sub


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, clip: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return tree_scale(tree, factor)


def gaussian_noise_like(tree, sigma: float, key: jax.Array):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [sigma * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
             for k, x in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, noisy)


def privatize_update(global_params, client_params, *, clip: float,
                     noise_multiplier: float, key: jax.Array):
    """Returns the DP version of ``client_params``:
    global + noise(clip(client - global))."""
    delta = tree_sub(client_params, global_params)
    delta = clip_by_global_norm(delta, clip)
    if noise_multiplier > 0.0:
        delta = tree_add(delta, gaussian_noise_like(
            delta, noise_multiplier * clip, key))
    return tree_add(global_params, delta)

"""Pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_mean(trees: Sequence[Pytree], weights: Sequence[float]) -> Pytree:
    """Weighted parameter average — the FedAvg aggregation primitive."""
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    out = tree_scale(trees[0], float(w[0]))
    for t, wi in zip(trees[1:], w[1:]):
        out = jax.tree.map(lambda acc, x, wi=float(wi): acc + wi * x, out, t)
    return out


def tree_stack(trees: Sequence[Pytree]) -> Pytree:
    """Stack homogeneous pytrees along a new leading axis (client axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Pytree, n: int) -> list[Pytree]:
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_leading_dim(tree: Pytree) -> int:
    """Size of the leading (client) axis of a stacked pytree."""
    return int(jax.tree.leaves(tree)[0].shape[0])


def tree_take(tree: Pytree, idx) -> Pytree:
    """Gather along the leading (client) axis of a stacked pytree."""
    idx = jnp.asarray(idx)
    return jax.tree.map(lambda x: x[idx], tree)


def tree_cat(trees: Sequence[Pytree]) -> Pytree:
    """Concatenate stacked pytrees along the leading (client) axis —
    the bucketed round engine's per-bucket stacks re-join through this."""
    if len(trees) == 1:
        return trees[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


def tree_weighted_mean_stacked(stack: Pytree, weights) -> Pytree:
    """FedAvg aggregation over the leading (client) axis of a stacked
    pytree — one contraction per leaf instead of K sequential adds."""
    w = np.asarray(weights, dtype=np.float64)
    w = jnp.asarray(w / w.sum(), jnp.float32)
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32),
                                axes=([0], [0])).astype(x.dtype), stack)


def tree_trimmed_mean_stacked(stack: Pytree, weights, trim: int) -> Pytree:
    """Per-coordinate trimmed weighted mean over the leading (client) axis.

    For every scalar coordinate the ``trim`` smallest and ``trim`` largest
    of the K client values are discarded and the survivors averaged with
    their (renormalized) client weights — robust to up to ``trim``
    arbitrarily corrupted uploads per coordinate (docs/robustness.md).

    ``trim == 0`` delegates to :func:`tree_weighted_mean_stacked` so plain
    configs stay *bitwise* identical to FedAvg (a sorted summation would
    reorder the floating-point adds).
    """
    if trim == 0:
        return tree_weighted_mean_stacked(stack, weights)
    k = tree_leading_dim(stack)
    if 2 * trim >= k:
        raise ValueError(f"trim={trim} needs K >= {2 * trim + 1} uploads, "
                         f"got K={k}")
    w = np.asarray(weights, dtype=np.float64)
    w = jnp.asarray(w / w.sum(), jnp.float32)

    def _leaf(x):
        flat = x.astype(jnp.float32).reshape(k, -1)
        order = jnp.argsort(flat, axis=0)                  # [K, D]
        sorted_vals = jnp.take_along_axis(flat, order, axis=0)
        sorted_w = w[order]                                # weight by rank
        keep = jnp.zeros((k, 1), jnp.float32).at[trim:k - trim].set(1.0)
        kept_w = sorted_w * keep
        # zero trimmed slots by where(), not by the 0-weight product: a
        # non-finite value in the trim region (NaN sorts last) would
        # otherwise poison the sum via NaN * 0 = NaN
        kept_vals = jnp.where(keep > 0, sorted_vals, 0.0)
        num = jnp.sum(kept_vals * kept_w, axis=0)
        den = jnp.sum(kept_w, axis=0)
        return (num / den).reshape(x.shape[1:]).astype(x.dtype)

    return jax.tree.map(_leaf, stack)


def tree_coordinate_median_stacked(stack: Pytree, weights) -> Pytree:
    """Per-coordinate weighted median over the leading (client) axis.

    The weighted median is the smallest client value whose cumulative
    (sorted-order) weight reaches half the total — with uniform weights
    and odd K this is the classic coordinate-wise median, robust to
    ``(K-1)//2`` arbitrary uploads per coordinate.
    """
    k = tree_leading_dim(stack)
    w = np.asarray(weights, dtype=np.float64)
    w = jnp.asarray(w / w.sum(), jnp.float32)

    def _leaf(x):
        flat = x.astype(jnp.float32).reshape(k, -1)
        order = jnp.argsort(flat, axis=0)
        sorted_vals = jnp.take_along_axis(flat, order, axis=0)
        cum = jnp.cumsum(w[order], axis=0)
        # first rank whose cumulative weight crosses 0.5 (inclusive)
        idx = jnp.argmax(cum >= 0.5, axis=0)
        med = jnp.take_along_axis(sorted_vals, idx[None, :], axis=0)[0]
        return med.reshape(x.shape[1:]).astype(x.dtype)

    return jax.tree.map(_leaf, stack)


def tree_spec(tree: Pytree) -> list:
    """Flat ``(path, shape, dtype)`` signature of a pytree, for upload
    wire-safety checks (``PopulationManager.push_wave``)."""
    out = []

    def _leaf(path, x):
        dt = x.dtype if hasattr(x, "dtype") else np.asarray(x).dtype
        out.append((path, tuple(np.shape(x)), str(dt)))
        return x

    tree_map_with_path(_leaf, tree)
    return out


def tree_check_like(tree: Pytree, like: Pytree, what: str = "pytree") -> None:
    """Raise ValueError naming the first structural mismatch between
    ``tree`` and the prototype ``like`` (paths, shapes, dtypes)."""
    got, want = tree_spec(tree), tree_spec(like)
    got_paths = [p for p, _, _ in got]
    want_paths = [p for p, _, _ in want]
    if got_paths != want_paths:
        missing = sorted(set(want_paths) - set(got_paths))
        extra = sorted(set(got_paths) - set(want_paths))
        raise ValueError(
            f"{what} structure mismatch: missing leaves {missing[:4]}, "
            f"unexpected leaves {extra[:4]}")
    for (p, gs, gd), (_, ws, wd) in zip(got, want):
        if gs != ws:
            raise ValueError(f"{what} leaf {p!r} has shape {gs}, "
                             f"expected {ws}")
        if gd != wd:
            raise ValueError(f"{what} leaf {p!r} has dtype {gd}, "
                             f"expected {wd}")


def tree_sq_dist(a: Pytree, b: Pytree):
    """sum ||a-b||^2 over all leaves (FedProx proximal term)."""
    d = jax.tree.map(lambda x, y: jnp.sum((x - y) ** 2), a, b)
    return jax.tree.reduce(jnp.add, d)


def tree_count(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_isfinite(tree: Pytree):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_map_with_path(fn: Callable, tree: Pytree) -> Pytree:
    """fn(path_str, leaf) -> leaf, path joined with '/'."""

    def _fn(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        return fn("/".join(keys), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)

"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    qk_norm=True,
    window=1024,
    tie_embeddings=True,
    # 5 sliding-window (local) layers per 1 full (global) layer
    pattern=tuple([BlockSpec("attn_local", "swiglu")] * 5
                  + [BlockSpec("attn_global", "swiglu")]),
)

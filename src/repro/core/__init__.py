from repro.core.feddf import (FusionConfig, avg_logits_kl, distill,
                              feddf_fuse_homogeneous,
                              feddf_fuse_heterogeneous)
from repro.core.server import (FLConfig, FLResult, RoundLog, run_federated,
                               run_federated_heterogeneous)
from repro.core.nets import Net, mlp, tiny_transformer
from repro.core.ensemble import ensemble_accuracy
from repro.core.dropworst import drop_worst
from repro.core.quantize import binarize, comm_bytes

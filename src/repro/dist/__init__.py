"""Distributed runtime: fusion pod + client pods over a wire protocol.

Eagerly exposes only the dependency-light pieces (``DistConfig`` and the
wire format — stdlib + numpy), so ``core.engine`` can embed the config
and the jax-free spec layer can validate codec names without importing
transports or jax.  The driver registers itself through
``repro.drivers`` (importing it here would close an import cycle:
engine -> dist -> driver -> drivers -> sync -> engine).
"""
from repro.dist.config import DistConfig
from repro.dist.frames import (available_codecs, codec_by_id, decode_frame,
                               encode_frame, get_codec)

__all__ = ["DistConfig", "available_codecs", "codec_by_id", "decode_frame",
           "encode_frame", "get_codec"]


def __getattr__(name):
    if name in ("DistributedDriver",):
        from repro.dist.driver import DistributedDriver
        return DistributedDriver
    if name in ("ClientPodRunner", "shard_clients"):
        import repro.dist.pods as pods
        return getattr(pods, name)
    if name in ("LoopbackTransport", "TCPTransport", "TCPPodEndpoint"):
        import repro.dist.transport as transport
        return getattr(transport, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.common.pytree import tree_stack, tree_weighted_mean
from repro.core.feddf import avg_logits_kl
from repro.core.quantize import binarize
from repro.data.partition import class_histogram, dirichlet_partition
from repro.kernels import ref
from repro.kernels.ensemble_kl import ensemble_kl

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Dirichlet partition invariants (paper §4.1 / Appendix C.2)
# ---------------------------------------------------------------------------

@given(n=st.integers(50, 400), k=st.integers(2, 12),
       alpha=st.sampled_from([0.01, 0.1, 1.0, 100.0]),
       seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_partition_disjoint_and_complete(n, k, alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 5, size=n)
    parts = dirichlet_partition(labels, k, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n  # disjoint AND complete
    assert all(len(p) >= 1 for p in parts)


def test_partition_alpha_controls_noniidness():
    """Smaller alpha -> more concentrated per-client class distributions."""
    labels = np.random.default_rng(0).integers(0, 10, size=20_000)

    def mean_max_frac(alpha):
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        h = class_histogram(labels, parts, 10).astype(float)
        h = h / np.maximum(h.sum(1, keepdims=True), 1)
        return float(np.mean(h.max(axis=1)))

    assert mean_max_frac(0.01) > mean_max_frac(1.0) > mean_max_frac(100.0)
    assert mean_max_frac(100.0) < 0.2  # ~uniform over 10 classes
    assert mean_max_frac(0.01) > 0.8   # ~one class per client


# ---------------------------------------------------------------------------
# AVGLOGITS loss properties
# ---------------------------------------------------------------------------

@given(k=st.integers(1, 6), b=st.integers(1, 8), c=st.integers(2, 40),
       seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_kl_nonnegative_and_zero_iff_equal(k, b, c, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    s = jax.random.normal(k1, (b, c)) * 2
    t = jax.random.normal(k2, (k, b, c)) * 2
    val = float(avg_logits_kl(s, t))
    assert val >= -1e-6
    t_same = jnp.broadcast_to(s, (k, b, c))
    assert abs(float(avg_logits_kl(s, t_same))) < 1e-5


@given(k=st.integers(1, 4), b=st.integers(1, 4),
       c=st.sampled_from([17, 64, 130]), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kernel_matches_oracle_property(k, b, c, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    s = jax.random.normal(k1, (b, c)) * 3
    t = jax.random.normal(k2, (k, b, c)) * 3
    assert jnp.allclose(ensemble_kl(s, t, 1.0), ref.ensemble_kl(s, t, 1.0),
                        rtol=1e-5, atol=1e-6)


@given(seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kl_shift_invariance(seed):
    """Softmax-KL is invariant to per-row logit shifts."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    s = jax.random.normal(k1, (4, 32))
    t = jax.random.normal(k2, (3, 4, 32))
    shift_s = jax.random.normal(k3, (4, 1)) * 10
    a = avg_logits_kl(s, t)
    b = avg_logits_kl(s + shift_s, t)
    c = avg_logits_kl(s, t + 5.0)
    assert jnp.allclose(a, b, rtol=1e-4, atol=1e-5)
    assert jnp.allclose(a, c, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Parameter-average / stacking invariants (FedAvg primitive)
# ---------------------------------------------------------------------------

@given(k=st.integers(1, 5), seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_weighted_mean_identity_and_convexity(k, seed):
    key = jax.random.PRNGKey(seed)
    trees = [{"a": jax.random.normal(jax.random.fold_in(key, i), (3, 4)),
              "b": {"c": jax.random.normal(jax.random.fold_in(key, 100 + i),
                                           (5,))}}
             for i in range(k)]
    same = tree_weighted_mean([trees[0]] * k, [1.0] * k)
    assert jnp.allclose(same["a"], trees[0]["a"], atol=1e-6)
    avg = tree_weighted_mean(trees, list(range(1, k + 1)))
    lo = jnp.min(jnp.stack([t["a"] for t in trees]), 0)
    hi = jnp.max(jnp.stack([t["a"] for t in trees]), 0)
    assert bool(jnp.all(avg["a"] >= lo - 1e-5))
    assert bool(jnp.all(avg["a"] <= hi + 1e-5))


@given(seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_stack_roundtrip(seed):
    key = jax.random.PRNGKey(seed)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (2, 3))}
             for i in range(4)]
    stacked = tree_stack(trees)
    assert stacked["w"].shape == (4, 2, 3)
    for i in range(4):
        assert jnp.allclose(stacked["w"][i], trees[i]["w"])


# ---------------------------------------------------------------------------
# Binarization (STE) invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_binarize_values_and_grad(seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (8, 8))
    q = binarize({"w": w})["w"]
    scale = jnp.mean(jnp.abs(w))
    assert jnp.allclose(jnp.abs(q), scale, atol=1e-6)  # +/- one scale
    # STE: gradient passes through unchanged
    g = jax.grad(lambda x: jnp.sum(binarize({"w": x})["w"] * 2.0))(w)
    assert jnp.allclose(g, 2.0 * jnp.ones_like(w) * jnp.abs(jnp.sign(w)),
                        atol=0.6)  # sign() grad + scale-term grad
    # vectors are untouched
    v = jax.random.normal(key, (16,))
    assert jnp.allclose(binarize({"v": v})["v"], v)


# ---------------------------------------------------------------------------
# SSD: chunking must be invariant to chunk size
# ---------------------------------------------------------------------------

@given(q1=st.sampled_from([4, 8, 16]), q2=st.sampled_from([5, 32, 64]),
       seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_ssd_chunk_size_invariance(q1, q2, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 48, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y1 = ref.ssd_scan(x, dt, a_log, bm, cm, q1)
    y2 = ref.ssd_scan(x, dt, a_log, bm, cm, q2)
    assert jnp.allclose(y1, y2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Chunked (flash-pattern) attention == naive attention, over random geometry
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 2), s=st.integers(3, 40), kvh=st.sampled_from([1, 2]),
       rep=st.sampled_from([1, 3]), d=st.sampled_from([4, 8]),
       causal=st.booleans(), window=st.sampled_from([None, 5, 16]),
       chunk=st.sampled_from([4, 7, 64]), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_chunked_attention_matches_naive(b, s, kvh, rep, d, causal, window,
                                         chunk, seed):
    from repro.models.attention import _sdpa, _sdpa_chunked
    if not causal and window is not None:
        window = None  # window only applies to causal/local layers
    h = kvh * rep
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = (j <= i) if causal else jnp.ones((s, s), bool)
    if window is not None:
        mask = mask & (i - j < window)
    ref_out = _sdpa(q, k, v, mask[None, None], d)
    out = _sdpa_chunked(q, k, v, d, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-5)

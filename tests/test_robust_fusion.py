"""Fault injection, robust fusion and recovery (docs/robustness.md).

 1. ``FaultModel`` draws are counter-based: corruption for
    ``(wave, client, attempt)`` is a pure function of (config, seed) —
    identical across calls, redrawn per attempt; byzantine membership is
    a static draw.  Crash / bitflip / nan corruptions have the shapes
    they claim.
 2. Screening: robust-z outlier masks flag poisoned norms but never
    honest near-identical ones (the MAD floor); ``NormScreen``'s rolling
    window accepts honest traffic, rejects outliers, and round-trips
    through ``checkpoint/io.py``.
 3. Robust aggregators: ``trimmed_mean`` with ``trim == 0`` IS fedavg
    (bitwise); with b outliers among 2b+1 honest uploads both
    ``trimmed_mean`` and ``coordinate_median`` recover the honest value
    exactly (hypothesis property when available + deterministic pins).
 4. FedDF teacher-consensus filter drops non-finite / divergent
    teachers before distillation and keeps honest ensembles whole.
 5. End-to-end (sync): fault-free configs with defense/quorum knobs set
    are bit-identical to the historic trajectory; under a chaos config
    the defended run tracks the fault-free accuracy while the
    undefended run visibly degrades; an all-poisoned round skips fusion
    (quorum) and carries the globals.
 6. End-to-end (buffered_async): chaos configs complete with finite
    globals and populated quarantine telemetry; an all-poisoned
    population skips every fusion under a quorum instead of raising.
 7. Checkpoint atomicity: a kill mid-write leaves the previous
    checkpoint loadable (temp + ``os.replace``), and the CLI fault
    flags round-trip through ``--dump-config``.
 8. Back-compat: specs / RoundLogs / registry checkpoints predating the
    fault axis load with inert defaults.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.api import (CohortSpec, DriverSpec, Experiment, ExperimentSpec,
                       FaultSpec, FusionSpec, ModelSpec, PartitionSpec,
                       PopulationSpec, SourceSpec, StrategySpec, TaskSpec,
                       TrafficSpec)
from repro.checkpoint import io as ckpt_io
from repro.common.pytree import (tree_check_like, tree_coordinate_median_stacked,
                                 tree_trimmed_mean_stacked,
                                 tree_weighted_mean_stacked)
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.core.engine import RoundLog
from repro.core.feddf import filter_teacher_stack
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.population import ClientRegistry, FaultConfig, FaultModel, NormScreen
from repro.population.faults import (delta_norm, leaves_finite, outlier_mask,
                                     robust_z)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
    SETTINGS = dict(max_examples=25, deadline=None)
except ImportError:          # hypothesis is a dev/CI dep (requirements-dev)
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# fault model: counter-based injection
# ---------------------------------------------------------------------------

def _leaves(rng, scale=1.0):
    return [rng.normal(size=(4, 3)).astype(np.float32) * scale,
            rng.normal(size=(7,)).astype(np.float32) * scale]


def test_fault_model_clean_path_is_identity():
    rng = np.random.default_rng(0)
    leaves = _leaves(rng)
    base = _leaves(rng)
    fm = FaultModel(FaultConfig(), seed=0, n=8)
    out, kinds = fm.corrupt(3, 2, leaves, base)
    assert kinds == ()
    for o, l in zip(out, leaves):
        np.testing.assert_array_equal(o, l)


def test_fault_model_draws_are_counter_based():
    cfg = FaultConfig(nan_rate=0.5, bitflip_rate=0.5, crash_rate=0.5)
    rng = np.random.default_rng(1)
    leaves, base = _leaves(rng), _leaves(rng)
    a = FaultModel(cfg, seed=7, n=8)
    b = FaultModel(cfg, seed=7, n=8)
    for wave in range(4):
        for c in range(4):
            oa, ka = a.corrupt(wave, c, leaves, base)
            ob, kb = b.corrupt(wave, c, leaves, base)
            assert ka == kb
            for x, y in zip(oa, ob):
                np.testing.assert_array_equal(x, y)
    # a retry redraws the transport faults: the (rare) case where every
    # attempt produces identical corruption would defeat retrying
    o0, _ = a.corrupt(0, 0, leaves, base, attempt=0)
    o1, _ = a.corrupt(0, 0, leaves, base, attempt=1)
    assert any(not np.array_equal(x, y) for x, y in zip(o0, o1))


def test_fault_model_byzantine_static_and_transforms():
    cfg = FaultConfig(byzantine_frac=0.5, byzantine_scale=10.0)
    fm = FaultModel(cfg, seed=3, n=16)
    fm2 = FaultModel(cfg, seed=3, n=16)
    np.testing.assert_array_equal(fm.byzantine, fm2.byzantine)
    assert 0 < int(fm.byzantine.sum()) < 16
    byz = int(np.flatnonzero(fm.byzantine)[0])
    honest = int(np.flatnonzero(~fm.byzantine)[0])
    rng = np.random.default_rng(2)
    base = _leaves(rng)
    leaves = [b + 0.1 for b in base]
    out, kinds = fm.corrupt(1, byz, leaves, base)
    assert kinds == ("byzantine",)
    # sign_flip sends base - scale * delta
    np.testing.assert_allclose(out[0], base[0] - 10.0 * 0.1,
                               rtol=1e-4, atol=1e-5)
    _, kinds = fm.corrupt(1, honest, leaves, base)
    assert kinds == ()
    sc = FaultModel(dataclasses.replace(cfg, byzantine_mode="scale"),
                    seed=3, n=16)
    out, _ = sc.corrupt(1, byz, leaves, base)
    np.testing.assert_allclose(out[0], base[0] + 10.0 * 0.1,
                               rtol=1e-4, atol=1e-5)


def test_fault_model_crash_zeroes_a_tail():
    fm = FaultModel(FaultConfig(crash_rate=1.0), seed=0, n=4)
    rng = np.random.default_rng(3)
    base = _leaves(rng)
    leaves = [np.full((4, 3), 2.0, np.float32), np.full(7, 2.0, np.float32)]
    out, kinds = fm.corrupt(1, 0, leaves, base)
    assert "crash" in kinds
    flat = np.concatenate([o.reshape(-1) for o in out])
    zeros = flat == 0.0
    # a contiguous tail is zeroed; at least one param survives
    assert zeros.any() and not zeros[0]
    assert np.array_equal(np.flatnonzero(zeros),
                          np.arange(flat.size - zeros.sum(), flat.size))


def test_fault_model_bitflip_and_nan_touch_one_leaf():
    rng = np.random.default_rng(4)
    leaves, base = _leaves(rng), _leaves(rng)
    fm = FaultModel(FaultConfig(bitflip_rate=1.0, bitflip_bits=2),
                    seed=1, n=4)
    out, kinds = fm.corrupt(1, 0, leaves, base)
    assert "bitflip" in kinds
    changed = [int((o != l).sum()) for o, l in zip(out, leaves)]
    assert sum(1 for c in changed if c) == 1 and max(changed) <= 2
    fm = FaultModel(FaultConfig(nan_rate=1.0), seed=1, n=4)
    out, kinds = fm.corrupt(1, 0, leaves, base)
    assert "nan" in kinds and not leaves_finite(out)
    assert sum(int((~np.isfinite(o)).sum()) for o in out) == 1
    # inputs were never mutated
    assert leaves_finite(leaves)


# ---------------------------------------------------------------------------
# screening: robust-z masks + the rolling NormScreen
# ---------------------------------------------------------------------------

def test_outlier_mask_flags_poison_not_honest():
    honest = [1.0, 1.05, 0.95, 1.02, 0.98]
    mask = outlier_mask(honest + [12.0, np.nan], sigma=6.0)
    np.testing.assert_array_equal(
        mask, [False] * 5 + [True, True])


def test_outlier_mask_mad_collapse_keeps_honest():
    # identical norms + one epsilon jitter: the relative MAD floor must
    # keep the jittered honest upload (naive MAD would z it to infinity)
    mask = outlier_mask([1.0, 1.0, 1.0, 1.0 + 1e-6], sigma=6.0)
    assert not mask.any()


def test_robust_z_scales_with_relative_floor():
    z = robust_z(np.array([1.0, 2.0]), center=1.0, mad=0.0)
    assert z[0] == 0.0 and z[1] == pytest.approx(1.0 / 0.05, rel=1e-6)


def test_norm_screen_accepts_honest_rejects_outliers():
    s = NormScreen(sigma=6.0, min_history=4)
    for i in range(6):
        ok, why = s.check(0, 1.0 + 0.01 * i)
        assert ok and why is None
    ok, why = s.check(0, 15.0)
    assert not ok and why == "norm_outlier"
    ok, why = s.check(0, np.inf)
    assert not ok and why == "nonfinite"
    # other prototypes have their own window
    ok, _ = s.check(1, 15.0)
    assert ok


def test_norm_screen_state_round_trip(tmp_path):
    s = NormScreen(sigma=4.0)
    for i in range(7):
        s.check(i % 2, 1.0 + 0.1 * i)
    path = str(tmp_path / "screen")
    ckpt_io.save_obj(path, s.state_dict())
    s2 = NormScreen(sigma=4.0)
    s2.load_state(ckpt_io.load_obj(path))
    assert s2.history.keys() == s.history.keys()
    for p in s.history:        # windows persist as float32 arrays
        np.testing.assert_allclose(s2.history[p], s.history[p], rtol=1e-6)


def test_delta_norm_ignores_non_float_leaves():
    leaves = [np.ones(3, np.float32), np.arange(4, dtype=np.int32)]
    base = [np.zeros(3, np.float32), np.zeros(4, np.int32)]
    assert delta_norm(leaves, base) == pytest.approx(np.sqrt(3.0))


# ---------------------------------------------------------------------------
# robust aggregators: fedavg reduction + outlier invariance
# ---------------------------------------------------------------------------

def _stack(rows):
    return {"w": np.stack([r for r in rows]).astype(np.float32)}


def test_trimmed_mean_trim0_is_fedavg_bitwise():
    rng = np.random.default_rng(5)
    stack = {"w": rng.normal(size=(5, 4, 3)).astype(np.float32),
             "b": rng.normal(size=(5, 7)).astype(np.float32)}
    weights = rng.uniform(0.5, 2.0, 5)
    ref = tree_weighted_mean_stacked(stack, weights)
    out = tree_trimmed_mean_stacked(stack, weights, trim=0)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trimmed_mean_rejects_overtrim():
    stack = {"w": np.ones((3, 2), np.float32)}
    with pytest.raises(ValueError, match="trim"):
        tree_trimmed_mean_stacked(stack, np.ones(3), trim=2)


def test_trimmed_mean_masks_nonfinite_in_trim_region():
    """NaN sorts last and lands in the trim region; it must be excluded
    by where(), not a 0-weight product (NaN * 0 = NaN)."""
    honest = np.array([1.0, 2.0, 3.0], np.float32)
    rows = [honest] * 3 + [np.full(3, np.nan, np.float32)]
    out = np.asarray(tree_trimmed_mean_stacked(
        _stack(rows), np.ones(4), trim=1)["w"])
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, honest, rtol=1e-6)


@pytest.mark.parametrize("b", [1, 2, 3])
def test_robust_aggregators_recover_honest_value(b):
    """b arbitrary outliers among 2b+1 honest (identical) uploads leave
    both robust aggregates at exactly the honest value."""
    rng = np.random.default_rng(b)
    honest = rng.normal(size=(4,)).astype(np.float32)
    rows = [honest] * (2 * b + 1) + \
        [rng.normal(size=(4,)).astype(np.float32) * 1e6 for _ in range(b)]
    order = rng.permutation(len(rows))
    stack = _stack([rows[i] for i in order])
    w = np.ones(len(rows))
    tm = np.asarray(tree_trimmed_mean_stacked(stack, w, trim=b)["w"])
    cm = np.asarray(tree_coordinate_median_stacked(stack, w)["w"])
    np.testing.assert_allclose(tm, honest, rtol=1e-6)
    np.testing.assert_array_equal(cm, honest)


if HAVE_HYPOTHESIS:

    @given(b=st.integers(1, 3), dim=st.integers(1, 6),
           seed=st.integers(0, 100),
           outlier_scale=st.sampled_from([-1e8, -10.0, 10.0, 1e8]))
    @settings(**SETTINGS)
    def test_hyp_outlier_invariance(b, dim, seed, outlier_scale):
        rng = np.random.default_rng(seed)
        honest = rng.normal(size=(dim,)).astype(np.float32)
        rows = [honest] * (2 * b + 1) + \
            [honest + np.float32(outlier_scale) * (1 + rng.random(dim))
             .astype(np.float32) for _ in range(b)]
        order = rng.permutation(len(rows))
        stack = _stack([rows[i] for i in order])
        w = np.ones(len(rows))
        tm = np.asarray(tree_trimmed_mean_stacked(stack, w, trim=b)["w"])
        cm = np.asarray(tree_coordinate_median_stacked(stack, w)["w"])
        np.testing.assert_allclose(tm, honest, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(cm, honest)

    @given(k=st.integers(1, 8), dim=st.integers(1, 5),
           seed=st.integers(0, 100))
    @settings(**SETTINGS)
    def test_hyp_trim0_reduces_to_fedavg(k, dim, seed):
        rng = np.random.default_rng(seed)
        stack = {"w": rng.normal(size=(k, dim)).astype(np.float32)}
        w = rng.uniform(0.1, 3.0, k)
        ref = np.asarray(tree_weighted_mean_stacked(stack, w)["w"])
        out = np.asarray(tree_trimmed_mean_stacked(stack, w, trim=0)["w"])
        np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# FedDF teacher-consensus filter
# ---------------------------------------------------------------------------

def _teacher_stack(net, keys, poison=()):
    params = [net.init(jax.random.PRNGKey(k)) for k in keys]
    for i, kind in poison:
        leaves, treedef = jax.tree.flatten(params[i])
        first = np.array(leaves[0], np.float32)
        if kind == "nan":
            first.reshape(-1)[0] = np.nan
        else:  # diverged: absurdly scaled weights
            first = first * 1e4
        params[i] = jax.tree.unflatten(treedef, [first] + leaves[1:])
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *params)


def test_teacher_filter_drops_poisoned_keeps_honest():
    net = mlp(2, 3, hidden=(8,))
    probe = np.random.default_rng(0).normal(size=(16, 2)).astype(np.float32)
    honest = _teacher_stack(net, [0, 1, 2, 3])
    kept, dropped = filter_teacher_stack(net, honest, probe, sigma=6.0)
    assert dropped == 0 and list(kept) == [0, 1, 2, 3]
    poisoned = _teacher_stack(net, [0, 1, 2, 3],
                              poison=[(1, "nan"), (3, "diverged")])
    kept, dropped = filter_teacher_stack(net, poisoned, probe, sigma=6.0)
    assert dropped == 2 and list(kept) == [0, 2]


def test_teacher_filter_all_poisoned_returns_empty():
    net = mlp(2, 3, hidden=(8,))
    probe = np.zeros((4, 2), np.float32)
    stack = _teacher_stack(net, [0, 1], poison=[(0, "nan"), (1, "nan")])
    kept, dropped = filter_teacher_stack(net, stack, probe)
    assert kept.size == 0 and dropped == 2


# ---------------------------------------------------------------------------
# registry / pytree seams
# ---------------------------------------------------------------------------

def test_registry_quarantine_counters_and_backcompat(tmp_path):
    reg = ClientRegistry(8, partition_sizes=[10] * 4,
                         client_steps=[5] * 4, client_proto=[0] * 4,
                         client_bucket=[0] * 4)
    reg.record_dispatch(np.array([2, 3]), wave=1)
    pri = float(reg.priority[2])
    reg.record_quarantine([2])
    assert reg.quarantines[2] == 1 and not reg.in_flight[2]
    assert float(reg.priority[2]) == pytest.approx(0.5 * pri)
    # pre-PR 8 checkpoints have no quarantine column: defaults to zeros
    state = reg.state_dict()
    del state["quarantines"]
    old = ClientRegistry.from_state(state)
    assert int(old.quarantines.sum()) == 0
    assert old.size == reg.size


def test_tree_check_like_names_the_mismatch():
    like = {"w": np.zeros((1, 4), np.float32), "b": np.zeros((1,), np.float32)}
    tree_check_like(dict(like), like, what="upload")     # clean: no raise
    with pytest.raises(ValueError, match="shape"):
        tree_check_like({"w": np.zeros((1, 5), np.float32),
                         "b": np.zeros((1,), np.float32)}, like, what="upload")
    with pytest.raises(ValueError, match="dtype"):
        tree_check_like({"w": np.zeros((1, 4), np.float64),
                         "b": np.zeros((1,), np.float32)}, like, what="upload")
    with pytest.raises(ValueError, match="upload"):
        tree_check_like({"w": np.zeros((1, 4), np.float32)}, like,
                        what="upload")


def test_push_wave_validates_upload_structure():
    from repro.population import PopulationManager
    from repro.population.config import PopulationConfig
    from repro.population.scheduler import SamplerContext, make_sampler

    class _G:
        stack = {"w": np.zeros((4, 2), np.float32)}
        weights = np.ones(4)

    ctx = SamplerContext(n_clients=8, n_partitions=8,
                         proto=np.zeros(8, int), bucket=np.zeros(8, int),
                         bucket_client_caps=[[8]])
    m = PopulationManager(
        PopulationConfig(size=8), seed=0, n_partitions=8,
        partition_sizes=[10] * 8, client_steps=[5] * 8,
        client_proto=[0] * 8, client_bucket=[0] * 8, n_active=4,
        sampler=make_sampler("uniform").bind(ctx))
    rng = np.random.default_rng(0)
    w, cohort = m.next_wave(rng)
    assert m.push_wave(w, cohort, [_G()], base_version=0) == 4
    # second wave uploads a different structure: loud error, not NaN soup
    bad = _G()
    bad.stack = {"w": np.zeros((4, 3), np.float32)}
    w2, cohort2 = m.next_wave(rng)
    with pytest.raises(ValueError, match="proto 0 upload.*shape"):
        m.push_wave(w2, cohort2, [bad], base_version=0)


# ---------------------------------------------------------------------------
# end-to-end: sync driver chaos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


def small_cfg(strategy="feddf", rounds=2, **kw):
    kw.setdefault("client_fraction", 0.5)
    kw.setdefault("local_epochs", 3)
    return FLConfig(strategy=strategy, rounds=rounds,
                    local_batch_size=32, local_lr=0.05, seed=0,
                    fusion=FusionConfig(max_steps=50, patience=50,
                                        eval_every=25, batch_size=32), **kw)


def _run(problem, cfg):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    return run_rounds([net], [0] * len(parts), train, parts, val, test,
                      cfg, source=src, driver="sync")


def _assert_same_run(a, b):
    res_a, glob_a, rtt_a = a
    res_b, glob_b, rtt_b = b
    assert rtt_a == rtt_b
    for ra, rb in zip(res_a, res_b):
        assert ra.logs == rb.logs
    for ga, gb in zip(glob_a, glob_b):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("strategy", ["fedavg", "feddf"])
def test_faultfree_config_is_bit_identical(problem, strategy):
    """Quorum / retry / screen knobs with zero injection rates must not
    perturb the trajectory — the fault seam is a strict no-op."""
    base = _run(problem, small_cfg(strategy=strategy))
    armed = _run(problem, small_cfg(
        strategy=strategy,
        faults=FaultConfig(quorum=0.9, retries=5, backoff=4.0,
                           norm_sigma=2.0, teacher_sigma=2.0)))
    _assert_same_run(base, armed)


def test_chaos_sync_defense_bounds_drift(problem):
    """Byzantine + NaN uploads: the undefended run visibly degrades,
    the screened run tracks the fault-free accuracy within 1 pt."""
    chaos = dict(byzantine_frac=0.3, byzantine_scale=10.0, nan_rate=0.15)
    clean = _run(problem, small_cfg("fedavg", rounds=5, client_fraction=1.0))
    defended = _run(problem, small_cfg(
        "fedavg", rounds=5, client_fraction=1.0,
        faults=FaultConfig(**chaos)))
    undefended = _run(problem, small_cfg(
        "fedavg", rounds=5, client_fraction=1.0,
        faults=FaultConfig(**chaos, screen="off", teacher_filter="off")))
    acc = lambda r: r[0][0].logs[-1].test_acc
    assert acc(undefended) < acc(clean) - 0.1          # visible damage
    assert abs(acc(defended) - acc(clean)) <= 0.01     # bounded drift
    for leaf in jax.tree.leaves(defended[1][0]):
        assert bool(np.isfinite(np.asarray(leaf)).all())
    logs = defended[0][0].logs
    assert sum(l.n_corrupted for l in logs) > 0
    assert sum(l.n_quarantined for l in logs) > 0


def test_chaos_sync_quorum_skips_fusion(problem):
    """Every upload NaN-poisoned: screening quarantines the full cohort,
    the quorum shortfall skips fusion and the globals carry over."""
    out = _run(problem, small_cfg(
        "fedavg", rounds=2,
        faults=FaultConfig(nan_rate=1.0, quorum=0.5, retries=1)))
    logs = out[0][0].logs
    assert all(not l.fused for l in logs)
    assert all(l.n_quarantined == 3 for l in logs)      # K = 6 * 0.5
    assert all(l.n_retries == 3 for l in logs)          # 1 retry each
    assert logs[0].test_acc == logs[1].test_acc          # globals frozen
    for leaf in jax.tree.leaves(out[1][0]):
        assert bool(np.isfinite(np.asarray(leaf)).all())


def test_chaos_feddf_teacher_filter(problem):
    """Screening off, teacher filter on: poisoned teachers are dropped
    before distillation and the fused student stays finite."""
    out = _run(problem, small_cfg(
        "feddf", rounds=2,
        faults=FaultConfig(nan_rate=0.6, screen="off")))
    logs = out[0][0].logs
    assert sum(l.n_teachers_filtered for l in logs) > 0
    for leaf in jax.tree.leaves(out[1][0]):
        assert bool(np.isfinite(np.asarray(leaf)).all())


# ---------------------------------------------------------------------------
# end-to-end: buffered_async chaos
# ---------------------------------------------------------------------------

def api_spec(driver=None, strategy="feddf", rounds=3, **kw):
    return ExperimentSpec(
        task=TaskSpec(name="blobs", n_samples=1200),
        partition=PartitionSpec(n_clients=6, alpha=1.0),
        cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                {"hidden": [16, 16]})]),
        strategy=StrategySpec(name=strategy,
                              fusion=FusionSpec(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32)),
        source=(SourceSpec(name="unlabeled", params={"n": 500})
                if strategy == "feddf" else None),
        driver=driver if driver is not None else DriverSpec(),
        rounds=rounds, local_batch_size=32, local_lr=0.05, seed=0,
        **{"client_fraction": 0.5, "local_epochs": 3, **kw})


def test_chaos_buffered_completes_with_telemetry():
    spec = api_spec(
        DriverSpec(kind="buffered_async"), strategy="fedavg", rounds=3,
        population=PopulationSpec(size=12, buffer_size=3, max_staleness=4,
                                  traffic=TrafficSpec(latency=1.0,
                                                      jitter=0.2)),
        faults=FaultSpec(nan_rate=0.3, byzantine_frac=0.25, crash_rate=0.1,
                         quorum=0.5, retries=0))
    res = Experiment(spec).run()
    assert [l.round for l in res.result.logs] == [1, 2, 3]
    s = res.summary()
    assert s["faults"]["corrupted_uploads"] > 0
    assert s["faults"]["quarantined_uploads"] > 0
    for leaf in jax.tree.leaves(res.global_params[0]):
        assert bool(np.isfinite(np.asarray(leaf)).all())


def test_chaos_buffered_quorum_skips_all_rounds():
    """nan_rate=1.0 quarantines every upload: with a quorum the buffered
    driver skips each round (fused=False) instead of raising."""
    spec = api_spec(
        DriverSpec(kind="buffered_async"), strategy="fedavg", rounds=2,
        local_epochs=1,
        population=PopulationSpec(size=12, buffer_size=3),
        faults=FaultSpec(nan_rate=1.0, retries=0, quorum=0.5))
    res = Experiment(spec).run()
    logs = res.result.logs
    assert [l.round for l in logs] == [1, 2]
    assert all(not l.fused for l in logs)
    assert all(l.n_quarantined > 0 for l in logs)
    assert res.summary()["faults"]["rounds_skipped"] == 2


def test_faultfree_buffered_bit_identical():
    pop = PopulationSpec(size=12, buffer_size=3, max_staleness=4,
                         traffic=TrafficSpec(latency=1.0, jitter=0.2))
    base = Experiment(api_spec(DriverSpec(kind="buffered_async"),
                               strategy="fedavg", population=pop)).run()
    armed = Experiment(api_spec(
        DriverSpec(kind="buffered_async"), strategy="fedavg",
        population=pop,
        faults=FaultSpec(quorum=0.9, retries=4, norm_sigma=2.0))).run()
    assert base.result.logs == armed.result.logs
    for x, y in zip(jax.tree.leaves(base.global_params[0]),
                    jax.tree.leaves(armed.global_params[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# checkpoint atomicity: kill mid-write
# ---------------------------------------------------------------------------

class _Bomb(Exception):
    pass


def test_checkpoint_survives_kill_mid_write(tmp_path, monkeypatch):
    path = str(tmp_path / "g")
    v1 = {"w": np.ones((3, 2), np.float32)}
    v2 = {"w": np.full((3, 2), 9.0, np.float32)}
    ckpt_io.save(path, v1, {"v": 1})

    # crash while the payload temp file is being written: neither the
    # .npz nor the manifest may change
    real_fsync = os.fsync
    monkeypatch.setattr(ckpt_io.os, "fsync",
                        lambda fd: (_ for _ in ()).throw(_Bomb()))
    with pytest.raises(_Bomb):
        ckpt_io.save(path, v2, {"v": 2})
    monkeypatch.setattr(ckpt_io.os, "fsync", real_fsync)
    out = ckpt_io.restore(path, like=v1)
    np.testing.assert_array_equal(np.asarray(out["w"]), v1["w"])
    assert ckpt_io.metadata(path)["v"] == 1

    # crash between the payload replace and the manifest replace: the
    # manifest still describes a loadable checkpoint
    calls = {"n": 0}
    real_replace = os.replace

    def bomb_second(src, dst):
        calls["n"] += 1
        if calls["n"] == 2:
            raise _Bomb()
        return real_replace(src, dst)

    monkeypatch.setattr(ckpt_io.os, "replace", bomb_second)
    with pytest.raises(_Bomb):
        ckpt_io.save(path, v2, {"v": 2})
    monkeypatch.setattr(ckpt_io.os, "replace", real_replace)
    out = ckpt_io.restore(path, like=v1)
    assert np.isfinite(np.asarray(out["w"])).all()
    # a clean retry fully commits v2
    ckpt_io.save(path, v2, {"v": 2})
    np.testing.assert_array_equal(
        np.asarray(ckpt_io.restore(path, like=v1)["w"]), v2["w"])
    assert ckpt_io.metadata(path)["v"] == 2


def test_save_obj_atomic_kill_mid_write(tmp_path, monkeypatch):
    path = str(tmp_path / "s")
    ckpt_io.save_obj(path, {"state": [np.arange(3), 7]})
    monkeypatch.setattr(ckpt_io.os, "fsync",
                        lambda fd: (_ for _ in ()).throw(_Bomb()))
    with pytest.raises(_Bomb):
        ckpt_io.save_obj(path, {"state": [np.arange(9), 8]})
    monkeypatch.undo()
    obj = ckpt_io.load_obj(path)
    np.testing.assert_array_equal(np.asarray(obj["state"][0]), np.arange(3))
    assert obj["state"][1] == 7


# ---------------------------------------------------------------------------
# spec layer: round trips, validation, CLI flags, back-compat
# ---------------------------------------------------------------------------

def test_fault_spec_round_trips():
    spec = api_spec(faults=FaultSpec(nan_rate=0.1, byzantine_frac=0.2,
                                     quorum=0.6, retries=3))
    spec.validate()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    d = spec.to_dict()["faults"]
    assert d["nan_rate"] == 0.1 and d["quorum"] == 0.6


def test_fault_spec_back_compat_and_unknown_keys():
    d = api_spec().to_dict()
    del d["faults"]                   # pre-PR 8 spec
    assert ExperimentSpec.from_dict(d).faults == FaultSpec()
    with pytest.raises(ValueError, match="unknown field"):
        FaultSpec.from_dict({"nan_rate": 0.1, "nope": 1})


@pytest.mark.parametrize("faults,match", [
    (FaultSpec(nan_rate=1.5), "nan_rate"),
    (FaultSpec(byzantine_frac=-0.1), "byzantine_frac"),
    (FaultSpec(byzantine_mode="nope"), "byzantine_mode"),
    (FaultSpec(byzantine_scale=0.0), "byzantine_scale"),
    (FaultSpec(bitflip_bits=0), "bitflip_bits"),
    (FaultSpec(screen="maybe"), "screen"),
    (FaultSpec(norm_sigma=0.0), "norm_sigma"),
    (FaultSpec(quorum=0.0), "quorum"),
    (FaultSpec(retries=-1), "retries"),
    (FaultSpec(backoff=0.5), "backoff"),
])
def test_fault_spec_validation(faults, match):
    with pytest.raises(ValueError, match=match):
        api_spec(faults=faults).validate()


def test_trim_frac_validation():
    spec = api_spec(strategy="fedavg")
    spec.strategy.trim_frac = 0.5
    with pytest.raises(ValueError, match="trim_frac"):
        spec.validate()


def test_cli_fault_flags_round_trip(tmp_path):
    from repro.launch.train import main
    cfg_path = str(tmp_path / "spec.json")
    main(["--strategy", "fedavg", "--rounds", "1", "--clients", "4",
          "-C", "1.0", "--local-epochs", "2", "--n-samples", "400",
          "--checkpoint-every", "0",
          "--faults-nan", "0.1", "--faults-byzantine", "0.25",
          "--faults-byzantine-scale", "8", "--faults-byzantine-mode",
          "scale", "--faults-bitflip", "0.05", "--faults-crash", "0.02",
          "--screen", "on", "--teacher-filter", "off",
          "--quorum", "0.5", "--retries", "3", "--backoff", "1.5",
          "--robust-agg", "trimmed_mean", "--trim-frac", "0.25",
          "--dump-config", cfg_path, "--out", str(tmp_path / "a")])
    spec = ExperimentSpec.load(cfg_path)
    assert spec.faults == FaultSpec(
        nan_rate=0.1, byzantine_frac=0.25, byzantine_scale=8.0,
        byzantine_mode="scale", bitflip_rate=0.05, crash_rate=0.02,
        screen="on", teacher_filter="off", quorum=0.5, retries=3,
        backoff=1.5)
    assert spec.strategy.name == "trimmed_mean"
    assert spec.strategy.trim_frac == 0.25
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    summary = json.load(open(tmp_path / "a" / "summary.json"))
    assert summary["config"] == spec.to_dict()
    assert summary["config"]["faults"]["quorum"] == 0.5


def test_roundlog_fault_fields_back_compat():
    old = {"round": 1, "test_acc": 0.5, "val_acc": 0.5}
    log = RoundLog(**old)
    assert log.fused and not log.rolled_back
    assert (log.n_corrupted, log.n_quarantined, log.n_retries,
            log.n_teachers_filtered) == (0, 0, 0, 0)

"""Figure 6(b,c): FedDF is undemanding on distillation-set size (1% of
data already works) and a moderate number of distillation steps approaches
optimal performance."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import default_problem, emit, fl_cfg, fusion_cfg, scale
from repro.core import mlp, run_federated
from repro.data import UnlabeledDataset


def run(seed: int = 0) -> dict:
    rounds = scale(4, 10)
    t0 = time.time()
    train, val, test, parts, _ = default_problem(seed=seed, alpha=1.0)
    net = mlp(2, 3, hidden=(48, 48))
    pool = np.random.default_rng(seed + 7).uniform(-3, 3, (3000, 2)) \
        .astype(np.float32)
    results = {}
    # --- dataset size sweep (Fig 6b)
    for frac in (0.01, 0.1, 1.0):
        src = UnlabeledDataset(pool[: max(int(len(pool) * frac), 8)])
        cfg = fl_cfg("feddf", rounds, seed=seed)
        res = run_federated(net, train, parts, val, test, cfg, source=src)
        results[f"size={frac}"] = res.best_acc
    # --- distillation steps sweep (Fig 6c)
    for steps in (20, 100, 400):
        cfg = fl_cfg("feddf", rounds, seed=seed, fusion=fusion_cfg(steps))
        res = run_federated(net, train, parts, val, test, cfg,
                            source=UnlabeledDataset(pool))
        results[f"steps={steps}"] = res.best_acc
    dt = time.time() - t0
    claims = {
        "one_percent_data_works":
            results["size=0.01"] >= results["size=1.0"] - 0.05,
        "moderate_steps_suffice":
            results["steps=100"] >= results["steps=400"] - 0.04,
    }
    emit("fig6_distill_steps", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

"""Table 4: federated learning with 1-bit binarized clients (STE local
training).  Paper: FedDF matches/bests FedAvg on binarized ResNet-8 without
GN tuning, at ~1/10 the uplink bytes."""
from __future__ import annotations

import time

import jax

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated
from repro.core.quantize import binarize, comm_bytes


def run(seed: int = 0) -> dict:
    rounds = scale(6, 15)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=1.0)
    net = mlp(2, 3, hidden=(48, 48), norm="none")
    results = {}
    for name, (strat, source) in {
        "fedavg_binary": ("fedavg", None),
        "feddf_binary": ("feddf", src),
    }.items():
        cfg = fl_cfg(strat, rounds, seed=seed, quantize=binarize,
                     local_lr=0.1)
        res = run_federated(net, train, parts, val, test, cfg, source=source)
        results[name] = {"best_acc": res.best_acc,
                         "final_acc": res.final_acc}
    p0 = net.init(jax.random.PRNGKey(0))
    results["uplink_bytes_fp32"] = comm_bytes(p0)
    results["uplink_bytes_binary"] = comm_bytes(p0, binarized=True)
    dt = time.time() - t0
    claims = {
        "feddf_binary_at_least_fedavg":
            results["feddf_binary"]["best_acc"]
            >= results["fedavg_binary"]["best_acc"] - 0.02,
        "binary_compression_over_8x":
            results["uplink_bytes_fp32"]
            > 8 * results["uplink_bytes_binary"],
    }
    emit("table4_lowbit", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

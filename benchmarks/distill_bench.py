"""Distillation fast-path benchmark (ISSUE 3 + ISSUE 6 acceptance).

``--case all`` (default) measures the teacher-logit bank
(``core/logit_bank.py``) against the on-the-fly teacher-forward path:

 * homogeneous K=8 toy config: steady-state distill steps/sec, measured
   as MARGINAL throughput between a short and a long run of the same
   config — the one-time jit compile and bank build cancel in the
   difference (both are also reported).  The bank path must be >= 2x on
   CPU.
 * one G=3 heterogeneous round: teacher batch-forwards counted via
   ``TEACHER_FORWARDS`` — the bank is built once and shared by all G
   group-students, so the count must drop >= G x.

``--case quantized`` measures the int8 bank against the fp32 bank at
C=64 (where the ``N x C x 1 + N x 4`` vs ``N x C x 4`` formula gives a
>= 3.5x shrink): device bank bytes, marginal distill steps/sec, and the
distilled student's teacher-agreement drift (must stay <= 0.5pt).  It
also writes analytic per-distill-step roofline records (bytes moved /
FLOPs, fused kernel vs unfused gather-then-KL) into
``experiments/dryrun/`` where ``benchmarks/roofline_report.py`` picks
them up next to the dry-run sweep.

Writes ``BENCH_distill.json`` / ``BENCH_distill_quant.json`` (override
with ``BENCH_DISTILL_OUT`` / ``BENCH_DISTILL_QUANT_OUT``) so CI's
bench-smoke job records the perf trajectory, and emits the usual CSV
lines via ``benchmarks.common.emit``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench
from repro.common.pytree import tree_stack, tree_weighted_mean_stacked
from repro.core import mlp
from repro.core.feddf import (FusionConfig, distill,
                              feddf_fuse_heterogeneous_stacked,
                              make_teacher_logits_fn)
from repro.core.logit_bank import TEACHER_FORWARDS
from repro.data.distill_sources import UnlabeledDataset

K = 8
POOL_N = 2048
DIM, CLASSES = 16, 10
CLASSES_Q = 64  # quantized case: 4C/(C+4) >= 3.5x needs C >= 56
OUT = os.environ.get("BENCH_DISTILL_OUT", "BENCH_distill.json")
OUT_QUANT = os.environ.get("BENCH_DISTILL_QUANT_OUT",
                           "BENCH_distill_quant.json")


def _teachers(net, k, seed0=0):
    return tree_stack([net.init(jax.random.PRNGKey(seed0 + i))
                       for i in range(k)])


def _pool(n, dim, seed=0):
    return np.random.default_rng(seed).uniform(
        -3, 3, (n, dim)).astype(np.float32)


def _fusion(steps, mode, batch):
    return FusionConfig(max_steps=steps, patience=10 * steps,
                        eval_every=100, batch_size=batch,
                        use_fused_kernel=False, logit_bank=mode)


def homogeneous(short, long_):
    net = mlp(DIM, CLASSES, hidden=(128, 128))
    stack = _teachers(net, K)
    tfn = make_teacher_logits_fn(net, stack)
    student = tree_weighted_mean_stacked(stack, np.ones(K))
    src = UnlabeledDataset(_pool(POOL_N, DIM))

    def timed(steps, mode, reps=2):
        # min over reps: a GC pause / noisy neighbour inflating one run
        # would otherwise corrupt the marginal estimate below
        best, info = None, None
        for _ in range(reps):
            t0 = time.time()
            params, info = distill(net, student, [tfn], src,
                                   _fusion(steps, mode, 256), seed=0)
            jax.block_until_ready(jax.tree.leaves(params)[0])
            wall = time.time() - t0
            best = wall if best is None else min(best, wall)
        return best, info

    out = {}
    for mode in ("off", "on"):
        t_short, _ = timed(short, mode)
        t_long, info = timed(long_, mode)
        out[mode] = {
            "wall_short_s": t_short, "wall_long_s": t_long,
            # compile (and for the bank path, the build) cancels in the
            # difference: this is the per-step loop throughput.  The floor
            # keeps a pathological timer inversion from emitting a
            # negative/absurd rate
            "steps_per_s": (long_ - short) / max(t_long - t_short, 1e-3),
            "bank_build_s": info["bank_build_s"],
            "teacher_batch_forwards": info["teacher_batch_forwards"]}
    speedup = out["on"]["steps_per_s"] / out["off"]["steps_per_s"]
    rec = {"K": K, "dim": DIM, "classes": CLASSES, "hidden": [128, 128],
           "batch": 256, "steps_short": short, "steps_long": long_,
           "pool_n": POOL_N, "speedup": speedup,
           "onthefly": out["off"], "bank": out["on"]}
    emit("distill_homog_K8", 1.0 / out["on"]["steps_per_s"],
         f"speedup_x{speedup:.2f}", record=rec)
    return rec


def heterogeneous(steps):
    G = 3
    nets = [mlp(2, 3, hidden=(32,), name="s"),
            mlp(2, 3, hidden=(48, 48), name="m"),
            mlp(2, 3, hidden=(64,), name="l")]
    protos = [(nets[g], _teachers(nets[g], 2, seed0=10 * g), [1.0, 1.0])
              for g in range(G)]
    src = UnlabeledDataset(_pool(POOL_N, 2, seed=1))

    counts, walls = {}, {}
    for mode in ("off", "on"):
        TEACHER_FORWARDS.reset()
        t0 = time.time()
        fused, _ = feddf_fuse_heterogeneous_stacked(
            protos, src, _fusion(steps, mode, 128), seed=0)
        jax.block_until_ready(jax.tree.leaves(fused[-1])[0])
        walls[mode] = time.time() - t0
        counts[mode] = TEACHER_FORWARDS.count
    rec = {"G": G, "steps": steps,
           "teacher_forwards_onthefly": counts["off"],
           "teacher_forwards_bank": counts["on"],
           "forward_reduction_x": counts["off"] / max(1, counts["on"]),
           "wall_onthefly_s": walls["off"], "wall_bank_s": walls["on"]}
    emit("distill_hetero_G3", walls["on"],
         f"fwd_reduction_x{rec['forward_reduction_x']:.0f}", record=rec)
    return rec


def quantized(short, long_):
    """int8 bank vs fp32 bank at C=64: device bytes, MARGINAL distill
    steps/sec (compile + bank build cancel in the long-short difference)
    and teacher-agreement drift of the distilled student.  Both runs use
    the jnp (unfused) path — the CPU production path under
    ``use_fused_kernel='auto'`` — so the ratio isolates the bank dtype."""
    net = mlp(DIM, CLASSES_Q, hidden=(128, 128))
    stack = _teachers(net, K)
    tfn = make_teacher_logits_fn(net, stack)
    student = tree_weighted_mean_stacked(stack, np.ones(K))
    src = UnlabeledDataset(_pool(POOL_N, DIM))
    # held-out probe labelled by the teacher ensemble itself: "accuracy"
    # here is agreement with the AVGLOGITS distillation target, the only
    # ground truth this synthetic config has
    eval_x = jnp.asarray(_pool(1024, DIM, seed=7))
    labels = np.asarray(jnp.argmax(jnp.mean(
        tfn(eval_x).astype(jnp.float32), axis=0), axis=-1))

    def fusion(steps, dtype):
        return FusionConfig(max_steps=steps, patience=10 * steps,
                            eval_every=100, batch_size=256,
                            use_fused_kernel=False, logit_bank="on",
                            bank_dtype=dtype)

    def timed(steps, dtype, reps=2):
        best, out = None, None
        for _ in range(reps):
            t0 = time.time()
            params, info = distill(net, student, [tfn], src,
                                   fusion(steps, dtype), seed=0)
            jax.block_until_ready(jax.tree.leaves(params)[0])
            wall = time.time() - t0
            if best is None or wall < best:
                best, out = wall, (params, info)
        return best, out

    res = {}
    for dtype in ("float32", "int8"):
        t_short, _ = timed(short, dtype)
        t_long, (params, info) = timed(long_, dtype)
        pred = np.asarray(jnp.argmax(
            net.apply(params, eval_x, train=False), axis=-1))
        res[dtype] = {
            "wall_short_s": t_short, "wall_long_s": t_long,
            "steps_per_s": (long_ - short) / max(t_long - t_short, 1e-3),
            "bank_nbytes": info["bank_nbytes"],
            "bank_dtype": info["bank_dtype"],
            "teacher_agreement": float((pred == labels).mean())}
    rec = {"K": K, "dim": DIM, "classes": CLASSES_Q, "hidden": [128, 128],
           "batch": 256, "steps_short": short, "steps_long": long_,
           "pool_n": POOL_N,
           "bank_bytes_reduction_x":
               res["float32"]["bank_nbytes"] / res["int8"]["bank_nbytes"],
           "marginal_steps_per_s_ratio":
               res["int8"]["steps_per_s"] / res["float32"]["steps_per_s"],
           "teacher_agreement_drift":
               abs(res["int8"]["teacher_agreement"]
                   - res["float32"]["teacher_agreement"]),
           "float32": res["float32"], "int8": res["int8"]}
    emit("distill_quantized_bank", 1.0 / res["int8"]["steps_per_s"],
         f"bytes_x{rec['bank_bytes_reduction_x']:.2f}", record=rec)
    return rec


def roofline_records(b=256, c=CLASSES_Q, out_dir=None):
    """Analytic per-distill-step roofline entries for the bank -> KL loss
    stage, fused kernel vs unfused gather-then-``ensemble_kl_pre``, per
    bank dtype — written as dry-run-style baseline records so
    ``benchmarks/roofline_report.py`` tables them next to the sweep.

    Byte accounting (fp32 student logits [B, C] are an input either way):
    the unfused path round-trips the dequantized teacher rows, both
    log-softmax outputs and the KL product through HBM (4 intermediates,
    write + read each); the fused kernel streams the bank tile once and
    emits only three per-row statistics.  FLOPs are identical up to the
    per-element dequantize multiply, so quantization + fusion moves the
    stage toward the compute roof.
    """
    from repro.launch import mesh as mesh_mod
    out_dir = out_dir or os.path.join(os.path.dirname(__file__), "..",
                                      "experiments", "dryrun")
    os.makedirs(out_dir, exist_ok=True)
    recs = []
    for dtype, item in (("float32", 4), ("int8", 1)):
        scales = b * 4 if item == 1 else 0
        inputs = b * c * item + scales + b * c * 4  # bank rows + student
        flops = 14 * b * c + (b * c if item == 1 else 0)
        for variant, extra, outputs in (
                ("unfused", 4 * 2 * b * c * 4, b * 4),  # 4 HBM round trips
                ("fused", 0, 3 * b * 4)):               # kl + 2 lse rows
            bytes_moved = inputs + extra + outputs
            terms = {"compute_s": flops / mesh_mod.PEAK_FLOPS_BF16,
                     "memory_s": bytes_moved / mesh_mod.HBM_BW,
                     "collective_s": 0.0}
            rec = {"arch": f"distill_kl_{variant}",
                   "shape": f"b{b}c{c}_{dtype}", "mesh": "1chip",
                   "variant": "baseline", "ok": True,
                   "bytes_per_step": bytes_moved, "flops_per_step": flops,
                   "roofline": {**terms,
                                "dominant": max(terms, key=terms.get),
                                "useful_flops_ratio": 1.0}}
            path = os.path.join(out_dir, f"{rec['arch']}__{rec['shape']}__"
                                         f"{rec['mesh']}__baseline.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            recs.append(rec)
    return recs


def run(case: str = "all") -> None:
    if case == "all":
        result = {"homogeneous": homogeneous(scale(200, 400),
                                             scale(1200, 2400)),
                  "heterogeneous": heterogeneous(scale(300, 1000))}
        finish_bench("distill", result, out=OUT,
                     config={"steps_short": scale(200, 400),
                             "steps_long": scale(1200, 2400)})
        print(f"wrote {OUT}: homog speedup "
              f"x{result['homogeneous']['speedup']:.2f}, hetero forward "
              f"reduction "
              f"x{result['heterogeneous']['forward_reduction_x']:.0f}")
        return
    assert case == "quantized", case
    result = quantized(scale(200, 400), scale(1200, 2400))
    result["roofline_records"] = roofline_records()
    finish_bench("distill_quant", result, out=OUT_QUANT,
                 config={"steps_short": scale(200, 400),
                         "steps_long": scale(1200, 2400)})
    print(f"wrote {OUT_QUANT}: bank bytes "
          f"x{result['bank_bytes_reduction_x']:.2f} smaller, marginal "
          f"steps/sec x{result['marginal_steps_per_s_ratio']:.2f}, "
          f"agreement drift {result['teacher_agreement_drift']:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", default="all", choices=["all", "quantized"])
    run(ap.parse_args().case)

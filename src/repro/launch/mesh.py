"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Target hardware: TPU v5e — 256 chips per pod in a
16x16 2D arrangement; the multi-pod mesh adds a leading "pod" axis over the
data-center network.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU integration tests (requires
    xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_mesh(hosts: int | None = None,
                   model: int = 1) -> jax.sharding.Mesh:
    """("data", "model") mesh for the multi-host fed-round driver
    (``repro.drivers.multihost.drive_fed_rounds``): each "data" slice
    holds whole client replicas (clients shard over it), "model" is the
    within-client tensor-parallel width.  Defaults to every visible
    device on the data axis — on a simulated mesh set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first."""
    hosts = hosts or len(jax.devices()) // model
    return jax.make_mesh((hosts, model), ("data", "model"))


def make_client_mesh(n: int | None = None) -> jax.sharding.Mesh:
    """1-D ("data",) mesh for the federated round engine: the stacked
    client axis of ``make_batched_local_update`` shards over it, so K
    active clients train data-parallel.  Unbucketed homogeneous runs need
    K to divide ``n``; heterogeneous / bucketed runs pad their client
    capacities up to divisibility (docs/bucketing.md).  Defaults to every
    visible device."""
    n = n or len(jax.devices())
    return jax.make_mesh((n,), ("data",))

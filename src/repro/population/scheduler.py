"""Cohort sampling policies behind a registry (mirrors core/strategies.py).

The scheduler owns the *who trains next* decision.  ``RoundEngine``
delegates its historic ``rng.choice`` draw here (``uniform`` with a full
population reproduces it bit-for-bit), while the buffered-async driver
passes an availability mask so offline / in-flight clients are skipped.

Samplers:

- ``uniform``        — the paper's i.i.d. cohort draw.
- ``capacity_aware`` — fills PR 5's run-fixed (prototype, step-bucket)
  client capacities cell by cell, fullest cells first, so fewer buckets
  open per round and padded-slot waste drops (docs/bucketing.md).
- ``prioritized``    — O(log N) sum-tree draw keyed on last observed
  staleness: clients whose uploads keep arriving stale (or who were
  recently dropped) are resampled sooner, pulling their freshness up.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Type

import numpy as np

from repro.population.sumtree import SumTree


@dataclasses.dataclass
class SamplerContext:
    """Run-fixed population facts a sampler may condition on."""
    n_clients: int                 # population size N
    n_partitions: int              # engine data partitions (<= N)
    proto: np.ndarray              # [N] prototype group of each client
    bucket: np.ndarray             # [N] step-bucket within its prototype
    bucket_client_caps: List[List[int]]  # per proto: client cap per bucket
    priority_init: float = 1.0


class CohortSampler:
    """Base policy: bind once to a run's context, then draw cohorts."""
    kind = "base"

    def bind(self, ctx: SamplerContext) -> "CohortSampler":
        self.ctx = ctx
        return self

    def sample(self, rng: np.random.Generator, k: int,
               available: Optional[np.ndarray] = None,
               tick: int = 0) -> np.ndarray:
        raise NotImplementedError

    def observe(self, ids, staleness=None) -> None:
        """Feedback after uploads are consumed (no-op by default)."""

    def penalize(self, ids, priority) -> None:
        """Downweight quarantined clients (no-op for unweighted policies)."""

    def load_priorities(self, values) -> None:
        """Restore per-client sampling state from a checkpoint (no-op)."""


_SAMPLERS: Dict[str, Type[CohortSampler]] = {}


def register_sampler(name: str):
    def deco(cls):
        cls.kind = name
        _SAMPLERS[name] = cls
        return cls
    return deco


def get_sampler(name: str) -> Type[CohortSampler]:
    if name not in _SAMPLERS:
        raise KeyError(f"unknown cohort sampler {name!r}; "
                       f"options: {sorted(_SAMPLERS)}")
    return _SAMPLERS[name]


def make_sampler(name: str) -> CohortSampler:
    return get_sampler(name)()


def available_samplers() -> List[str]:
    return sorted(_SAMPLERS)


@register_sampler("uniform")
class UniformSampler(CohortSampler):
    """The historic engine draw: k distinct clients, equal probability.

    With ``available=None`` (everyone reachable) this is *exactly*
    ``rng.choice(N, size=k, replace=False)`` — the call the engine made
    before the scheduler seam existed — so default-config trajectories
    stay bit-identical.
    """

    def sample(self, rng, k, available=None, tick=0):
        if available is None:
            k = min(k, self.ctx.n_clients)
            return rng.choice(self.ctx.n_clients, size=k, replace=False)
        available = np.asarray(available)
        k = min(k, len(available))
        return rng.choice(available, size=k, replace=False)


@register_sampler("capacity_aware")
class CapacityAwareSampler(CohortSampler):
    """Fill run-fixed (prototype, bucket) capacities, fullest cells first.

    ``build_round_batches`` pads every *opened* bucket to its run-fixed
    client capacity x step capacity, so the waste metric is driven by how
    many cells a cohort opens and how full each is.  Greedy: shuffle the
    available pool, group by cell, take whole cells in decreasing
    fill-count order up to each cell's cap; spill past the caps only when
    the cohort can't otherwise be filled.
    """

    def sample(self, rng, k, available=None, tick=0):
        ctx = self.ctx
        ids = (np.arange(ctx.n_clients) if available is None
               else np.asarray(available))
        ids = ids[rng.permutation(len(ids))]
        k = min(k, len(ids))
        by_cell: Dict[tuple, list] = {}
        for i in ids:
            by_cell.setdefault(
                (int(ctx.proto[i]), int(ctx.bucket[i])), []).append(int(i))

        def cap(cell):
            caps = ctx.bucket_client_caps[cell[0]]
            return caps[cell[1]] if cell[1] < len(caps) else k

        cells = sorted(by_cell.items(),
                       key=lambda kv: (-min(len(kv[1]), cap(kv[0])), kv[0]))
        chosen: list = []
        taken: Dict[tuple, int] = {}
        for cell, members in cells:
            if len(chosen) >= k:
                break
            take = min(cap(cell), len(members), k - len(chosen))
            chosen.extend(members[:take])
            taken[cell] = take
        if len(chosen) < k:   # capacities exhausted: spill round-robin
            for cell, members in cells:
                extra = members[taken.get(cell, 0):]
                take = min(len(extra), k - len(chosen))
                chosen.extend(extra[:take])
                if len(chosen) >= k:
                    break
        return np.asarray(chosen, dtype=np.int64)


@register_sampler("prioritized")
class PrioritizedSampler(CohortSampler):
    """Sum-tree draw proportional to per-client priority (1 + staleness).

    ``observe`` bumps a client's priority to ``1 + s`` after its upload
    is consumed at staleness ``s``, so chronically stale clients are
    redrawn sooner.  Unseen clients keep ``priority_init``.  Masking an
    availability subset costs O(U log N) for U unavailable clients
    (priorities are zeroed for the draw and restored after).
    """

    def bind(self, ctx):
        super().bind(ctx)
        self.tree = SumTree.from_values(
            np.full(ctx.n_clients, ctx.priority_init, np.float64))
        return self

    def sample(self, rng, k, available=None, tick=0):
        n = self.ctx.n_clients
        if available is None:
            return self.tree.sample(rng, min(k, n))
        available = np.asarray(available)
        mask = np.zeros(n, np.bool_)
        mask[available] = True
        off = np.flatnonzero(~mask)
        saved = [(int(i), self.tree.get(int(i))) for i in off]
        try:
            for i, _ in saved:
                self.tree.set(i, 0.0)
            return self.tree.sample(rng, min(k, len(available)))
        finally:
            for i, v in saved:
                self.tree.set(i, v)

    def observe(self, ids, staleness=None):
        s = 0.0 if staleness is None else staleness
        self.tree.set_many(np.asarray(ids), 1.0 + np.asarray(s, np.float64))

    def penalize(self, ids, priority):
        """Sink quarantined clients: set their mass to ``priority``."""
        self.tree.set_many(np.asarray(ids),
                           np.asarray(priority, np.float64))

    def load_priorities(self, values):
        self.tree = SumTree.from_values(np.asarray(values, np.float64))

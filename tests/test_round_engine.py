"""Vectorized round engine + server-strategy registry (docs/round_engine.md).

 1. The batched vmap-over-clients local update reproduces the sequential
    reference path per client — exactly, including zero-padded step masks
    for uneven client datasets — for fedavg, fedprox, DP, and quantized
    variants.
 2. All four built-in strategies round-trip through the registry and
    through ``run_federated``; unknown names fail loudly; new strategies
    can be registered.
 3. Both homogeneous and heterogeneous loops route through the shared
    engine (``run_rounds``).
 4. The production fed-round step builder lowers on a small mesh
    (subprocess with forced host devices).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FLConfig, FusionConfig, available_strategies,
                        binarize, get_strategy, mlp, register_strategy,
                        run_federated, run_federated_heterogeneous)
from repro.core.client import (build_batched_batches, build_batches,
                               make_batched_local_update, make_local_update,
                               n_local_steps)
from repro.core.privacy import privatize_update
from repro.core.strategies import ServerStrategy
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.optim.optimizers import adam, sgd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def clients():
    """Three clients with UNEVEN dataset sizes (exercises step padding)."""
    rng = np.random.default_rng(0)
    sizes = [96, 37, 64]
    x = rng.normal(size=(sum(sizes), 2)).astype(np.float32)
    y = rng.integers(0, 3, size=sum(sizes))
    parts, off = [], 0
    for n in sizes:
        parts.append(np.arange(off, off + n))
        off += n
    net = mlp(2, 3, hidden=(16,), norm="bn")
    return net, net.init(jax.random.PRNGKey(0)), x, y, parts


def _sequential(net, g, x, y, parts, opt, *, prox_mu=0.0, quantize=None,
                dp=None, keys=None):
    upd = make_local_update(net, opt, prox_mu=prox_mu, quantize=quantize)
    out = []
    for k, idx in enumerate(parts):
        xb, yb = build_batches(x[idx], y[idx], 32, 3, seed=k)
        p = upd(g, jnp.asarray(xb), jnp.asarray(yb), g)
        if dp is not None:
            p = privatize_update(g, p, clip=dp[0], noise_multiplier=dp[1],
                                 key=keys[k])
        out.append(p)
    return out


def _max_err(seq, stack):
    err = 0.0
    for k, p in enumerate(seq):
        pk = jax.tree.map(lambda t, k=k: t[k], stack)
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(pk)):
            err = max(err, float(jnp.max(jnp.abs(a - b))))
    return err


@pytest.mark.parametrize("variant", ["fedavg", "fedprox", "adam", "quant",
                                     "dp"])
def test_batched_matches_sequential(clients, variant):
    net, g, x, y, parts = clients
    opt = adam(1e-3) if variant == "adam" else sgd(0.05)
    kw = {}
    dp = None
    if variant == "fedprox":
        kw["prox_mu"] = 0.5
    if variant == "quant":
        kw["quantize"] = binarize
    if variant == "dp":
        dp = (1.0, 0.3)
        kw["dp_clip"], kw["dp_noise_multiplier"] = dp
    keys = [jax.random.PRNGKey(100 + k) for k in range(len(parts))]

    seq = _sequential(net, g, x, y, parts, opt,
                      prox_mu=kw.get("prox_mu", 0.0),
                      quantize=kw.get("quantize"), dp=dp, keys=keys)

    bupd = make_batched_local_update(net, opt, **kw)
    xb, yb, mask = build_batched_batches(x, y, parts, 32, 3,
                                         seeds=range(len(parts)))
    # the 37-sample client has fewer steps than the 96-sample one
    assert not mask.all() and mask.any()
    stack = bupd(g, jnp.asarray(xb), jnp.asarray(yb), g, jnp.asarray(mask),
                 jnp.stack(keys))
    # adam's rsqrt chain fuses differently under vmap -> small f32 drift
    assert _max_err(seq, stack) < (5e-4 if variant == "adam" else 1e-5)


def test_batched_fixed_step_cap(clients):
    """Padding beyond the round max (the engine's one-compile cap) is
    still a no-op."""
    net, g, x, y, parts = clients
    bupd = make_batched_local_update(net, sgd(0.05))
    keys = jnp.zeros((len(parts), 2), jnp.uint32)
    outs = []
    for n_steps in (None, 2 * n_local_steps(96, 32, 3)):
        xb, yb, mask = build_batched_batches(x, y, parts, 32, 3,
                                             seeds=range(len(parts)),
                                             n_steps=n_steps)
        outs.append(bupd(g, jnp.asarray(xb), jnp.asarray(yb), g,
                         jnp.asarray(mask), keys))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_make_opt_plumbs_configured_lrs():
    """_make_opt must honour cfg.local_adam_lr (historically it silently
    hard-coded Adam lr=1e-3) and cfg.local_lr for sgd."""
    from repro.core.engine import _make_opt
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 2.0)}

    def step(opt):
        d, _ = opt.update(grads, opt.init(params), params, jnp.asarray(0))
        return d["w"]

    got = step(_make_opt(FLConfig(local_optimizer="adam",
                                  local_adam_lr=0.05)))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(step(adam(0.05))), atol=1e-7)
    assert not np.allclose(np.asarray(got), np.asarray(step(adam(1e-3))))

    got_sgd = step(_make_opt(FLConfig(local_optimizer="sgd",
                                      local_lr=0.2)))
    np.testing.assert_allclose(np.asarray(got_sgd),
                               np.asarray(step(sgd(0.2))), atol=1e-7)


def test_heterogeneous_run_accepts_mesh(problem):
    """Heterogeneous cohorts now accept a client mesh (per-bucket client
    capacities pad up to mesh divisibility instead of being rng-bound):
    no 'mesh ignored' warning, and the sharded trajectory equals the
    unsharded one.  The multi-device case runs in test_bucketing.py."""
    import warnings as _w
    train, val, test, parts, src = problem
    nets = [mlp(2, 3, hidden=(8,), name="p0"),
            mlp(2, 3, hidden=(12,), name="p1")]
    proto = [k % 2 for k in range(len(parts))]
    cfg = FLConfig(strategy="fedavg", rounds=1, client_fraction=0.5,
                   local_epochs=1, local_batch_size=32, local_lr=0.05,
                   seed=0)
    from repro.launch.mesh import make_client_mesh
    base, base_globals = run_federated_heterogeneous(
        nets, proto, train, parts, val, test, cfg)
    with _w.catch_warnings():
        _w.simplefilter("error")  # any engine warning fails the test
        sharded, sharded_globals = run_federated_heterogeneous(
            nets, proto, train, parts, val, test, cfg,
            mesh=make_client_mesh(1))
    for a, b in zip(base, sharded):
        assert a.logs == b.logs
    for ga, gb in zip(base_globals, sharded_globals):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

def test_registry_has_builtins():
    assert {"fedavg", "fedprox", "fedavgm", "feddf"} <= \
        set(available_strategies())
    for name in ("fedavg", "fedprox", "fedavgm", "feddf"):
        s = get_strategy(name)
        assert s.name == name
    assert get_strategy("fedprox").local_prox_mu(FLConfig(prox_mu=0.7)) == 0.7
    assert get_strategy("fedavg").local_prox_mu(FLConfig(prox_mu=0.7)) == 0.0


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("no-such-strategy")


@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "fedavgm",
                                      "feddf"])
def test_strategies_roundtrip_through_engine(problem, strategy):
    train, val, test, parts, src = problem
    cfg = FLConfig(strategy=strategy, rounds=2, client_fraction=0.5,
                   local_epochs=3, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                               eval_every=25, batch_size=32))
    net = mlp(2, 3, hidden=(16, 16))
    res = run_federated(net, train, parts, val, test, cfg,
                        source=src if strategy == "feddf" else None)
    assert len(res.logs) == 2
    assert 0.0 <= res.final_acc <= 1.0
    assert res.final_acc > 1.0 / 3  # above chance after two rounds


def test_custom_strategy_registers_and_runs(problem):
    train, val, test, parts, src = problem

    @register_strategy("midpoint-test")
    class Midpoint(ServerStrategy):
        """Average of fedavg aggregate and the previous global."""

        def aggregate(self, groups, state, ctx):
            from repro.common.pytree import tree_weighted_mean_stacked
            new = []
            for g in groups:
                if g.stack is None:
                    new.append(g.prev_global)
                    continue
                avg = tree_weighted_mean_stacked(g.stack, g.weights)
                new.append(jax.tree.map(lambda a, b: 0.5 * (a + b), avg,
                                        g.prev_global))
            return new, state, [{} for _ in groups]

    try:
        cfg = FLConfig(strategy="midpoint-test", rounds=1,
                       client_fraction=0.5, local_epochs=2,
                       local_batch_size=32, local_lr=0.05, seed=0)
        net = mlp(2, 3, hidden=(16,))
        res = run_federated(net, train, parts, val, test, cfg)
        assert len(res.logs) == 1
    finally:
        from repro.core import strategies as S
        S._REGISTRY.pop("midpoint-test", None)


def test_heterogeneous_routes_through_engine(problem):
    train, val, test, parts, src = problem
    nets = [mlp(2, 3, hidden=(12,), name="proto-s"),
            mlp(2, 3, hidden=(24,), name="proto-m")]
    proto = [k % 2 for k in range(len(parts))]
    cfg = FLConfig(strategy="feddf", rounds=2, client_fraction=0.5,
                   local_epochs=3, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                               eval_every=25, batch_size=32))
    results, globals_ = run_federated_heterogeneous(
        nets, proto, train, parts, val, test, cfg, source=src)
    assert len(results) == len(globals_) == 2
    for r in results:
        assert len(r.logs) == 2
        assert r.logs[-1].ensemble_acc is not None


def test_dropworst_stacked_matches_list(problem):
    train, val, test, parts, src = problem
    from repro.common.pytree import tree_stack
    from repro.core.dropworst import drop_worst, drop_worst_stacked
    net = mlp(2, 3, hidden=(16,))
    plist = [net.init(jax.random.PRNGKey(i)) for i in range(4)]
    plist.append(jax.tree.map(jnp.zeros_like, plist[0]))  # dummy
    w = [1.0, 2.0, 3.0, 4.0, 99.0]
    _, kept_w, kept_i = drop_worst(net, plist, w, val.x, val.y, 3)
    stack = tree_stack(plist)
    kept_s, kept_ws, kept_is = drop_worst_stacked(net, stack, w, val.x,
                                                  val.y, 3)
    assert kept_is == kept_i
    assert kept_ws == kept_w
    assert jax.tree.leaves(kept_s)[0].shape[0] == len(kept_i)


# ---------------------------------------------------------------------------
# production step builder (lowering only; forced host devices in subprocess)
# ---------------------------------------------------------------------------

def test_fed_round_step_lowers_on_mesh():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, sys
sys.path.insert(0, {src!r})
from repro.configs.qwen3_8b import CONFIG
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import make_fed_round_step
cfg = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=128, vocab_size=256,
                          head_dim=16)
mesh = make_debug_mesh(2, 2)
b = make_fed_round_step(cfg, mesh, n_clients=4, local_steps=2,
                        batch_size=2, seq_len=32)
b.lower(mesh)
print("LOWER_OK fed_round_step")
""".format(src=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.stdout.count("LOWER_OK") == 1, r.stdout + r.stderr

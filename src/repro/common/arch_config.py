"""Architecture configuration dataclasses.

Every assigned architecture (and the paper's own small nets) is described by
an :class:`ArchConfig`.  The model stack (`repro.models.transformer`) consumes
this config to build parameters and forward functions; `repro.launch.dryrun`
consumes it to build sharding specs and input specs.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

LayerKind = Literal["attn_global", "attn_local", "mamba", "shared_attn"]
MlpKind = Literal["swiglu", "gelu", "moe", "none"]
Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer of the repeating block pattern."""

    mixer: LayerKind
    mlp: MlpKind


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Full description of one architecture.

    The repeating ``pattern`` is applied ``n_layers`` times by truncating /
    cycling: layer ``i`` uses ``pattern[i % len(pattern)]``.  This preserves
    exact layer counts for non-uniform stacks (gemma3's 5:1 local:global,
    zamba2's mamba+shared-attn interleave).
    """

    name: str
    family: Family
    source: str  # citation from the assignment table

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    pattern: Tuple[BlockSpec, ...]

    head_dim: Optional[int] = None  # default: d_model // n_heads
    qk_norm: bool = False
    causal: bool = True  # False for encoder-only (hubert)
    window: int = 1024  # sliding window size for attn_local layers
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-2

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0  # number of SSD heads; default d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- modality frontend stubs ---
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"
    n_frontend_tokens: int = 0  # patch/frame tokens prepended by the stub

    # --- schedules / training quirks recorded with the arch ---
    lr_schedule: Literal["cosine", "wsd", "constant"] = "cosine"

    # --- execution variants (§Perf levers, not architecture identity) ---
    # naive: materialise [S,T] scores; chunked: flash-pattern online-softmax
    # scan over KV chunks (HLO analogue of kernels/swa_attn.py)
    attn_impl: Literal["naive", "chunked"] = "naive"
    attn_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
            f"{self.name}: n_heads must be a multiple of n_kv_heads"
        )
        assert len(self.pattern) >= 1

    # ------------------------------------------------------------------
    def layer_spec(self, i: int) -> BlockSpec:
        return self.pattern[i % len(self.pattern)]

    @property
    def layer_kinds(self) -> Tuple[BlockSpec, ...]:
        return tuple(self.layer_spec(i) for i in range(self.n_layers))

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def has_attention(self) -> bool:
        return any(b.mixer != "mamba" for b in self.pattern)

    @property
    def has_mamba(self) -> bool:
        return any(b.mixer == "mamba" for b in self.pattern)

    @property
    def has_moe(self) -> bool:
        return any(b.mlp == "moe" for b in self.pattern)

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve a 500k-token context.

        SSM/hybrid archs carry O(1)/windowed state; dense archs qualify only
        if every attention layer is sliding-window or the global layers are a
        small minority (gemma3: decode cost is linear, local layers keep a
        window-sized cache).
        """
        if not self.has_attention:
            return True
        if self.family in ("ssm", "hybrid"):
            return True
        return all(b.mixer in ("attn_local", "mamba") for b in self.pattern) or (
            sum(b.mixer == "attn_global" for b in self.pattern)
            <= len(self.pattern) // 4
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        hd = self.head_dim
        for spec in self.layer_kinds:
            if spec.mixer in ("attn_global", "attn_local", "shared_attn"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o + d  # + norm
                if self.qk_norm:
                    total += 2 * hd
            elif spec.mixer == "mamba":
                di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
                in_proj = d * (2 * di + 2 * ns + nh)
                conv = self.ssm_conv * (di + 2 * ns)
                total += in_proj + conv + nh * 2 + di * d + d  # A,D + out + norm
            if spec.mlp in ("swiglu",):
                total += 3 * d * self.d_ff + d
            elif spec.mlp == "gelu":
                total += 2 * d * self.d_ff + d
            elif spec.mlp == "moe":
                total += self.n_experts * 3 * d * self.d_ff  # experts (swiglu)
                total += d * self.n_experts + d  # router + norm
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.has_moe:
            return self.param_count()
        d = self.d_model
        dense_every = self.param_count()
        moe_layers = sum(b.mlp == "moe" for b in self.layer_kinds)
        all_expert = moe_layers * self.n_experts * 3 * d * self.d_ff
        active_expert = moe_layers * self.top_k * 3 * d * self.d_ff
        return dense_every - all_expert + active_expert


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test variant: same family/pattern, tiny dims."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.pattern) // 3)) if len(cfg.pattern) > 1 else 2,
        d_model=min(cfg.d_model, 128),
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 503),
        head_dim=32,
        window=32,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
        name=cfg.name + "-smoke",
    )
    # keep at least one full pattern repetition
    if len(cfg.pattern) > 1:
        small["n_layers"] = len(cfg.pattern)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

"""Runtime config of the distributed fusion-pod / client-pod topology.

Dependency-free (stdlib only) so it can be embedded in ``FLConfig``
without dragging transports or jax into config construction, and so the
jax-free spec layer (``api/spec.py``) can validate the same ranges.

See ``docs/distributed.md`` for the pod topology and wire format.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.common.options import TRANSPORT_KINDS


@dataclass
class DistConfig:
    """Knobs of the ``distributed`` driver (``repro.dist.driver``).

    transport          "loopback" (in-process pod threads over queue
                       pairs — deterministic, CI-testable) or "tcp"
                       (one OS process per client pod over localhost).
    wire_codec         uplink codec name from the codec registry
                       (``repro.dist.frames``): "fp32" is exact (the
                       degenerate config that matches ``sync`` bitwise),
                       "binarize" / "int8" are the paper's low-bit
                       experiments as bandwidth engineering.  The
                       downlink (globals) is always fp32.
    n_pods             number of client pods; client k lives on pod
                       k % n_pods.
    heartbeat_s        pod heartbeat period; a pod silent for
                       3 * heartbeat_s is presumed dead and its clients
                       are re-routed to a live pod.
    upload_deadline_s  per-upload deadline for attempt 0; attempt a
                       waits upload_deadline_s * faults.backoff ** a
                       (PR 8's retry/backoff bookkeeping).
    verify_crc         False disables CRC rejection (the *undefended*
                       transport used by BENCH_dist to show corruption
                       diverging; never disable outside benchmarks).
    wire_log           optional path of the append-only accepted-upload
                       log; on restart, uploads of the resumed round are
                       replayed from it instead of re-dispatched.
    kill_pod /         chaos-harness hook (loopback only): kill pod
    kill_after_round   ``kill_pod`` after round ``kill_after_round``
                       completes, exercising dead-pod re-routing.
    spec_json          internal — serialized ExperimentSpec handed to
                       tcp pod subprocesses so they rebuild an identical
                       engine; filled by ``api.experiment.to_fl_config``.
    """

    transport: str = "loopback"
    wire_codec: str = "fp32"
    n_pods: int = 2
    heartbeat_s: float = 5.0
    upload_deadline_s: float = 30.0
    verify_crc: bool = True
    wire_log: Optional[str] = None
    kill_pod: Optional[int] = None
    kill_after_round: int = 0
    spec_json: Optional[str] = None

    def validate(self) -> "DistConfig":
        if self.transport not in TRANSPORT_KINDS:
            raise ValueError(
                f"dist.transport must be one of {TRANSPORT_KINDS}, got {self.transport!r}"
            )
        from repro.dist.frames import available_codecs

        if self.wire_codec not in available_codecs():
            raise ValueError(
                f"dist.wire_codec must be one of {available_codecs()}, got {self.wire_codec!r}"
            )
        if self.n_pods < 1:
            raise ValueError(f"dist.n_pods must be >= 1, got {self.n_pods}")
        if self.heartbeat_s <= 0:
            raise ValueError(f"dist.heartbeat_s must be > 0, got {self.heartbeat_s}")
        if self.upload_deadline_s <= 0:
            raise ValueError(
                f"dist.upload_deadline_s must be > 0, got {self.upload_deadline_s}"
            )
        return self

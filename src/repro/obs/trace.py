"""Flight-recorder span tracing: zero-dependency, disarmed-by-default.

``with span("train_clients", round=t):`` wraps every host-level phase of
the round engine plus the driver seams (async dispatch/join,
buffered-async fill/fuse waves, fault-pipeline screening, logit-bank
build/reuse, checkpoint write).  Spans are HOST spans — they never sit
inside a jit trace, so arming them cannot change what XLA compiles and
the disarmed path is a single module-global ``is None`` check returning
a shared no-op context manager (bit-identity with the seed trajectory
is pinned in tests, overhead is gated in ``benchmarks/obs_bench.py``).

Each finished span is one JSONL line::

    {"name": "train_clients", "t0": 3.21, "t1": 4.05, "dur_s": 0.84,
     "depth": 1, "parent": "round", "thread": "MainThread",
     "round": 7, "driver": "buffered_async", "wave": 12}

Timestamps are ``time.perf_counter()`` (monotonic) offsets from the
recorder's arm time, so idle gaps between spans on different threads —
the async overlap the drivers exist to create — are directly
subtractable.  Nesting (``depth``/``parent``) is tracked per-thread;
driver attribution rides in via :func:`set_context`, which pushes
ambient key/values (``driver=...``) that stamp every span opened on any
thread until popped.

Optional jax-profiler passthrough: when armed with ``profile_dir`` the
recorder calls ``jax.profiler.start_trace`` and enters a
``TraceAnnotation(name)`` alongside each span, so the same span
taxonomy shows up on XLA timelines.  jax is imported lazily and every
profiler call is guarded — a build without profiler support degrades to
plain JSONL tracing.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional


class _NullSpan:
    """Shared no-op context manager returned while disarmed."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **attrs):
        pass


_NULL = _NullSpan()

#: module-global recorder slot; ``None`` == disarmed (the common case).
_RECORDER: Optional["FlightRecorder"] = None


class _Span:
    __slots__ = ("rec", "name", "attrs", "t0", "_ann")

    def __init__(self, rec: "FlightRecorder", name: str, attrs: dict):
        self.rec, self.name, self.attrs = rec, name, attrs
        self._ann = None

    def annotate(self, **attrs) -> None:
        """Attach attributes discovered mid-span (fault stats etc.)."""
        self.attrs.update(attrs)

    def __enter__(self):
        self.rec._push(self.name)
        if self.rec._profiling:
            self._ann = self.rec._annotate(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._ann is not None:
            try:
                self._ann.__exit__(*exc)
            except Exception:  # pragma: no cover - profiler teardown quirk
                pass
        self.rec._pop(self.name, self.t0, t1, self.attrs)
        return False


class FlightRecorder:
    """Collects finished spans in memory and (optionally) appends them
    to a JSONL file as they close.  One recorder is armed at a time via
    :func:`arm`; :func:`span` routes through it."""

    def __init__(self, path: Optional[str] = None,
                 profile_dir: Optional[str] = None):
        self.path = path
        self.profile_dir = profile_dir
        self.spans: List[dict] = []
        self._epoch = time.perf_counter()
        self._local = threading.local()
        self._lock = threading.Lock()
        self._context: Dict[str, object] = {}
        self._f = None
        self._profiling = False
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._f = open(path, "a")

    # -- per-thread nesting stack -------------------------------------
    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, name: str) -> None:
        self._stack().append(name)

    def _pop(self, name: str, t0: float, t1: float, attrs: dict) -> None:
        st = self._stack()
        parent = st[-2] if len(st) > 1 else None
        depth = len(st) - 1
        st.pop()
        rec = {"name": name,
               "t0": t0 - self._epoch, "t1": t1 - self._epoch,
               "dur_s": t1 - t0, "depth": depth, "parent": parent,
               "thread": threading.current_thread().name}
        with self._lock:
            rec.update(self._context)
            rec.update(attrs)
            self.spans.append(rec)
            if self._f is not None:
                self._f.write(json.dumps(rec) + "\n")
                self._f.flush()

    # -- ambient attribution ------------------------------------------
    def set_context(self, **attrs) -> None:
        """Stamp ``attrs`` onto every subsequently closed span (any
        thread) until overwritten; ``key=None`` removes a key."""
        with self._lock:
            for k, v in attrs.items():
                if v is None:
                    self._context.pop(k, None)
                else:
                    self._context[k] = v

    # -- jax profiler passthrough -------------------------------------
    def _start_profiler(self) -> None:
        if not self.profile_dir:
            return
        try:
            import jax
            os.makedirs(self.profile_dir, exist_ok=True)
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True
        except Exception:  # pragma: no cover - no profiler support
            self._profiling = False

    def _stop_profiler(self) -> None:
        if not self._profiling:
            return
        try:  # pragma: no cover - exercised only with a profiler backend
            import jax
            jax.profiler.stop_trace()
        except Exception:
            pass
        self._profiling = False

    def _annotate(self, name: str):
        try:  # pragma: no cover - profiler-armed path
            import jax
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
            return ann
        except Exception:
            return None

    # -- summaries -----------------------------------------------------
    def phase_totals(self) -> Dict[str, float]:
        """Total seconds per span name."""
        out: Dict[str, float] = {}
        with self._lock:
            for s in self.spans:
                out[s["name"]] = out.get(s["name"], 0.0) + s["dur_s"]
        return out

    def per_round(self) -> Dict[int, Dict[str, float]]:
        """``{round: {span name: total seconds}}`` for round-stamped
        spans.  Buffered-async training runs in numbered *waves* inside
        a round's ``fill`` span; those wave spans carry ``wave=`` (not
        ``round=``) and aggregate under :meth:`phase_totals` instead."""
        out: Dict[int, Dict[str, float]] = {}
        with self._lock:
            for s in self.spans:
                r = s.get("round")
                if r is None:
                    continue
                row = out.setdefault(int(r), {})
                row[s["name"]] = row.get(s["name"], 0.0) + s["dur_s"]
        return out

    def summary(self) -> dict:
        """The ``RunResult.summary()["obs"]`` payload: phase totals,
        per-round phase breakdown, and the async idle gap (total time a
        driver spent blocked joining a fusion future)."""
        totals = self.phase_totals()
        per_round = self.per_round()
        idle = totals.get("join_fusion", 0.0) + totals.get("join_batches",
                                                           0.0)
        return {"n_spans": len(self.spans),
                "phase_totals_s": totals,
                "idle_gap_s": idle,
                "per_round": {str(k): v
                              for k, v in sorted(per_round.items())}}

    def close(self) -> None:
        self._stop_profiler()
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def arm(path: Optional[str] = None, profile_dir: Optional[str] = None
        ) -> FlightRecorder:
    """Install (and return) a recorder; replaces any armed one."""
    global _RECORDER
    if _RECORDER is not None:
        _RECORDER.close()
    _RECORDER = FlightRecorder(path=path, profile_dir=profile_dir)
    _RECORDER._start_profiler()
    return _RECORDER


def disarm() -> None:
    global _RECORDER
    if _RECORDER is not None:
        _RECORDER.close()
    _RECORDER = None


def recorder() -> Optional[FlightRecorder]:
    return _RECORDER


def span(name: str, **attrs):
    """Context manager timing ``name``; free no-op while disarmed."""
    rec = _RECORDER
    if rec is None:
        return _NULL
    return _Span(rec, name, attrs)


def set_context(**attrs) -> None:
    """Ambient span attribution (no-op while disarmed)."""
    rec = _RECORDER
    if rec is not None:
        rec.set_context(**attrs)


def load_spans(path: str) -> List[dict]:
    """Parse a span JSONL file back into dicts (validation + tests)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out

"""Pluggable server aggregation strategies + registry.

The round engine (``core/engine.py``) trains all active clients into one
stacked pytree per prototype group and hands the stacks to a
:class:`ServerStrategy`; the strategy owns everything server-side —
aggregation rule, server state (momentum), and ensemble distillation.

Built-ins (register more with :func:`register_strategy`):

  fedavg   — weighted parameter average (McMahan et al.)
  fedprox  — fedavg aggregation + proximal local objective (Li et al.)
  fedavgm  — server momentum:  v = beta v + dx;  x = x - v  (Hsu et al.)
  feddf    — fedavg init + server-side ensemble distillation (the paper)

See docs/round_engine.md for the architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.pytree import (Pytree, tree_add,
                                 tree_coordinate_median_stacked,
                                 tree_leading_dim, tree_scale, tree_sub,
                                 tree_take, tree_trimmed_mean_stacked,
                                 tree_weighted_mean_stacked, tree_zeros_like)
from repro.core.client import evaluate
from repro.core.nets import Net


@dataclasses.dataclass
class GroupRound:
    """One prototype group's view of a round: the clients' locally-trained
    params stacked on a leading [K_g] axis, plus their data weights."""

    net: Net
    prev_global: dict
    stack: Optional[Pytree]      # [K_g, ...]; None if no client this round
    weights: np.ndarray          # [K_g] local dataset sizes
    # FedAsync staleness importance (1+s)^-a per client, set by the
    # buffered_async driver; None (every sync/async round, and every
    # buffered round whose uploads are all fresh) keeps the historic
    # aggregation path bit-identical
    importance: Optional[np.ndarray] = None

    def effective_weights(self) -> np.ndarray:
        """Data weights scaled by staleness importance (if any)."""
        if self.importance is None:
            return self.weights
        return (np.asarray(self.weights, np.float64)
                * np.asarray(self.importance, np.float64))


@dataclasses.dataclass
class RoundContext:
    """Server-side context a strategy may consume when aggregating."""

    cfg: Any                     # FLConfig (duck-typed to avoid a cycle)
    round: int
    heterogeneous: bool
    source: Any = None           # DistillSource for distillation strategies
    val_x: Any = None
    val_y: Any = None
    test_x: Any = None
    test_y: Any = None


class ServerStrategy:
    """Interface: consume stacked client pytrees, emit new globals.

    ``aggregate`` returns (new globals per group, new server state,
    per-group info dicts — recognised keys: ``distill_steps``,
    ``pre_distill_acc``).
    """

    name: str = "base"
    needs_source: bool = False

    def local_prox_mu(self, cfg) -> float:
        """Proximal coefficient the engine folds into local training."""
        return 0.0

    def init_state(self, globals_: List[dict]):
        return None

    def aggregate(self, groups: List[GroupRound], state, ctx: RoundContext
                  ) -> Tuple[List[dict], Any, List[dict]]:
        raise NotImplementedError


_REGISTRY: Dict[str, Callable[[], ServerStrategy]] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("mine")`` adds a strategy the
    engine can dispatch to via ``FLConfig(strategy="mine")``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_strategy(name: str) -> ServerStrategy:
    if name not in _REGISTRY:
        raise ValueError(f"unknown strategy {name!r}; registered: "
                         f"{available_strategies()}")
    return _REGISTRY[name]()


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


@register_strategy("fedavg")
class FedAvg(ServerStrategy):
    def aggregate(self, groups, state, ctx):
        new = [g.prev_global if g.stack is None
               else tree_weighted_mean_stacked(g.stack,
                                               g.effective_weights())
               for g in groups]
        return new, state, [{} for _ in groups]


@register_strategy("fedprox")
class FedProx(FedAvg):
    """Identical server rule; the proximal term lives in the local loss."""

    def local_prox_mu(self, cfg) -> float:
        return cfg.prox_mu


@register_strategy("trimmed_mean")
class TrimmedMean(ServerStrategy):
    """Per-coordinate trimmed weighted mean (docs/robustness.md).

    ``cfg.trim_frac`` of the client axis is trimmed from EACH side of
    every coordinate's sorted values before averaging, tolerating up to
    ``floor(trim_frac * K)`` arbitrarily corrupted uploads.  The trim
    count is clamped to ``(K-1)//2`` so at least one value survives;
    ``trim_frac == 0`` is exactly fedavg (bitwise)."""

    def aggregate(self, groups, state, ctx):
        frac = float(getattr(ctx.cfg, "trim_frac", 0.2))
        new = []
        for g in groups:
            if g.stack is None:
                new.append(g.prev_global)
                continue
            k = tree_leading_dim(g.stack)
            trim = min(int(frac * k), (k - 1) // 2)
            new.append(tree_trimmed_mean_stacked(
                g.stack, g.effective_weights(), trim))
        return new, state, [{} for _ in groups]


@register_strategy("coordinate_median")
class CoordinateMedian(ServerStrategy):
    """Per-coordinate weighted median — max per-coordinate robustness
    (tolerates ``(K-1)//2`` corrupted uploads), at the cost of discarding
    averaging's variance reduction (docs/robustness.md)."""

    def aggregate(self, groups, state, ctx):
        new = [g.prev_global if g.stack is None
               else tree_coordinate_median_stacked(g.stack,
                                                   g.effective_weights())
               for g in groups]
        return new, state, [{} for _ in groups]


@register_strategy("fedavgm")
class FedAvgM(ServerStrategy):
    """dv = beta v + dx ; x = x - dv   (dx = x_old - avg), per group."""

    def init_state(self, globals_):
        return [None] * len(globals_)

    def aggregate(self, groups, state, ctx):
        beta = ctx.cfg.server_momentum
        new, bufs = [], list(state)
        for gi, g in enumerate(groups):
            if g.stack is None:
                new.append(g.prev_global)
                continue
            avg = tree_weighted_mean_stacked(g.stack,
                                             g.effective_weights())
            dx = tree_sub(g.prev_global, avg)
            buf = tree_zeros_like(dx) if bufs[gi] is None else bufs[gi]
            buf = tree_add(tree_scale(buf, beta), dx)
            bufs[gi] = buf
            new.append(tree_sub(g.prev_global, buf))
        return new, bufs, [{} for _ in groups]


def _filter_teachers(groups: List[GroupRound], ctx: "RoundContext"
                     ) -> Tuple[List[GroupRound], List[int]]:
    """FedDF teacher-consensus defense: drop non-finite / divergent
    teachers from each group's stack BEFORE the student init and the
    logit-bank rows are computed.  Active only when ``cfg.faults``
    requests it, so historic configs never pay the probe forward."""
    import jax

    from repro.core import feddf as feddf_mod
    faults = getattr(ctx.cfg, "faults", None)
    if faults is None or not faults.teacher_filter_active:
        return groups, [0] * len(groups)
    probe_n = min(64, int(ctx.cfg.fusion.batch_size))
    probe_x = ctx.source.sample(
        jax.random.PRNGKey(ctx.cfg.seed + 7919 * (ctx.round + 1)), probe_n)
    out, dropped = [], []
    for g in groups:
        if g.stack is None:
            out.append(g)
            dropped.append(0)
            continue
        kept, n_drop = feddf_mod.filter_teacher_stack(
            g.net, g.stack, probe_x, sigma=faults.teacher_sigma)
        if n_drop == 0:
            out.append(g)
        elif kept.size == 0:
            # every teacher poisoned: skip this group's fusion entirely
            out.append(dataclasses.replace(g, stack=None))
        else:
            out.append(dataclasses.replace(
                g, stack=tree_take(g.stack, kept),
                weights=np.asarray(g.weights)[kept],
                importance=(None if g.importance is None
                            else np.asarray(g.importance)[kept])))
        dropped.append(n_drop)
    return out, dropped


@register_strategy("feddf")
class FedDF(ServerStrategy):
    """Ensemble distillation fusion (Algorithm 1 / Algorithm 3).

    Homogeneous: one group, teachers = that group's stack.  Heterogeneous:
    every group distills against the ALL-groups teacher ensemble."""

    needs_source = True

    def aggregate(self, groups, state, ctx):
        from repro.core import feddf as feddf_mod
        cfg = ctx.cfg
        assert ctx.source is not None, "FedDF needs a distillation source"
        groups, n_filtered = _filter_teachers(groups, ctx)

        if not ctx.heterogeneous:
            g = groups[0]
            if g.stack is None:
                return [g.prev_global], state, [
                    {"teachers_filtered": n_filtered[0]}
                    if n_filtered[0] else {}]
            w_eff = g.effective_weights()
            avg = tree_weighted_mean_stacked(g.stack, w_eff)
            pre_acc = (evaluate(g.net, avg, ctx.test_x, ctx.test_y)
                       if ctx.test_x is not None else None)
            student = (avg if cfg.feddf_init_from == "average"
                       else g.prev_global)
            fused, info = feddf_mod.feddf_fuse_stacked(
                g.net, g.stack, w_eff, ctx.source, cfg.fusion,
                ctx.val_x, ctx.val_y, seed=cfg.seed + ctx.round,
                student=student, teacher_weights=g.importance)
            return [fused], state, [{
                "distill_steps": info["steps"],
                "pre_distill_acc": pre_acc,
                "teacher_forwards": info.get("teacher_batch_forwards", 0),
                "logit_bank": info.get("logit_bank", False),
                "bank": info.get("bank_decision", ""),
                "bank_dtype": info.get("bank_dtype", ""),
                "bank_nbytes": info.get("bank_nbytes", 0),
                "teachers_filtered": n_filtered[0],
                "diverged": info.get("diverged", False)}]

        protos = [(g.net, g.stack, g.effective_weights()) for g in groups]
        fused, infos = feddf_mod.feddf_fuse_heterogeneous_stacked(
            protos, ctx.source, cfg.fusion, ctx.val_x, ctx.val_y,
            seed=cfg.seed + ctx.round,
            importances=[g.importance for g in groups])
        new, out_infos = [], []
        for g, f, info, nf in zip(groups, fused, infos, n_filtered):
            new.append(g.prev_global if f is None else f)
            out_infos.append(
                ({"teachers_filtered": nf} if nf else {}) if f is None else {
                    "distill_steps": info.get("steps", 0),
                    "teacher_forwards": info.get("teacher_batch_forwards", 0),
                    "logit_bank": info.get("logit_bank", False),
                    "bank": info.get("bank_decision", ""),
                    "bank_dtype": info.get("bank_dtype", ""),
                    "bank_nbytes": info.get("bank_nbytes", 0),
                    "teachers_filtered": nf,
                    "diverged": info.get("diverged", False)})
        return new, state, out_infos

"""FedDF ensemble-distillation model fusion (the paper's core contribution).

AVGLOGITS (paper eq. in §3):

    x_{t,j} = x_{t,j-1} - eta * d/dx KL( sigma(mean_k f(x_k, d)),
                                         sigma(f(x_{t,j-1}, d)) )

Implementation notes:

* Teachers of one prototype are stacked along a leading "clients" axis and
  evaluated with a single ``jax.vmap``-ed forward — one fused program per
  prototype instead of |S_t| sequential forwards.
* The student update runs in jit'd chunks of ``eval_every`` steps
  (lax.scan); between chunks the server validation accuracy implements the
  paper's early stopping (plateau patience 1e3 steps, cap 1e4, Adam lr 1e-3
  with cosine annealing — §4.1 "model fusion procedure").
* The distillation batch is drawn inside the scan from the
  :class:`~repro.data.distill_sources.DistillSource` (unlabeled data /
  generator / noise), keyed by a threaded PRNG.
* ``use_fused_kernel=True`` routes the loss through the Pallas
  ``ensemble_kl`` kernel (TPU hot-path; interpret-mode on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import (tree_leading_dim, tree_stack, tree_unstack,
                                 tree_weighted_mean_stacked)
from repro.core.client import evaluate, softmax_xent
from repro.core.nets import Net
from repro.data.distill_sources import DistillSource
from repro.optim.optimizers import adam, apply_updates
from repro.optim.schedules import cosine


def avg_logits_kl(student_logits: jax.Array, teacher_logits: jax.Array,
                  temperature: float = 1.0) -> jax.Array:
    """KL( softmax(mean_k teacher), softmax(student) ), mean over batch.

    teacher_logits: [K, B, C] (raw, un-averaged); student_logits: [B, C].
    """
    t = jnp.mean(teacher_logits.astype(jnp.float32), axis=0) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    logp_t = jax.nn.log_softmax(t, axis=-1)
    logp_s = jax.nn.log_softmax(s, axis=-1)
    p_t = jnp.exp(logp_t)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1)
    return jnp.mean(kl) * temperature ** 2


@dataclasses.dataclass
class FusionConfig:
    """Paper defaults (§4.1): Adam 1e-3 + cosine, 1e4 step cap, 1e3 patience.

    ``optimizer``/``swag_samples`` reproduce the Table 7 ablation: server
    distillation with SGD, Adam (default), or Adam + SWAG-sampled extra
    teachers (the FedDistill [10] variant; see ``core/swag.py``)."""

    max_steps: int = 10_000
    patience: int = 1_000
    eval_every: int = 100
    batch_size: int = 128
    lr: float = 1e-3
    temperature: float = 1.0
    use_fused_kernel: bool = False
    optimizer: str = "adam"  # adam | sgd   (Table 7)
    swag_samples: int = 0    # extra SWAG teachers (Table 7 "SWAG" row)
    swag_scale: float = 0.5


def make_teacher_logits_fn(net: Net, teacher_stack):
    """Stacked homogeneous teachers -> fn(x) -> [K, B, C]."""

    def fn(x):
        return jax.vmap(lambda p: net.apply(p, x, train=False))(teacher_stack)

    return fn


def distill(
    student_net: Net,
    student_params,
    teacher_logit_fns: Sequence[Callable],
    source: DistillSource,
    fusion: FusionConfig,
    val_x: Optional[np.ndarray] = None,
    val_y: Optional[np.ndarray] = None,
    seed: int = 0,
) -> Tuple[dict, dict]:
    """Run server-side ensemble distillation; returns (params, info).

    ``teacher_logit_fns``: callables x -> [K_g, B, C]; logits are averaged
    over *all* teachers across groups (Algorithm 3 line 14).
    """
    if fusion.optimizer == "sgd":  # Table 7: same cosine schedule, SGD rule
        from repro.optim.optimizers import sgd as _sgd
        opt = _sgd(cosine(fusion.lr, fusion.max_steps))
    else:
        opt = adam(cosine(fusion.lr, fusion.max_steps))
    opt_state = opt.init(student_params)
    mask = student_net.trainable_mask(student_params)

    if fusion.use_fused_kernel:
        from repro.kernels.ops import ensemble_kl_loss
    else:
        ensemble_kl_loss = None

    def chunk(params, opt_state, key, step0):
        def body(carry, _):
            params, opt_state, key, step = carry
            key, k1 = jax.random.split(key)
            x = source.sample(k1, fusion.batch_size)

            t_logits = jnp.concatenate(
                [jnp.asarray(f(x)) for f in teacher_logit_fns], axis=0)

            def loss_fn(p):
                s_logits = student_net.apply(p, x, train=True)
                if ensemble_kl_loss is not None:
                    return ensemble_kl_loss(
                        s_logits, t_logits, temperature=fusion.temperature)
                return avg_logits_kl(s_logits, t_logits, fusion.temperature)

            grads = jax.grad(loss_fn)(params)
            grads = jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                                 grads, mask)
            deltas, opt_state2 = opt.update(grads, opt_state, params, step)
            params = apply_updates(params, deltas)
            return (params, opt_state2, key, step + 1), None

        (params, opt_state, key, step), _ = jax.lax.scan(
            body, (params, opt_state, key, step0), None,
            length=fusion.eval_every)
        return params, opt_state, key, step

    chunk = jax.jit(chunk)

    key = jax.random.PRNGKey(seed)
    best_params, best_acc, best_step = student_params, -1.0, 0
    step = jnp.int32(0)
    history = []
    params = student_params
    while int(step) < fusion.max_steps:
        params, opt_state, key, step = chunk(params, opt_state, key, step)
        if val_x is not None:
            acc = evaluate(student_net, params, val_x, val_y)
            history.append((int(step), acc))
            if acc > best_acc:
                best_acc, best_params, best_step = acc, params, int(step)
            elif int(step) - best_step >= fusion.patience:
                break  # early stopping: validation plateau (paper §4.1)
        else:
            best_params = params
    info = {"steps": int(step), "best_val_acc": best_acc,
            "best_step": best_step, "val_history": history}
    return best_params, info


def feddf_fuse_stacked(
    net: Net,
    teacher_stack,
    weights: Sequence[float],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
    student: Optional[dict] = None,
) -> Tuple[dict, dict]:
    """Algorithm 1 on an ALREADY-STACKED [K, ...] teacher pytree — the round
    engine hands its batched-training output straight in, no per-round
    ``tree_stack`` re-copy.  ``student=None`` initialises from the weighted
    average (line 6)."""
    if student is None:
        student = tree_weighted_mean_stacked(teacher_stack, weights)
    if fusion.swag_samples > 0:  # Table 7: FedDistill/SWAG teacher pool
        from repro.core.swag import swag_teachers
        plist = tree_unstack(teacher_stack, tree_leading_dim(teacher_stack))
        teacher_stack = tree_stack(swag_teachers(
            plist, fusion.swag_samples, scale=fusion.swag_scale, seed=seed))
    tfn = make_teacher_logits_fn(net, teacher_stack)
    return distill(net, student, [tfn], source, fusion, val_x, val_y, seed)


def feddf_fuse_homogeneous(
    net: Net,
    client_params: List[dict],
    client_weights: Sequence[float],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
    init_from: str = "average",
    prev_global: Optional[dict] = None,
) -> Tuple[dict, dict]:
    """List-of-pytrees wrapper over :func:`feddf_fuse_stacked`.
    ``init_from='previous'`` reproduces the Table 5 ablation (initialise
    from last round's fused model instead of the weighted average)."""
    student = (None if init_from == "average" or prev_global is None
               else prev_global)
    return feddf_fuse_stacked(net, tree_stack(client_params), client_weights,
                              source, fusion, val_x, val_y, seed,
                              student=student)


def feddf_fuse_heterogeneous_stacked(
    prototypes: List[Tuple[Net, Optional[dict], Sequence[float]]],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
) -> Tuple[List[Optional[dict]], List[dict]]:
    """Algorithm 3 on stacked per-group teacher pytrees: every group's
    student distills against the ALL-groups teacher ensemble.

    ``prototypes``: per group (net, stacked params [K_g, ...] or None,
    data weights).  Returns (fused params per group, info per group).
    """
    teacher_fns = [make_teacher_logits_fn(net, stack)
                   for net, stack, _ in prototypes if stack is not None]

    fused, infos = [], []
    for gi, (net, stack, weights) in enumerate(prototypes):
        if stack is None:
            fused.append(None)
            infos.append({"skipped": True})
            continue
        student = tree_weighted_mean_stacked(stack, weights)  # Alg.3 line 11
        p, info = distill(net, student, teacher_fns, source, fusion,
                          val_x, val_y, seed + gi)
        fused.append(p)
        infos.append(info)
    return fused, infos


def feddf_fuse_heterogeneous(
    prototypes: List[Tuple[Net, List[dict], Sequence[float]]],
    source: DistillSource,
    fusion: FusionConfig,
    val_x=None,
    val_y=None,
    seed: int = 0,
) -> Tuple[List[Optional[dict]], List[dict]]:
    """List-of-pytrees wrapper over
    :func:`feddf_fuse_heterogeneous_stacked`."""
    stacked = [(net, tree_stack(plist) if plist else None, weights)
               for net, plist, weights in prototypes]
    return feddf_fuse_heterogeneous_stacked(stacked, source, fusion,
                                            val_x, val_y, seed)

"""Round-engine microbenchmark (ISSUE 1 acceptance): per-round client
training wall-clock, sequential python-loop (`make_local_update` per
client) vs the vectorized engine path (`make_batched_local_update`, one
jitted vmap-over-clients scan).

Equal-size partitions, so neither path pays padding; both are warmed up
before timing so the numbers compare steady-state rounds, not compiles.
Emits ``round_engine_K{K},us_per_round,speedup`` per client count.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scale
from repro.core import mlp
from repro.core.client import (build_batched_batches, build_batches,
                               make_batched_local_update, make_local_update)
from repro.optim.optimizers import sgd

SAMPLES_PER_CLIENT = 256
BATCH = 32
EPOCHS = 8
LR = 0.05


def _problem(k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = k * SAMPLES_PER_CLIENT
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.integers(0, 3, size=n)
    parts = [np.arange(i * SAMPLES_PER_CLIENT, (i + 1) * SAMPLES_PER_CLIENT)
             for i in range(k)]
    return x, y, parts


def _time_rounds(fn, rounds: int) -> float:
    fn()  # warm-up: compile
    t0 = time.time()
    for _ in range(rounds):
        fn()
    return (time.time() - t0) / rounds


def run() -> None:
    rounds = scale(3, 10)
    net = mlp(2, 3, hidden=(32, 32))
    g = net.init(jax.random.PRNGKey(0))

    for k in (4, 8, 16):
        x, y, parts = _problem(k)

        upd = make_local_update(net, sgd(LR))
        per = [build_batches(x[idx], y[idx], BATCH, EPOCHS, seed=i)
               for i, idx in enumerate(parts)]
        per = [(jnp.asarray(xb), jnp.asarray(yb)) for xb, yb in per]

        def seq_round():
            outs = [upd(g, xb, yb, g) for xb, yb in per]
            jax.block_until_ready(outs[-1])

        bupd = make_batched_local_update(net, sgd(LR))
        xb, yb, mask = build_batched_batches(x, y, parts, BATCH, EPOCHS,
                                             seeds=list(range(k)))
        xb, yb, mask = jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(mask)
        keys = jnp.zeros((k, 2), jnp.uint32)

        def bat_round():
            jax.block_until_ready(bupd(g, xb, yb, g, mask, keys))

        t_seq = _time_rounds(seq_round, rounds)
        t_bat = _time_rounds(bat_round, rounds)
        speedup = t_seq / t_bat
        emit(f"round_engine_K{k}", t_bat,
             f"speedup_x{speedup:.2f}",
             record={"n_clients": k, "seq_s": t_seq, "batched_s": t_bat,
                     "speedup": speedup, "steps_per_client":
                     EPOCHS * (SAMPLES_PER_CLIENT // BATCH)})


if __name__ == "__main__":
    run()

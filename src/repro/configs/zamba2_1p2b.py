"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + SHARED-parameter attention blocks
(one attention weight set reused across the depth). [arXiv:2411.15242]

Layout: 38 layers = 5 x (6 mamba2 + 1 shared-attn) + 3 mamba2 (remainder).
"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    pattern=tuple([BlockSpec("mamba", "none")] * 6
                  + [BlockSpec("shared_attn", "swiglu")]),
)

"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter / activation dimension with a *logical*
name; the rules table maps logical names onto physical mesh axes.  Changing a
distribution strategy = changing one rules table, not the model.

Physical mesh axes:
  single-pod: ("data", "model")            shape (16, 16)
  multi-pod : ("pod", "data", "model")     shape (2, 16, 16)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]


def donation_supported() -> bool:
    """Buffer donation is implemented on gpu/tpu; on cpu it is a no-op
    that only emits a warning, so donation call sites skip it there."""
    return jax.default_backend() in ("gpu", "tpu")


def shard_map(f, mesh, in_specs, out_specs, check: bool = True):
    """``jax.shard_map`` across JAX versions.

    Newer releases expose it at the top level with ``check_vma``; 0.4.x only
    has ``jax.experimental.shard_map`` with ``check_rep``.  ``check`` maps to
    whichever the installed version takes.
    """
    smap = getattr(jax, "shard_map", None)
    if smap is not None:
        return smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check)
    from jax.experimental.shard_map import shard_map as smap_old
    return smap_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=check)

# Logical axis vocabulary -------------------------------------------------
#   batch      global batch dimension
#   seq        sequence dimension of activations
#   cache_seq  KV-cache sequence dimension (sequence parallelism for decode)
#   vocab      vocabulary dimension (embedding + lm head + logits)
#   embed      d_model dimension (FSDP shard target)
#   heads      query-head dimension
#   kv_heads   kv-head dimension
#   qkv        per-head feature dim (never sharded)
#   mlp        feed-forward hidden dimension
#   experts    MoE expert dimension (expert parallelism)
#   inner      mamba inner-channel dimension
#   state      SSM state dimension (never sharded)
#   layers     stacked-layer dimension of scanned params
#   clients    stacked-teacher dimension in FedDF fusion


def make_rules(
    *,
    multi_pod: bool = False,
    fsdp: bool = True,
    shard_cache_seq: bool = False,
    shard_clients: bool = False,
    layout: str = "tp",
    extra: Optional[Rules] = None,
) -> Rules:
    """``shard_clients=True`` puts the stacked-client leading axis of the
    federated round engine on the data axes (clients train data-parallel;
    see ``core/client.make_batched_local_update``).  Layouts:

    tp        — batch over (pod,)data; heads/mlp/experts tensor-parallel
                over "model"; d_model FSDP over data.  (baseline)
    dp_heavy  — ZeRO-style: batch over BOTH (data, model) axes; weights
                sharded on d_model over "data" and vocab over "model";
                no tensor parallelism.  Collectives become per-layer
                weight all-gathers (O(params·2B)) instead of per-layer
                activation all-reduces (O(B_local·S·d·fp32·L)) — the
                §Perf beyond-paper variant for mid-size dense models.
    """
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    if layout in ("dp_heavy", "dp_heavy_z3"):
        # z3: ZeRO-3-width param/optimizer sharding — the embed (d_model)
        # dim of every weight is sharded over BOTH axes, shrinking the
        # resident param+Adam footprint mesh-size-fold; gather volume per
        # layer is unchanged (each device still receives the full layer).
        dp_all = dp + ("model",)
        rules: Rules = {
            "batch": dp_all,
            "seq": (),
            "cache_seq": (),
            "vocab": ("model",),
            "embed": (dp_all if layout == "dp_heavy_z3" else ("data",))
                     if fsdp else (),
            "heads": (),
            "kv_heads": (),
            "qkv": (),
            "mlp": (),
            "experts": ("model",),  # expert weights still sharded
            "inner": (),
            "state": (),
            "conv": (),
            "layers": (),
            "clients": dp if shard_clients else (),
        }
    else:
        rules = {
            "batch": dp,
            "seq": (),
            "cache_seq": ("data",) if shard_cache_seq else (),
            "vocab": ("model",),
            "embed": dp if fsdp else (),
            "heads": ("model",),
            "kv_heads": ("model",),
            "qkv": (),
            "mlp": ("model",),
            "experts": ("model",),
            "inner": ("model",),
            "state": (),
            "conv": (),
            "layers": (),
            "clients": dp if shard_clients else (),
        }
    if extra:
        rules.update(extra)
    return rules


def logical_to_pspec(logical: Sequence[Optional[str]], rules: Rules) -> P:
    """Map a tuple of logical names (one per tensor dim) to a PartitionSpec.

    A mesh axis may appear at most once in a PartitionSpec; on conflicts the
    *first* dimension wins and later dims are replicated.
    """
    used: set = set()
    spec = []
    for name in logical:
        if name is None:
            spec.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a not in used)
        used.update(axes)
        if len(axes) == 0:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(axes)
    return P(*spec)


def tree_pspecs(logical_tree: Any, rules: Rules) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda names: logical_to_pspec(names, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(logical_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree_pspecs(logical_tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def fit_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim size.

    E.g. kv_heads=4 cannot shard over a 16-way "model" axis; rather than
    fail at lowering we replicate that dim (XLA would otherwise require
    padding).  Tuple entries are trimmed from the right."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes = axes[:-1]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def fit_pspecs(pspec_tree: Any, struct_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda spec, leaf: fit_pspec(spec, leaf.shape, mesh),
        pspec_tree, struct_tree, is_leaf=lambda x: isinstance(x, P))


def kv_cache_rules(rules: Rules, *, batch: int, data_size: int) -> Rules:
    """Decode-cache sharding.

    The cache SEQUENCE dim is sharded over "model" (sequence-parallel
    attention reads; XLA combines the sharded softmax with small
    all-reduces).  Sharding kv_heads instead fails for GQA archs whose
    kv_heads < 16 (fit_pspec would replicate and a 32k cache stops fitting:
    qwen3-8b decode_32k cache = 619 GB global).  With batch < data-axis
    size (long_500k: B=1) the batch dim is released and the sequence dim
    takes BOTH axes."""
    out = dict(rules)
    if batch < data_size:
        out["batch"] = ()
        out["cache_seq"] = ("data", "model")
    else:
        out["cache_seq"] = ("model",)
        out["kv_heads"] = ()  # avoid conflicting with cache_seq
    return out

"""Async-pipelined round driver: overlap round t's server-side fusion
with round t+1's client training.

FedDF's per-round cost is dominated by two phases with no mutual data
dependency once the teacher snapshot is taken: the batched client
training of the NEXT round and the ensemble-distillation fusion of the
CURRENT one.  This driver runs fusion on a worker thread while the main
thread builds and dispatches the next round's client training — jax
dispatch is asynchronous and never calls ``block_until_ready``, and the
engine's donated batch buffers are rebuilt per round, so the two
computations interleave on the backend.

Staleness semantics (``staleness`` knob, bounded S >= 0):

  staleness=0  sync semantics, bit-identical: round t+1's training waits
               for round t's fused globals.  Only the HOST-side batch
               building (a pure function of (round, cohort)) is
               prefetched ``prefetch`` rounds ahead on the worker.
  staleness=S  up to S rounds of client training run concurrently with
               the oldest round's fusion: round t's clients initialise
               from the newest fusion that has COMPLETED, at most S
               rounds staler than sync.  S=1 is the historic one-round
               overlap (trajectory drift gated <= 0.5pt in CI); each
               round's aggregation still consumes every upload.

Checkpoint/resume: ``round_end_hook`` fires in round order.  Under
staleness>=1 the hook's ``state`` is wrapped with the training bases of
ALL still-in-flight rounds (for S=1 exactly the historic single stale
base — the checkpoint format is unchanged), so ``Experiment.resume``
re-trains the interrupted rounds from the SAME bases an uninterrupted
pipeline used — trajectory equality is pinned in
``tests/test_drivers.py``.  In-flight work past the last completed hook
is discarded on kill and recomputed on resume.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, Optional, Tuple

from repro.core.engine import _UNSET, RoundEngine
from repro.drivers.base import Driver, register_driver, wrap_state
from repro.obs.trace import span


@register_driver("async_pipelined")
class AsyncPipelinedDriver(Driver):
    def run(self, engine: RoundEngine, *, log_fn=None, init_globals=None,
            init_state=_UNSET, start_round=1, init_logs=None,
            round_end_hook=None):
        globals_, state, logs, rng = self._setup(
            engine, init_globals, init_state, init_logs, start_round)
        # bases the interrupted in-flight rounds trained from, oldest
        # first; rounds start_round, start_round+1, ... consume them in
        # order, then fall back to the newest completed fusion
        pending_bases: Deque = deque()
        if self.staleness > 0:
            if self._resume_base_ring:
                pending_bases.extend(self._resume_base_ring)
            elif self._resume_prev_base is not None:
                pending_bases.append(self._resume_prev_base)
        rounds = engine.cfg.rounds
        rounds_to_target = None
        stopped = False

        # fusion gets a DEDICATED worker: sharing a pool with the batch
        # prefetcher could queue an aggregate behind host batch building
        # — exactly the phase the pipeline exists to keep busy
        agg_ex = ThreadPoolExecutor(max_workers=1)
        batch_ex = ThreadPoolExecutor(max_workers=1)
        batch_futs: Dict[int, object] = {}
        next_draw = start_round

        def prefetch_to(limit: int) -> None:
            # cohort draws stay on the driver thread IN ROUND ORDER (the
            # rng sequence is the resume contract); only the pure host
            # batch building goes to the worker
            nonlocal next_draw
            while next_draw <= min(limit, rounds):
                t_, next_draw = next_draw, next_draw + 1
                active = engine.sample_cohort(rng)
                batch_futs[t_] = batch_ex.submit(engine.build_round_batches,
                                                 t_, active)

        def aggregate_task(t, groups, st):
            out = engine.aggregate(t, groups, st)
            return (groups,) + out

        # submitted-but-unjoined rounds, oldest first: (future, round,
        # training base).  len(ring) never exceeds max(self.staleness, 1).
        ring: Deque[Tuple[object, int, object]] = deque()
        try:
            for t in range(start_round, rounds + 1):
                prefetch_to(t + self.prefetch)
                # idle gap: time blocked on the prefetch worker
                with span("join_batches", round=t):
                    batches = batch_futs.pop(t).result()

                if self.staleness == 0 and ring:
                    # sync semantics: fused globals gate the next training
                    fut, r, _ = ring.popleft()
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, fut, r, logs, log_fn, round_end_hook,
                        ring_bases=None)
                    if rounds_to_target is not None or stop:
                        stopped = True
                        break

                base = pending_bases.popleft() if pending_bases else globals_
                groups = engine.train_clients(t, base, batches)

                if self.staleness > 0 and len(ring) == self.staleness:
                    # ring full: join the oldest fusion AFTER dispatching
                    # round t's training.  Its checkpoint must carry the
                    # bases of every round still in flight (plus t's).
                    fut, r, _ = ring.popleft()
                    bases = [b for _, _, b in ring] + [base]
                    globals_, state, rounds_to_target, stop = self._finish(
                        engine, fut, r, logs, log_fn, round_end_hook,
                        ring_bases=bases)
                    if rounds_to_target is not None or stop:
                        stopped = True  # in-flight trained rounds discarded
                        break

                ring.append((agg_ex.submit(aggregate_task, t, groups, state),
                             t, base))

            while ring and not stopped:
                fut, r, _ = ring.popleft()
                bases = [b for _, _, b in ring] or None
                globals_, state, rounds_to_target, stop = self._finish(
                    engine, fut, r, logs, log_fn, round_end_hook,
                    ring_bases=bases)
                if rounds_to_target is not None or stop:
                    break  # later in-flight rounds discarded, as in sync
        finally:
            batch_ex.shutdown(wait=True, cancel_futures=True)
            agg_ex.shutdown(wait=True, cancel_futures=True)

        return self._results(engine, logs, globals_, rounds_to_target)

    def _finish(self, engine, agg_fut, t, logs, log_fn, round_end_hook,
                ring_bases):
        """Join round t's in-flight aggregation, then evaluate / log /
        checkpoint it.  ``ring_bases`` are the training bases of the
        rounds still in flight (oldest first) — wrapped into the
        checkpoint state so a resumed pipeline re-trains them from the
        same bases."""
        # idle gap: the driver thread blocked on the fusion worker — the
        # overlap the pipeline exists to create is 1 - this/total
        with span("join_fusion", round=t):
            groups, globals_, state, infos, dropped, ens_acc = \
                agg_fut.result()
        round_logs = engine.evaluate_round(t, globals_, groups, infos,
                                           dropped, ens_acc)
        reached, stop_requested = self._emit_round(engine, t, round_logs,
                                                   logs, log_fn)
        rounds_to_target = t if reached else None
        if round_end_hook is not None:
            hook_state = state
            if self.staleness > 0:
                bases = ring_bases if ring_bases else [globals_]
                hook_state = wrap_state(
                    state, bases[0],
                    base_ring=bases if len(bases) > 1 else None)
            round_end_hook(t, globals_, hook_state, logs, rounds_to_target)
        return globals_, state, rounds_to_target, stop_requested

from repro.common.arch_config import ArchConfig, BlockSpec, reduced
from repro.common import pytree, sharding

"""Serving driver: batched autoregressive decoding with the fused model.

Demonstrates the inference path of the framework on CPU with a reduced
config: prefill a batch of prompts, then serve_step tokens one at a time
against the KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b-smoke \\
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="feddf-paper")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if cfg.frontend == "audio_frames":
        raise SystemExit("encoder-only architecture: no decode step "
                         "(see DESIGN.md)")
    key = jax.random.PRNGKey(args.seed)
    params = T.init(cfg, key)
    b, s = args.batch, args.prompt_len
    max_seq = s + args.gen
    prompts = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        max_seq += cfg.n_frontend_tokens

    t0 = time.time()
    logits, caches = T.prefill(params, cfg, batch, max_seq=max_seq)
    print(f"prefill [{b}x{s}] in {time.time()-t0:.2f}s")

    decode = jax.jit(
        lambda p, tok, c, n: T.decode_step(p, cfg, {"tokens": tok}, c, n))
    cur = jnp.int32(s + (cfg.n_frontend_tokens
                         if cfg.frontend == "vision_patches" else 0))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        lg, caches = decode(params, tok, caches, cur)
        if args.temperature != 1.0:
            lg = lg / args.temperature
        key, k2 = jax.random.split(key)
        tok = jax.random.categorical(k2, lg[:, -1])[:, None]
        generated.append(tok)
        cur = cur + 1
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"generated [{b}x{args.gen}] in {dt:.2f}s "
          f"({b*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    for row in out[: min(b, 4)]:
        print("  tokens:", row.tolist())


if __name__ == "__main__":
    main()

"""Table 3: unnormalised nets (VGG-analogue) destabilise under non-iid
local training; drop-worst rescues aggregation; FedDF tops FedAvg/FedProx.

We provoke instability with a deeper norm-free MLP and a hot learning rate,
then compare aggregation with and without drop-worst."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(6, 15)
    t0 = time.time()
    train, val, test, parts, src = default_problem(seed=seed, alpha=0.3,
                                                   n=4000)
    net = mlp(2, 3, hidden=(64, 64, 64, 64), norm="none")
    results = {}
    for name, (strat, dw, source) in {
        "fedavg_no_dropworst": ("fedavg", False, None),
        "fedavg": ("fedavg", True, None),
        "fedprox": ("fedprox", True, None),
        "feddf": ("feddf", True, src),
    }.items():
        accs = []
        for s in range(scale(2, 3)):
            cfg = fl_cfg(strat, rounds, seed=seed + s, drop_worst=dw,
                         local_lr=0.2)  # hot lr -> occasional divergence
            res = run_federated(net, train, parts, val, test, cfg,
                                source=source)
            accs.append(res.best_acc)
        results[name] = {"mean": float(np.mean(accs)),
                         "std": float(np.std(accs)), "accs": accs}
    dt = time.time() - t0
    claims = {
        "dropworst_stabilises":
            results["fedavg"]["mean"] >=
            results["fedavg_no_dropworst"]["mean"] - 0.01,
        "feddf_top":
            results["feddf"]["mean"] >= max(
                results["fedavg"]["mean"], results["fedprox"]["mean"]) - 0.02,
    }
    emit("table3_dropworst", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

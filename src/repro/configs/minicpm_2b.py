"""minicpm-2b [dense]: 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753 —
llama-like arch, WSD (warmup-stable-decay) schedule. [arXiv:2404.06395]"""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    tie_embeddings=True,
    lr_schedule="wsd",
    pattern=(BlockSpec("attn_global", "swiglu"),),
)

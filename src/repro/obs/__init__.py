"""Flight-recorder observability layer (docs/observability.md).

Three pieces, all zero-dependency and disarmed-by-default:

* :mod:`repro.obs.trace` — phase-span tracing with JSONL output,
  per-thread nesting, driver/wave attribution, and optional
  jax-profiler passthrough.
* :mod:`repro.obs.metrics` — the unified metrics registry that absorbed
  the scattered ``TraceCounter`` singletons, plus per-round streaming
  sinks driven off the ``RoundEvent`` observer chain.
* :mod:`repro.obs.history` — the versioned ``BENCH_history.jsonl``
  schema every benchmark appends to and CI gates on.
"""
from repro.obs.history import (SCHEMA_VERSION, append, latest, load,
                               machine_fingerprint, make_record,
                               validate_record)
from repro.obs.metrics import (REGISTRY, Counter, CSVSink, Gauge, Histogram,
                               JSONLSink, MemorySink, MetricsObserver,
                               MetricsRegistry, device_memory_watermark)
from repro.obs.trace import (FlightRecorder, arm, disarm, load_spans,
                             recorder, set_context, span)

__all__ = [
    "SCHEMA_VERSION", "append", "latest", "load", "machine_fingerprint",
    "make_record", "validate_record",
    "REGISTRY", "Counter", "CSVSink", "Gauge", "Histogram", "JSONLSink",
    "MemorySink", "MetricsObserver", "MetricsRegistry",
    "device_memory_watermark",
    "FlightRecorder", "arm", "disarm", "load_spans", "recorder",
    "set_context", "span",
]

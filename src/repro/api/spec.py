"""Declarative, JSON-round-trippable experiment specification.

An :class:`ExperimentSpec` is the single source of truth for a federated
run: what data (``TaskSpec``), how it is split across clients
(``PartitionSpec``), which model prototypes the clients run
(``CohortSpec`` — homogeneous FL is simply a one-prototype cohort), how
the server fuses uploads (``StrategySpec``), what unlabeled data feeds
the distillation (``SourceSpec``), the privacy/compression treatment of
uploads (``PrivacySpec``) and the device layout (``ShardingSpec``).

Every component is referenced *by registry name* (``api/registries.py``),
so a run is fully describable — and reproducible — as data:

    spec = ExperimentSpec.from_json(spec.to_json())   # lossless
    Experiment(spec).run()

Design rules:

* every field is JSON-native (lists not tuples, names not callables) so
  ``from_json(to_json(spec)) == spec`` holds exactly;
* ``from_dict`` rejects unknown keys — a typo'd config fails loudly
  instead of silently running the defaults;
* ``validate()`` resolves every registry name eagerly, before any data
  or device work starts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Union


def _check_keys(cls, d: dict) -> None:
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(
            f"{cls.__name__}: unknown field(s) {sorted(unknown)}; "
            f"known fields: {sorted(known)}")


@dataclasses.dataclass
class TaskSpec:
    """Which dataset family to build (resolved via the task registry)."""

    name: str = "blobs"
    n_samples: int = 6000
    seed: Optional[int] = None       # None -> inherit ExperimentSpec.seed
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TaskSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class PartitionSpec:
    """Non-iid client split (Dirichlet, paper §4.1)."""

    n_clients: int = 20
    alpha: float = 1.0
    seed: Optional[int] = None       # None -> inherit ExperimentSpec.seed
    min_per_client: int = 1

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PartitionSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class ModelSpec:
    """One client-model prototype (resolved via the model registry)."""

    name: str = "mlp"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ModelSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class CohortSpec:
    """The client fleet: a list of model prototypes plus the client ->
    prototype assignment.  One prototype == homogeneous FL (Algorithm 1);
    several == heterogeneous fusion (Algorithm 3).

    ``assignment`` is either ``"round_robin"`` (client k runs prototype
    ``k % P``) or an explicit list of prototype indices, one per client.
    """

    prototypes: List[ModelSpec] = dataclasses.field(
        default_factory=lambda: [ModelSpec()])
    assignment: Union[str, List[int]] = "round_robin"

    def to_dict(self) -> dict:
        return {"prototypes": [m.to_dict() for m in self.prototypes],
                "assignment": self.assignment}

    @classmethod
    def from_dict(cls, d: dict) -> "CohortSpec":
        _check_keys(cls, d)
        d = dict(d)
        if "prototypes" in d:
            d["prototypes"] = [ModelSpec.from_dict(m)
                               for m in d["prototypes"]]
        return cls(**d)

    def client_prototypes(self, n_clients: int) -> List[int]:
        """Materialise the assignment as a per-client prototype index."""
        if self.assignment == "round_robin":
            return [k % len(self.prototypes) for k in range(n_clients)]
        return [int(p) for p in self.assignment]


@dataclasses.dataclass
class SourceSpec:
    """Distillation-data source (resolved via the source registry)."""

    name: str = "unlabeled"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SourceSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class FusionSpec:
    """Server-side distillation hyperparameters (paper §4.1 defaults).

    ``logit_bank`` controls the teacher-logit-bank fast path
    (``core/logit_bank.py``; see docs/distill_fast_path.md): ``auto``
    precomputes averaged teacher logits whenever the source exposes an
    indexable pool, ``on`` insists (warns + falls back otherwise),
    ``off`` keeps per-step teacher forwards.  ``bank_dtype`` trades bank
    memory against trajectory fidelity: ``float32`` (N x C x 4 bytes) is
    bitwise-identical to on-the-fly, ``bfloat16`` halves the rows,
    ``int8`` / ``fp8_e4m3`` store quantized rows plus one fp32 scale per
    row (N x C x 1 + N x 4 — docs/distill_fast_path.md).
    ``use_fused_kernel='auto'`` picks the Pallas kernel on TPU and the
    jnp reference path elsewhere.

    ``batch_sizes`` (heterogeneous cohorts only) gives each prototype
    group its own distillation batch size — one entry per cohort
    prototype; ``distill_bucket`` / ``distill_max_buckets`` bucket those
    sizes into run-fixed padded capacities (docs/bucketing.md)."""

    max_steps: int = 10_000
    patience: int = 1_000
    eval_every: int = 100
    batch_size: int = 128
    lr: float = 1e-3
    temperature: float = 1.0
    use_fused_kernel: Union[bool, str] = "auto"  # True | False | "auto"
    optimizer: str = "adam"          # adam | sgd (Table 7)
    swag_samples: int = 0
    swag_scale: float = 0.5
    logit_bank: str = "auto"         # auto | on | off
    bank_dtype: str = "float32"      # float32 | bfloat16 | int8 | fp8_e4m3
    batch_sizes: Optional[List[int]] = None  # per-prototype distill batch
    distill_bucket: str = "none"     # none | pow2 | quantile
    distill_max_buckets: int = 4

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FusionSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class StrategySpec:
    """Server aggregation rule (resolved via the strategy registry in
    ``core/strategies.py``) plus its hyperparameters."""

    name: str = "feddf"
    prox_mu: float = 0.01            # fedprox local proximal coefficient
    server_momentum: float = 0.3     # fedavgm beta
    drop_worst: bool = False
    trim_frac: float = 0.2           # trimmed_mean per-end trim fraction
    feddf_init_from: str = "average"  # average | previous (Table 5)
    fusion: FusionSpec = dataclasses.field(default_factory=FusionSpec)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fusion"] = self.fusion.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "StrategySpec":
        _check_keys(cls, d)
        d = dict(d)
        if "fusion" in d:
            d["fusion"] = FusionSpec.from_dict(d["fusion"])
        return cls(**d)


@dataclasses.dataclass
class PrivacySpec:
    """Client-upload treatment: DP clip+noise (``core/privacy.py``) and
    low-bit quantization by registry name (``core/quantize.py``)."""

    clip: Optional[float] = None         # None -> DP off
    noise_multiplier: float = 0.0
    quantizer: Optional[str] = None      # e.g. "binarize"; None -> fp32

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PrivacySpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class ShardingSpec:
    """Device layout for the round engine's stacked client axis."""

    shard_clients: bool = False
    client_axis: str = "data"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ShardingSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class BucketSpec:
    """Step-count bucketing of the round engine's client axis
    (docs/bucketing.md).

    ``kind``: ``none`` (pad every client of a prototype group to the
    group-wide maximum scan length — the historic path), ``pow2``
    (power-of-two scan capacities) or ``quantile`` (capacities at
    step-count quantiles).  ``max_buckets`` bounds the per-run compile
    count (at most buckets x prototypes client-update programs).
    Bucketing never changes a trajectory — it only regroups the vmap
    axis — but on skewed Dirichlet splits it removes most of the masked
    no-op padding steps."""

    kind: str = "none"               # none | pow2 | quantile
    max_buckets: int = 4

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "BucketSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class TrafficSpec:
    """Virtual-time client traffic model (docs/population.md).

    ``arrival``: ``always`` (every client reachable every wave — the
    historic implicit model) or ``bernoulli`` (each client online with
    probability ``rate`` per wave).  ``latency`` is the mean virtual
    upload delay; ``jitter`` is the sigma of a lognormal multiplier
    applied both per-client (static speed) and per-upload.  A
    ``straggler_frac`` fraction of clients upload ``straggler_mult``
    times slower, persistently.  ``dropout`` is the per-upload loss
    probability.  All draws are counter-keyed on (seed, wave), so a
    trace is a pure function of the spec — deterministic and
    resumable."""

    arrival: str = "always"          # always | bernoulli
    rate: float = 1.0                # bernoulli online probability
    latency: float = 0.0             # mean virtual upload latency
    jitter: float = 0.0              # lognormal sigma (speed + per-upload)
    straggler_frac: float = 0.0      # fraction of persistently slow clients
    straggler_mult: float = 8.0      # their latency multiplier
    dropout: float = 0.0             # per-upload loss probability

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class PopulationSpec:
    """The registered client population + cohort scheduling
    (docs/population.md; ``repro.population``).

    ``size=None`` keeps the population equal to the partition roster
    (the historic fixed-roster semantics, bit-identical); a larger size
    maps clients onto data partitions round-robin.  ``sampler`` is a
    cohort-sampler registry name (``uniform`` | ``capacity_aware`` |
    ``prioritized``).  ``buffer_size`` (buffered_async driver) is the
    upload count M that triggers an aggregation — None means the active
    cohort size K, the degenerate sync-equivalent setting.
    ``max_staleness`` bounds how many fusions old an upload may be and
    still fuse; older uploads are dropped with telemetry.
    ``staleness_exponent`` is ``a`` in the FedAsync importance
    ``(1 + s)^-a``."""

    size: Optional[int] = None
    sampler: str = "uniform"
    buffer_size: Optional[int] = None
    max_staleness: int = 4
    staleness_exponent: float = 0.5
    traffic: TrafficSpec = dataclasses.field(default_factory=TrafficSpec)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["traffic"] = self.traffic.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PopulationSpec":
        _check_keys(cls, d)
        d = dict(d)
        if "traffic" in d and isinstance(d["traffic"], dict):
            d["traffic"] = TrafficSpec.from_dict(d["traffic"])
        return cls(**d)


@dataclasses.dataclass
class FaultSpec:
    """Fault injection + robust-fusion defenses (docs/robustness.md).

    Injection knobs are per-upload probabilities; draws are
    counter-based on ``(seed, domain, wave, client, attempt)``
    (``repro.population.faults``) so a fault trace is a pure function of
    the spec — resumed runs never replay or shift it.  ``byzantine_frac``
    marks a persistent (static-domain) subset of clients adversarial,
    like traffic stragglers.

    Defenses (``screen`` — finite-ness + delta-norm quarantine;
    ``teacher_filter`` — FedDF logit-consensus teacher dropping) default
    to ``"auto"``: active iff any injection rate is positive, which
    keeps fault-free configs bit-identical to historic trajectories.
    ``quorum`` is the minimum usable-upload fraction a round needs to
    fuse (``None`` keeps the historic strict behavior); ``retries`` /
    ``backoff`` govern re-dispatch of rejected uploads."""

    nan_rate: float = 0.0            # P(NaN/Inf poisoning) per upload
    byzantine_frac: float = 0.0      # persistent adversarial client frac
    byzantine_scale: float = 10.0    # delta amplification
    byzantine_mode: str = "sign_flip"  # sign_flip | scale
    bitflip_rate: float = 0.0        # P(payload bit corruption) per upload
    bitflip_bits: int = 4            # XOR'd bits per corrupted payload
    crash_rate: float = 0.0          # P(mid-round crash -> partial upload)
    screen: str = "auto"             # auto | on | off
    norm_sigma: float = 6.0          # robust-z quarantine threshold
    teacher_filter: str = "auto"     # auto | on | off
    teacher_sigma: float = 6.0       # robust-z teacher-consensus threshold
    quorum: Optional[float] = None   # min usable fraction to fuse
    retries: int = 2                 # re-dispatch attempts per rejection
    backoff: float = 2.0             # exponential backoff base (virtual s)
    # transport-domain faults (distributed driver; docs/distributed.md):
    # injected on UPLOAD frames in flight, drawn from the same
    # counter-based rng under domain "transport" keyed by (wave, pod,
    # attempt) — a retry is a fresh draw, never a replay
    transport_drop: float = 0.0      # P(frame silently lost)
    transport_corrupt: float = 0.0   # P(frame bytes flipped in flight)
    transport_delay: float = 0.0     # P(frame delivery delayed)
    transport_delay_s: float = 0.25  # delay duration when delayed
    transport_disconnect: float = 0.0  # P(pod link goes dark mid-round)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class DriverSpec:
    """Round-driver selection (``repro.drivers`` registry; see
    docs/drivers.md).

    ``kind``: ``sync`` (serial reference loop) | ``async_pipelined``
    (round t+1's client training overlaps round t's fusion) |
    ``multihost`` (client axis sharded over a host/device mesh) — or any
    registered extension.  ``staleness`` bounds how many rounds the
    async driver's training base may lag the newest fusion (0 == exact
    sync semantics, 1 == one-round overlap; async only).  ``prefetch``
    is how many rounds of host-side batch building run ahead."""

    kind: str = "sync"
    staleness: int = 0
    prefetch: int = 1

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DriverSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class ObsSpec:
    """Flight-recorder observability (docs/observability.md).

    Everything defaults OFF; a disarmed run is bit-identical to the
    historic trajectory (pinned in ``tests/test_obs.py``).  ``trace``
    arms phase-span tracing for the run — spans land in memory (they
    feed ``RunResult.summary()["obs"]``) and, when ``trace_path`` is
    set, stream to an append-only JSONL file (a resumed run pointed at
    the same path continues the stream).  ``metrics_dir`` streams one
    per-round metrics record (registry counter deltas + accuracy +
    device watermark) to ``<dir>/metrics.jsonl`` and ``.csv``.
    ``profile`` additionally wraps the run in
    ``jax.profiler.start_trace(profile_dir)`` with a
    ``TraceAnnotation`` per span, putting the span taxonomy on XLA
    timelines; it requires ``profile_dir``."""

    trace: bool = False
    trace_path: Optional[str] = None
    metrics_dir: Optional[str] = None
    profile: bool = False
    profile_dir: Optional[str] = None

    @property
    def enabled(self) -> bool:
        """Does this spec arm the recorder at all?"""
        return bool(self.trace or self.trace_path or self.metrics_dir
                    or self.profile)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ObsSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class DistSpec:
    """Distributed-runtime topology + wire protocol (docs/distributed.md;
    ``repro.dist``; only read by ``driver.kind == "distributed"``).

    ``transport``: ``loopback`` (pods are threads, links are queues —
    the CI transport) or ``tcp`` (one subprocess per pod on localhost).
    ``wire_codec`` names the payload codec for client uploads
    (``repro.dist.frames``: ``fp32`` exact, ``binarize`` / ``int8``
    low-bit) — the downlink globals always travel fp32 so pods train
    from bit-identical params.  ``heartbeat_s`` is the pod heartbeat
    period (a pod is presumed dead after 3 missed beats);
    ``upload_deadline_s`` bounds each TRAIN->UPLOAD wait before the
    fusion pod re-dispatches with exponential backoff
    (``faults.backoff``).  ``verify_crc=False`` is the *undefended*
    ablation: corrupted frames are accepted instead of retried.
    ``wire_log`` appends every accepted UPLOAD frame to a crash-safe
    record log; a restarted fusion pod replays it so in-flight work
    survives the restart.

    The degenerate setting — loopback, fp32, zero transport faults —
    is bit-identical to ``driver.kind == "sync"`` (pinned in
    ``tests/test_dist.py``)."""

    transport: str = "loopback"      # loopback | tcp
    wire_codec: str = "fp32"         # fp32 | binarize | int8
    n_pods: int = 2
    heartbeat_s: float = 5.0
    upload_deadline_s: float = 30.0
    verify_crc: bool = True
    wire_log: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DistSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclasses.dataclass
class ExperimentSpec:
    """The complete, serializable description of one federated run."""

    task: TaskSpec = dataclasses.field(default_factory=TaskSpec)
    partition: PartitionSpec = dataclasses.field(
        default_factory=PartitionSpec)
    cohort: CohortSpec = dataclasses.field(default_factory=CohortSpec)
    strategy: StrategySpec = dataclasses.field(default_factory=StrategySpec)
    source: Optional[SourceSpec] = dataclasses.field(
        default_factory=SourceSpec)
    privacy: PrivacySpec = dataclasses.field(default_factory=PrivacySpec)
    sharding: ShardingSpec = dataclasses.field(default_factory=ShardingSpec)
    driver: DriverSpec = dataclasses.field(default_factory=DriverSpec)
    bucket: BucketSpec = dataclasses.field(default_factory=BucketSpec)
    population: PopulationSpec = dataclasses.field(
        default_factory=PopulationSpec)
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    obs: ObsSpec = dataclasses.field(default_factory=ObsSpec)
    dist: DistSpec = dataclasses.field(default_factory=DistSpec)
    # round loop
    rounds: int = 20
    client_fraction: float = 0.4
    local_epochs: int = 20
    local_batch_size: int = 32
    local_lr: float = 0.1
    local_optimizer: str = "sgd"     # sgd | adam (Table 6)
    local_adam_lr: float = 1e-3
    target_accuracy: Optional[float] = None
    seed: int = 0

    # -- serialization ----------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "task": self.task.to_dict(),
            "partition": self.partition.to_dict(),
            "cohort": self.cohort.to_dict(),
            "strategy": self.strategy.to_dict(),
            "source": None if self.source is None else self.source.to_dict(),
            "privacy": self.privacy.to_dict(),
            "sharding": self.sharding.to_dict(),
            "driver": self.driver.to_dict(),
            "bucket": self.bucket.to_dict(),
            "population": self.population.to_dict(),
            "faults": self.faults.to_dict(),
            "obs": self.obs.to_dict(),
            "dist": self.dist.to_dict(),
            "rounds": self.rounds,
            "client_fraction": self.client_fraction,
            "local_epochs": self.local_epochs,
            "local_batch_size": self.local_batch_size,
            "local_lr": self.local_lr,
            "local_optimizer": self.local_optimizer,
            "local_adam_lr": self.local_adam_lr,
            "target_accuracy": self.target_accuracy,
            "seed": self.seed,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        _check_keys(cls, d)
        d = dict(d)
        nested = {"task": TaskSpec, "partition": PartitionSpec,
                  "cohort": CohortSpec, "strategy": StrategySpec,
                  "privacy": PrivacySpec, "sharding": ShardingSpec,
                  "driver": DriverSpec, "bucket": BucketSpec,
                  "population": PopulationSpec, "faults": FaultSpec,
                  "obs": ObsSpec, "dist": DistSpec}
        for key, sub in nested.items():
            if key in d and isinstance(d[key], dict):
                d[key] = sub.from_dict(d[key])
        if d.get("source") is not None and isinstance(d["source"], dict):
            d["source"] = SourceSpec.from_dict(d["source"])
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- validation -------------------------------------------------------

    def validate(self) -> "ExperimentSpec":
        """Resolve every registry name and check ranges; returns self so
        ``Experiment(spec.validate())`` chains."""
        # local import: registries import nothing from here at module level,
        # but keep the spec module importable without jax-heavy builders
        from repro.api import registries as R
        from repro.core.strategies import get_strategy

        R.get_task(self.task.name)
        for m in self.cohort.prototypes:
            R.get_model(m.name)
        if self.source is not None:
            R.get_source(self.source.name)
        if self.privacy.quantizer is not None:
            R.get_quantizer(self.privacy.quantizer)
        strategy = get_strategy(self.strategy.name)
        if strategy.needs_source and self.source is None:
            raise ValueError(
                f"strategy {self.strategy.name!r} needs a distillation "
                f"source but spec.source is None")

        from repro.common.options import (BANK_DTYPES, FUSED_KERNEL_MODES,
                                          LOGIT_BANK_MODES)
        fusion = self.strategy.fusion
        if fusion.logit_bank not in LOGIT_BANK_MODES:
            raise ValueError(
                f"fusion.logit_bank must be one of {LOGIT_BANK_MODES}, "
                f"got {fusion.logit_bank!r}")
        if fusion.bank_dtype not in BANK_DTYPES:
            raise ValueError(
                f"fusion.bank_dtype must be one of {BANK_DTYPES}, got "
                f"{fusion.bank_dtype!r}")
        # isinstance check, not membership: `1 in (True, False, "auto")`
        # is True, but the runtime resolver (ops.use_pallas) rejects ints
        if not (isinstance(fusion.use_fused_kernel, bool)
                or fusion.use_fused_kernel == "auto"):
            raise ValueError(
                f"fusion.use_fused_kernel must be one of "
                f"{FUSED_KERNEL_MODES}, got {fusion.use_fused_kernel!r}")

        from repro.common.options import BUCKET_KINDS
        if fusion.distill_bucket not in BUCKET_KINDS:
            raise ValueError(
                f"fusion.distill_bucket must be one of {BUCKET_KINDS}, "
                f"got {fusion.distill_bucket!r}")
        if fusion.distill_max_buckets < 1:
            raise ValueError(
                f"fusion.distill_max_buckets must be >= 1, got "
                f"{fusion.distill_max_buckets}")
        if fusion.batch_sizes is not None:
            if len(fusion.batch_sizes) != len(self.cohort.prototypes):
                raise ValueError(
                    f"fusion.batch_sizes has {len(fusion.batch_sizes)} "
                    f"entries for {len(self.cohort.prototypes)} cohort "
                    f"prototypes (one distill batch size per prototype)")
            bad = [b for b in fusion.batch_sizes if int(b) < 1]
            if bad:
                raise ValueError(
                    f"fusion.batch_sizes must all be >= 1, got {bad}")
        if self.bucket.kind not in BUCKET_KINDS:
            raise ValueError(
                f"bucket.kind must be one of {BUCKET_KINDS}, got "
                f"{self.bucket.kind!r}")
        if self.bucket.max_buckets < 1:
            raise ValueError(
                f"bucket.max_buckets must be >= 1, got "
                f"{self.bucket.max_buckets}")

        from repro.drivers import get_driver
        get_driver(self.driver.kind)  # unknown kinds fail before any work
        if self.driver.staleness < 0:
            raise ValueError(
                f"driver.staleness must be >= 0 (bounded staleness), "
                f"got {self.driver.staleness}")
        if self.driver.staleness and self.driver.kind not in (
                "async_pipelined", "buffered_async"):
            raise ValueError(
                f"driver.staleness > 0 only applies to the "
                f"'async_pipelined' / 'buffered_async' drivers, got kind "
                f"{self.driver.kind!r}")
        if self.driver.kind == "buffered_async" \
                and self.driver.staleness > 1:
            raise ValueError(
                f"buffered_async bounds driver.staleness to 0 or 1 "
                f"(upload staleness is population.max_staleness), got "
                f"{self.driver.staleness}")
        if self.driver.prefetch < 0:
            raise ValueError(
                f"driver.prefetch must be >= 0, got "
                f"{self.driver.prefetch}")

        from repro.common.options import TRANSPORT_KINDS
        from repro.dist.frames import available_codecs
        dist = self.dist
        if dist.transport not in TRANSPORT_KINDS:
            raise ValueError(
                f"dist.transport must be one of {TRANSPORT_KINDS}, got "
                f"{dist.transport!r}")
        if dist.wire_codec not in available_codecs():
            raise ValueError(
                f"dist.wire_codec must be one of {available_codecs()}, "
                f"got {dist.wire_codec!r}")
        if dist.n_pods < 1:
            raise ValueError(f"dist.n_pods must be >= 1, got {dist.n_pods}")
        if dist.heartbeat_s <= 0 or dist.upload_deadline_s <= 0:
            raise ValueError(
                "dist.heartbeat_s and dist.upload_deadline_s must be > 0")

        from repro.common.options import ARRIVAL_KINDS
        from repro.population.scheduler import get_sampler
        pop, tr = self.population, self.population.traffic
        get_sampler(pop.sampler)  # unknown sampler names fail eagerly
        if pop.size is not None and pop.size < 1:
            raise ValueError(f"population.size must be >= 1 or None, got "
                             f"{pop.size}")
        if pop.buffer_size is not None and pop.buffer_size < 1:
            raise ValueError(
                f"population.buffer_size must be >= 1 or None, got "
                f"{pop.buffer_size}")
        if pop.max_staleness < 0:
            raise ValueError(f"population.max_staleness must be >= 0, "
                             f"got {pop.max_staleness}")
        if pop.staleness_exponent < 0:
            raise ValueError(
                f"population.staleness_exponent must be >= 0, got "
                f"{pop.staleness_exponent}")
        if self.driver.kind == "buffered_async" \
                and self.driver.staleness > pop.max_staleness:
            raise ValueError(
                f"buffered_async with driver.staleness="
                f"{self.driver.staleness} needs population.max_staleness "
                f">= {self.driver.staleness} (overlap-trained uploads "
                f"would all be stale-dropped), got {pop.max_staleness}")
        if tr.arrival not in ARRIVAL_KINDS:
            raise ValueError(
                f"traffic.arrival must be one of {ARRIVAL_KINDS}, got "
                f"{tr.arrival!r}")
        if not 0.0 < tr.rate <= 1.0:
            raise ValueError(
                f"traffic.rate must be in (0, 1], got {tr.rate}")
        if tr.latency < 0 or tr.jitter < 0:
            raise ValueError("traffic.latency and traffic.jitter must be "
                             ">= 0")
        if not 0.0 <= tr.straggler_frac <= 1.0:
            raise ValueError(
                f"traffic.straggler_frac must be in [0, 1], got "
                f"{tr.straggler_frac}")
        if tr.straggler_mult < 1.0:
            raise ValueError(
                f"traffic.straggler_mult must be >= 1, got "
                f"{tr.straggler_mult}")
        if not 0.0 <= tr.dropout < 1.0:
            raise ValueError(
                f"traffic.dropout must be in [0, 1), got {tr.dropout}")

        if self.obs.profile and not self.obs.profile_dir:
            raise ValueError(
                "obs.profile=True needs obs.profile_dir (where "
                "jax.profiler.start_trace writes its artifacts)")

        # fault knobs share their ranges/messages with the engine-level
        # mirror — one validator, no drift between the two layers
        from repro.population.config import FaultConfig
        FaultConfig(**self.faults.to_dict()).validate()
        if not 0.0 <= self.strategy.trim_frac < 0.5:
            raise ValueError(
                f"strategy.trim_frac must be in [0, 0.5) (trimming half "
                f"or more from each end leaves nothing), got "
                f"{self.strategy.trim_frac}")

        if not self.cohort.prototypes:
            raise ValueError("cohort needs at least one prototype")
        if (self.cohort.assignment != "round_robin"
                and not isinstance(self.cohort.assignment, list)):
            raise ValueError(
                "cohort.assignment must be 'round_robin' or a list of "
                "prototype indices")
        if isinstance(self.cohort.assignment, list):
            if len(self.cohort.assignment) != self.partition.n_clients:
                raise ValueError(
                    f"cohort.assignment has {len(self.cohort.assignment)} "
                    f"entries for {self.partition.n_clients} clients")
            bad = [p for p in self.cohort.assignment
                   if not 0 <= int(p) < len(self.cohort.prototypes)]
            if bad:
                raise ValueError(f"cohort.assignment references unknown "
                                 f"prototype indices {bad}")

        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if not 0.0 < self.client_fraction <= 1.0:
            raise ValueError(
                f"client_fraction must be in (0, 1], got "
                f"{self.client_fraction}")
        if self.partition.n_clients < 1:
            raise ValueError("partition.n_clients must be >= 1")
        if self.local_epochs < 1 or self.local_batch_size < 1:
            raise ValueError("local_epochs and local_batch_size must be "
                             ">= 1")
        if self.local_optimizer not in ("sgd", "adam"):
            raise ValueError(
                f"local_optimizer must be 'sgd' or 'adam', got "
                f"{self.local_optimizer!r}")
        return self

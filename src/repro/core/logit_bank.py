"""Teacher-logit bank: the precomputed, shared, device-resident fast path
for FedDF's server-side distillation.

FedDF's cost center is the fusion loop — up to 10k Adam steps per round
where every step re-forwards *all K frozen teachers* on the distillation
batch, and in the heterogeneous case every one of the G group-students
redundantly re-forwards the same all-groups teacher ensemble.  But the
teachers are FROZEN during fusion and AVGLOGITS only ever consumes
``mean_k f(x_k, d)``: for a source with a finite pool (``DistillSource.
pool()``), the per-example averaged teacher logits can be computed ONCE —
one chunked vmapped forward pass per teacher group over the pool, reduced
on the fly to ``[N, C]`` — and the scan then *gathers* bank rows by the
sampled indices instead of calling the teachers per step:

    teacher forwards:  K x steps            ->  K x ceil(N / chunk)
    heterogeneous:     G x K x steps        ->  K x ceil(N / chunk)   (shared)

Memory: ``N x C x itemsize(bank_dtype)`` bytes, plus one fp32 scale per
row for the quantized dtypes (fp32 default; bf16 halves the rows; int8 /
fp8_e4m3 shrink them 4x to ``N x C x 1 + N x 4`` with per-row symmetric
scales computed during the build pass — the fused distill kernel
dequantizes rows on the fly, see ``kernels/ensemble_kl.ensemble_kl_bank``).
The bank lives on device next to its pool; pass a ``sharding`` to spread
the N axis over a mesh.  See docs/distill_fast_path.md for the lifecycle
and the break-even analysis against the on-the-fly path.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
import weakref
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.common.counters import TraceCounter
from repro.obs import trace as _trace
from repro.obs.metrics import REGISTRY
from repro.common.options import (BANK_DTYPES, LOGIT_BANK_MODES,
                                  QUANTIZED_BANK_DTYPES)

DEFAULT_CHUNK = 512

# symmetric per-row quantization: q = round/cast(row / scale) with
# scale = amax(|row|) / QUANT_MAX[dtype], so the row's extremes land
# exactly on the representable range
_INT8_MAX = 127.0
_FP8_E4M3_MAX = 448.0  # largest finite float8_e4m3fn value


def _storage_dtypes():
    out = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "int8": jnp.int8}
    fp8 = getattr(jnp, "float8_e4m3fn", None)
    if fp8 is not None:  # backend/jax support is optional
        out["fp8_e4m3"] = fp8
    return out


_BANK_DTYPES = _storage_dtypes()
_QUANT_MAX = {"int8": _INT8_MAX, "fp8_e4m3": _FP8_E4M3_MAX}

# kept under the historic name: feddf.py (CHUNK_COMPILES) and downstream
# code construct counters via this alias
_ForwardCounter = TraceCounter

# Process-wide count of teacher *batch* forwards (one teacher, one batch
# of rows) — the bench/tests' evidence that the bank removes the K x steps
# (and hetero G x) redundancy.  Lives in the unified metrics registry
# under a dotted name; this alias keeps the historic interface.
TEACHER_FORWARDS = REGISTRY.counter("core.logit_bank.teacher_forwards")


@dataclasses.dataclass
class LogitBank:
    """Per-round bank of averaged teacher logits over a distillation pool.

    ``pool``: device-resident inputs [N, ...]; ``logits``: mean-over-all-
    teachers logits [N, C] in ``bank_dtype``.  Built once per round (and
    shared by every group-student in heterogeneous fusion); discarded when
    the round's fused models are done.
    """

    pool: jax.Array
    logits: jax.Array
    n_teachers: int
    n_teacher_batch_forwards: int
    build_time_s: float
    # per-row fp32 dequantization scales [N] for the quantized dtypes
    # (int8 / fp8_e4m3); None for float32 / bfloat16 rows
    scales: Optional[jax.Array] = None
    # the FusionConfig.bank_dtype literal these rows are stored in
    dtype_name: str = "float32"
    # True when these rows came out of the persistent cross-round cache
    # (static teacher pool) instead of a fresh build — callers charge zero
    # build forwards for a reused bank
    reused: bool = False

    @property
    def n(self) -> int:
        return int(self.pool.shape[0])

    @property
    def quantized(self) -> bool:
        return self.scales is not None

    @property
    def nbytes(self) -> int:
        """Bank row bytes, scales included — the observable the quantized
        dtypes exist to shrink (N x C x 1 + N x 4 vs N x C x 4)."""
        total = int(self.logits.size) * self.logits.dtype.itemsize
        if self.scales is not None:
            total += int(self.scales.size) * self.scales.dtype.itemsize
        return total


def bank_dtype(name: str):
    """Storage jnp dtype for a ``FusionConfig.bank_dtype`` literal.  Raises
    for unknown names, and for ``fp8_e4m3`` when this jax build has no
    float8 support (the literal itself is always spec-valid)."""
    if name in BANK_DTYPES and name not in _BANK_DTYPES:
        raise ValueError(
            f"bank_dtype {name!r} is not supported by this jax build "
            f"(no jnp.float8_e4m3fn); use one of {sorted(_BANK_DTYPES)}")
    if name not in _BANK_DTYPES:
        raise ValueError(f"bank_dtype must be one of "
                         f"{sorted(BANK_DTYPES)}, got {name!r}")
    return _BANK_DTYPES[name]


def quantize_rows(rows: jax.Array, dtype_name: str
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row quantization of fp32 logit rows ``[M, C]`` ->
    ``(q [M, C] storage-dtype, scales [M] fp32)``.

    ``scale_i = amax(|row_i|) / qmax`` maps each row's extremes onto the
    full representable range, so the worst-case dequant error is bounded
    per row (int8: ``scale_i / 2`` from rounding).  All-zero rows get
    scale 1 so dequantization is exact.  KL is shift-invariant in the
    logits but NOT scale-invariant, which is why the scale must ride
    along instead of being folded into a global constant.
    """
    qmax = _QUANT_MAX[dtype_name]
    storage = bank_dtype(dtype_name)
    rows = rows.astype(jnp.float32)
    amax = jnp.max(jnp.abs(rows), axis=-1)
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    scaled = rows / scales[:, None]
    if dtype_name == "int8":
        q = jnp.clip(jnp.round(scaled), -_INT8_MAX, _INT8_MAX)
    else:  # fp8: the cast itself rounds; clip guards the finite range
        q = jnp.clip(scaled, -qmax, qmax)
    return q.astype(storage), scales


def dequantize_rows(rows: jax.Array,
                    scales: Optional[jax.Array] = None) -> jax.Array:
    """fp32 logit rows from stored bank rows (+ their per-row scales)."""
    out = rows.astype(jnp.float32)
    if scales is not None:
        out = out * scales[..., None]
    return out


def _dtype_name_of(dtype) -> str:
    """Normalize a ``dtype`` argument (BANK_DTYPES literal or the jnp
    dtype itself — the historic calling convention) to the literal."""
    if isinstance(dtype, str):
        bank_dtype(dtype)  # validate
        return dtype
    for name, jdt in _BANK_DTYPES.items():
        if jnp.dtype(dtype) == jnp.dtype(jdt):
            return name
    raise ValueError(f"unsupported bank dtype {dtype!r}; "
                     f"use one of {sorted(_BANK_DTYPES)}")


def build_logit_bank(teacher_logit_fns: Sequence[Callable], pool, *,
                     chunk_size: int = DEFAULT_CHUNK, dtype=jnp.float32,
                     sharding=None, teacher_weights=None) -> LogitBank:
    """One chunked pass of every teacher group over ``pool`` -> LogitBank.

    Each chunk evaluates all groups' stacked teachers ([K_g, c, C] each),
    concatenates along the teacher axis and reduces to the fp32 mean on
    the fly — the full [K, N, C] tensor is never materialized.  With
    ``dtype=float32`` the stored rows are the exact values the on-the-fly
    path would have averaged per step, so trajectories match.  For the
    quantized dtypes (``int8`` / ``fp8_e4m3``, by literal name or storage
    jnp dtype) each chunk's fp32 mean is quantized inside the same jitted
    pass — per-row scales ride on ``LogitBank.scales`` and the full fp32
    bank never materializes either.

    ``teacher_weights`` ([k_total] in concat order; normalized or not —
    it is re-normalized here) folds a weighted teacher consensus into the
    stored rows at build time (the buffered-async staleness-importance
    path, docs/population.md): downstream gathers stay byte-identical in
    shape and cost.  None keeps the historic uniform mean bitwise.
    """
    t0 = time.time()
    dtype_name = _dtype_name_of(dtype)
    storage = bank_dtype(dtype_name)
    quantized = dtype_name in QUANTIZED_BANK_DTYPES
    pool = jnp.asarray(pool)
    n = int(pool.shape[0])
    c = max(1, min(int(chunk_size), n))
    n_chunks = -(-n // c)
    pad = n_chunks * c - n
    pool_p = (jnp.concatenate(
        [pool, jnp.zeros((pad,) + pool.shape[1:], pool.dtype)])
        if pad else pool)

    k_total = int(jax.eval_shape(
        lambda xc: jnp.concatenate(
            [jnp.asarray(f(xc)) for f in teacher_logit_fns], axis=0),
        jax.ShapeDtypeStruct((c,) + pool.shape[1:], pool.dtype)).shape[0])

    w_norm = None
    if teacher_weights is not None:
        w = jnp.asarray(teacher_weights, jnp.float32)
        if w.shape != (k_total,):
            raise ValueError(
                f"teacher_weights must have shape ({k_total},) to match "
                f"the concatenated teacher axis, got {tuple(w.shape)}")
        w_norm = w / jnp.sum(w)

    @jax.jit
    def fwd(xc):
        t = jnp.concatenate(
            [jnp.asarray(f(xc)) for f in teacher_logit_fns], axis=0)
        t = t.astype(jnp.float32)
        mean = (jnp.mean(t, axis=0) if w_norm is None
                else jnp.tensordot(w_norm, t, axes=([0], [0])))
        if quantized:
            return quantize_rows(mean, dtype_name)
        return mean.astype(storage), None

    chunks, scale_chunks = [], []
    for i in range(n_chunks):
        rows, sc = fwd(pool_p[i * c:(i + 1) * c])
        chunks.append(rows)
        if sc is not None:
            scale_chunks.append(sc)
        TEACHER_FORWARDS.add(k_total)
    logits = (jnp.concatenate(chunks, axis=0)[:n] if n_chunks > 1
              else chunks[0][:n])
    scales = None
    if scale_chunks:
        scales = (jnp.concatenate(scale_chunks, axis=0)[:n]
                  if n_chunks > 1 else scale_chunks[0][:n])
    if sharding is not None:
        pool = jax.device_put(pool, sharding)
        logits = jax.device_put(logits, sharding)
        if scales is not None:
            scales = jax.device_put(scales, sharding)
    return LogitBank(pool=pool, logits=logits, n_teachers=k_total,
                     n_teacher_batch_forwards=n_chunks * k_total,
                     build_time_s=time.time() - t0,
                     scales=scales, dtype_name=dtype_name)


class _PersistentBankCache:
    """Size-1 cross-round bank cache for STATIC teacher pools.

    Keyed on teacher-stack *identity* (the ``id()`` of every stacked
    teacher leaf plus the pool object and bank dtype): when the exact
    same frozen teacher arrays are fused again — e.g. repeated
    ``feddf_init_from='previous'`` ablation sweeps or benchmarks
    re-fusing one round's uploads — the previous build's rows are reused
    instead of re-forwarding every teacher over the pool.  Any upload
    change produces new arrays, hence new ids, hence a miss that
    replaces the entry.

    The keyed arrays are held through WEAK references: a hit requires
    every one of them to still be alive, so a recycled id can never
    produce a false hit, and an ordinary training run — whose uploads
    die as soon as the next round replaces them — drops the entry (bank
    rows included, via the death callbacks) instead of pinning a whole
    round's working set for process lifetime.
    """

    def __init__(self):
        self._gen = 0
        self._key = None
        self._refs: Tuple = ()
        self._bank: Optional[LogitBank] = None

    def lookup(self, key) -> Optional[LogitBank]:
        if key is None or key != self._key:
            return None
        if any(r() is None for r in self._refs):
            self.clear()  # a keyed array died; its id may be recycled
            return None
        return self._bank

    def store(self, key, referents, bank: LogitBank) -> None:
        self._gen += 1
        gen = self._gen

        def on_dead(_ref, _gen=gen):
            # drop the bank as soon as any keyed upload is GC'd — unless
            # a newer entry (or clear) already superseded this one
            if self._gen == _gen:
                self.clear()

        self._key = key
        self._refs = tuple(weakref.ref(x, on_dead) for x in referents)
        self._bank = bank

    def clear(self) -> None:
        self._gen += 1
        self._key, self._refs, self._bank = None, (), None


PERSISTENT_BANK = _PersistentBankCache()


def _identity_key(teacher_logit_fns, pool, dtype_name: str,
                  teacher_weights=None):
    """(key, referents) for the persistent cache, or (None, ()) when any
    teacher fn is a plain callable without a stamped ``.stack`` (no
    stable identity to key on).  Teacher weights join the key by VALUE:
    the same frozen stacks re-fused under different staleness importance
    must not hit the uniform (or differently-weighted) entry."""
    ids, referents = [], []
    for f in teacher_logit_fns:
        stack = getattr(f, "stack", None)
        if stack is None:
            return None, ()
        leaves = jax.tree.leaves(stack)
        ids.extend(id(l) for l in leaves)
        referents.extend(leaves)
    referents.append(pool)
    w_key = (None if teacher_weights is None
             else tuple(float(w) for w in jnp.asarray(teacher_weights)))
    return (tuple(ids), id(pool), dtype_name, w_key), referents


def resolve_bank(teacher_logit_fns: Sequence[Callable], source, fusion, *,
                 sharding=None, expected_steps: Optional[int] = None,
                 teacher_weights=None
                 ) -> Tuple[Optional[LogitBank], str]:
    """Resolve ``FusionConfig.logit_bank`` against the source.

    Returns ``(bank_or_None, reason)`` where ``reason`` is one of
    ``built`` / ``reused`` (persistent-cache hit) / ``off`` /
    ``no_teachers`` / ``no_pool`` / ``skipped_small_run``.

    ``auto`` builds a bank whenever the source exposes a pool AND the run
    is long enough to amortize the build: with ``expected_steps`` given
    (the caller's early-stopping estimate), a run expected to touch fewer
    than ``N`` pool rows (``expected_steps x batch_size < N``) keeps the
    on-the-fly path — the bank's one full pass over the pool would cost
    more teacher forwards than it saves.  ``on`` always builds when it
    can and warns when it cannot (generator / noise synthesize inputs per
    step, so there is nothing to precompute over).
    """
    mode = getattr(fusion, "logit_bank", "off")
    if mode not in LOGIT_BANK_MODES:
        raise ValueError(f"logit_bank must be one of {LOGIT_BANK_MODES}, "
                         f"got {mode!r}")
    if mode == "off":
        return None, "off"
    if not teacher_logit_fns:
        return None, "no_teachers"
    pool_fn = getattr(source, "pool", None)
    pool = pool_fn() if callable(pool_fn) else None
    if pool is None:
        if mode == "on":
            warnings.warn(
                f"logit_bank='on' but source {type(source).__name__} has "
                f"no indexable pool(); falling back to on-the-fly teacher "
                f"forwards", UserWarning, stacklevel=2)
        return None, "no_pool"
    dtype_name = fusion.bank_dtype
    bank_dtype(dtype_name)  # validate before any early-out
    key, referents = (None, ()) if sharding is not None else \
        _identity_key(teacher_logit_fns, pool, dtype_name,
                      teacher_weights)
    # cache lookup precedes the break-even skip: a cached bank costs one
    # dict compare, so even a run too short to amortize a BUILD uses it
    cached = PERSISTENT_BANK.lookup(key)
    if cached is not None:
        with _trace.span("bank_reuse", pool_n=len(pool)):
            return dataclasses.replace(cached, reused=True), "reused"
    if (mode == "auto" and expected_steps is not None
            and expected_steps * fusion.batch_size < len(pool)):
        return None, "skipped_small_run"
    with _trace.span("bank_build", pool_n=len(pool),
                     n_teachers=len(teacher_logit_fns)):
        bank = build_logit_bank(teacher_logit_fns, pool,
                                dtype=bank_dtype(dtype_name),
                                sharding=sharding,
                                teacher_weights=teacher_weights)
    if key is not None:
        PERSISTENT_BANK.store(key, referents, bank)
    return bank, "built"


def bank_for_fusion(teacher_logit_fns: Sequence[Callable], source,
                    fusion, *, sharding=None,
                    expected_steps: Optional[int] = None
                    ) -> Optional[LogitBank]:
    """:func:`resolve_bank` without the reason (the historic surface)."""
    return resolve_bank(teacher_logit_fns, source, fusion,
                        sharding=sharding,
                        expected_steps=expected_steps)[0]

"""Population / buffered-async benchmark (ISSUE 7 acceptance).

Marginal UPLOAD throughput (uploads fused per second) of the
``buffered_async`` driver under a realistic traffic model against the
serial ``sync`` driver on the homogeneous K=8 toy config.  The buffered
driver's gain is FedBuff's amortization knob: the server fuses every
``M = buffer_size`` buffered uploads, so with M = 3K three client waves
share ONE ensemble-distillation fusion — the per-round server cost the
sync loop pays per K uploads — while waves train concurrently with the
previous fusion on a worker thread and stragglers fuse late with
``(1+s)^-a`` importance instead of gating the round.  Throughput is
MARGINAL between a short and a long run of the same config (min over
reps each), so per-run jit compiles cancel — the ``distill_bench``
idiom shared via ``benchmarks/timing.py``.

Also asserted, not just recorded: the DEGENERATE buffered config
(``buffer_size == K``, zero latency, uniform sampler, ``staleness=0``)
reproduces the sync per-round accuracy log exactly — the population
seam costs nothing when unused.  The traffic run's final-accuracy drift
vs sync is recorded and gated <= 0.5pt in CI.

Writes ``BENCH_population.json`` (override with ``BENCH_POPULATION_OUT``).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench, marginal_rate
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.drivers import make_driver
from repro.population import PopulationConfig, TrafficConfig

K = 8
DIM, CLASSES = 16, 10
POOL_N = 2048
OUT = os.environ.get("BENCH_POPULATION_OUT", "BENCH_population.json")

# the traffic regime the subsystem exists for: a quarter of the
# population uploads 8x slower, uploads jitter lognormally, a little
# dropout; max_staleness is generous so stragglers fuse downweighted
# instead of being discarded
TRAFFIC = TrafficConfig(arrival="bernoulli", rate=0.95, latency=1.0,
                        jitter=0.3, straggler_frac=0.25,
                        straggler_mult=8.0, dropout=0.02)


def _problem(seed=0):
    ds = gaussian_mixture(4000, n_classes=CLASSES, dim=DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, K, 1.0, seed=seed)
    src = UnlabeledDataset(np.random.default_rng(seed + 1).uniform(
        -3, 3, (POOL_N, DIM)).astype(np.float32))
    return train, val, test, parts, src


def _config(rounds, steps, population=None):
    # local training and fusion deliberately comparable: the buffered
    # driver hides wave training inside the previous round's fusion
    return FLConfig(
        strategy="feddf", rounds=rounds, client_fraction=1.0,
        local_epochs=25, local_batch_size=32, local_lr=0.05, seed=0,
        fusion=FusionConfig(max_steps=steps, patience=10 * steps,
                            eval_every=100, batch_size=128,
                            use_fused_kernel=False),
        population=population or PopulationConfig())


def run() -> None:
    r_short = 2
    r_long = scale(5, 8)
    steps = scale(500, 700)
    train, val, test, parts, src = _problem()
    net = mlp(DIM, CLASSES, hidden=(128, 128))

    def measure(driver_fn, population=None, uploads_per_round=K):
        def one_run(rounds):
            cfg = _config(rounds, steps, population)
            results, globals_, _ = run_rounds(
                [net], [0] * K, train, parts, val, test, cfg,
                source=src, driver=driver_fn())
            jax.block_until_ready(jax.tree.leaves(globals_[0])[0])
            return results[0]

        stats, result = marginal_rate(one_run, r_short, r_long, reps=2)
        return {"wall_short_s": stats["wall_short_s"],
                "wall_long_s": stats["wall_long_s"],
                "rounds_per_s": stats["per_s"],
                "uploads_per_s": stats["per_s"] * uploads_per_round,
                "final_acc": result.final_acc}, result

    sync, r_sync = measure(lambda: "sync")

    # degenerate buffered == sync, asserted bitwise on the accuracy log
    degen, r_degen = measure(
        lambda: make_driver("buffered_async", staleness=0))
    assert [l.test_acc for l in r_degen.logs] == \
        [l.test_acc for l in r_sync.logs], \
        "degenerate buffered_async must reproduce the sync trajectory"
    degen["trajectory_equal"] = True

    # M = 3K: three waves of client training per server fusion — the
    # FedBuff amortization the uploads/s ratio quantifies
    pop = PopulationConfig(size=4 * K, sampler="prioritized",
                           buffer_size=3 * K, max_staleness=8,
                           staleness_exponent=0.5, traffic=TRAFFIC)
    buf, r_buf = measure(
        lambda: make_driver("buffered_async", staleness=1),
        population=pop, uploads_per_round=3 * K)

    ratio = buf["uploads_per_s"] / sync["uploads_per_s"]
    drift = abs(r_sync.final_acc - r_buf.final_acc)
    mean_staleness = float(np.mean([
        sum(s * c for s, c in enumerate(l.staleness_hist)) /
        max(sum(l.staleness_hist), 1)
        for l in r_buf.logs if l.staleness_hist is not None]))
    rec = {
        "K": K, "dim": DIM, "classes": CLASSES, "hidden": [128, 128],
        "rounds_short": r_short, "rounds_long": r_long,
        "local_epochs": 25, "distill_steps": steps, "distill_batch": 128,
        "population_size": pop.size, "buffer_size": pop.buffer_size,
        "traffic": TRAFFIC.__dict__,
        "sync": sync, "buffered_degenerate": degen,
        "buffered_traffic": buf,
        "uploads_ratio": ratio,
        "final_acc_drift": drift,
        "mean_staleness": mean_staleness,
    }
    emit("population_upload_throughput", 1.0 / buf["uploads_per_s"],
         f"uploads_x{ratio:.2f}", record=rec)
    finish_bench("population", rec, out=OUT,
                 config={"K": K, "population_size": pop.size,
                         "buffer_size": pop.buffer_size,
                         "rounds_short": r_short, "rounds_long": r_long})
    print(f"wrote {OUT}: buffered_async(traffic) x{ratio:.2f} uploads/s "
          f"over sync ({sync['uploads_per_s']:.2f} -> "
          f"{buf['uploads_per_s']:.2f}), final-acc drift {drift:.4f}, "
          f"mean staleness {mean_staleness:.2f}")


if __name__ == "__main__":
    run()

from repro.models import attention, frontends, layers, moe, ssm, transformer

"""Modality frontend *stubs* (the one sanctioned carve-out).

[audio]/[vlm] architectures specify the transformer backbone only; the
mel-spectrogram + conv feature extractor (HuBERT) and the ViT/projector
(InternVL2) are represented by precomputed embeddings of the right shape,
delivered via ``input_specs()``.  This module only documents the expected
shapes and provides random-embedding generators for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.arch_config import ArchConfig


def audio_frames_spec(cfg: ArchConfig, batch: int, seq: int):
    """HuBERT-style: conv feature extractor output, one embedding per frame."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)


def vision_patches_spec(cfg: ArchConfig, batch: int):
    """InternVL2-style: projected ViT patch embeddings prepended to text."""
    return jax.ShapeDtypeStruct(
        (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)


def fake_audio_frames(key, cfg: ArchConfig, batch: int, seq: int,
                      dtype=jnp.float32):
    return jax.random.normal(key, (batch, seq, cfg.d_model), dtype) * 0.02


def fake_vision_patches(key, cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return jax.random.normal(
        key, (batch, cfg.n_frontend_tokens, cfg.d_model), dtype) * 0.02

"""Compact host-side client registry: struct-of-arrays for ~10^6 clients.

Each registered client is one row across a handful of numpy arrays — no
per-client Python objects — so a million-client registry costs
``size * 45`` bytes (see :attr:`ClientRegistry.nbytes` and the memory
formula in docs/population.md).  Clients map onto the engine's data
partitions round-robin (``partition[i] = i % n_partitions``): many
devices can share one data shard, which is how a fixed benchmark dataset
serves an arbitrarily large simulated population.

The registry is mutable run state: it checkpoints through
``checkpoint/io.py`` (``state_dict`` is a flat dict of arrays) and
``Experiment.resume`` restores it bit-identically.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

# EMA smoothing for observed upload latency (registry.ema_latency).
EMA_DECAY = 0.9

# Arrays persisted by state_dict, in a fixed order.
_FIELDS = ("partition", "proto", "steps", "bucket", "data_size",
           "last_seen", "uploads", "dropouts", "stale_drops", "in_flight",
           "ema_latency", "priority", "quarantines")

# Fields absent from pre-PR 8 checkpoints load with these defaults.
_FIELD_DEFAULTS = {"quarantines": (np.int32, 0)}


class ClientRegistry:
    """Struct-of-arrays state for a registered client population.

    Static per-client facts (data partition, prototype, local step count
    and PR 5 step-bucket) are derived once from the engine's partition
    tables; dynamic counters (last-seen wave, uploads, dropouts, EMA
    latency, sampling priority) are updated by the
    :class:`~repro.population.manager.PopulationManager` as traffic flows.
    """

    def __init__(self, size: int, partition_sizes: Sequence[int],
                 client_steps: Sequence[int], client_proto: Sequence[int],
                 client_bucket: Sequence[int]):
        n_parts = len(partition_sizes)
        if size < 1 or n_parts < 1:
            raise ValueError("registry needs size >= 1 and >= 1 partition")
        self.size = int(size)
        part = (np.arange(self.size, dtype=np.int64) % n_parts)
        # static (derived, but persisted so a resumed registry never
        # depends on re-derivation order)
        self.partition = part.astype(np.int32)
        self.proto = np.asarray(client_proto, np.int16)[part]
        self.steps = np.asarray(client_steps, np.int32)[part]
        self.bucket = np.asarray(client_bucket, np.int16)[part]
        self.data_size = np.asarray(partition_sizes, np.int32)[part]
        # dynamic
        self.last_seen = np.full(self.size, -1, np.int32)   # wave index
        self.uploads = np.zeros(self.size, np.int32)
        self.dropouts = np.zeros(self.size, np.int32)
        self.stale_drops = np.zeros(self.size, np.int32)
        self.in_flight = np.zeros(self.size, np.bool_)
        self.ema_latency = np.zeros(self.size, np.float32)
        self.priority = np.ones(self.size, np.float32)
        self.quarantines = np.zeros(self.size, np.int32)

    # -- traffic hooks ---------------------------------------------------

    def record_dispatch(self, ids: np.ndarray, wave: int) -> None:
        self.last_seen[ids] = wave
        self.in_flight[ids] = True

    def record_dropout(self, ids) -> None:
        self.dropouts[ids] += 1
        self.in_flight[ids] = False

    def record_stale_drop(self, ids) -> None:
        self.stale_drops[ids] += 1
        self.in_flight[ids] = False

    def record_upload(self, ids, latency, staleness) -> None:
        self.uploads[ids] += 1
        self.in_flight[ids] = False
        prev = self.ema_latency[ids]
        obs = np.asarray(latency, np.float32)
        first = self.uploads[ids] == 1
        self.ema_latency[ids] = np.where(
            first, obs, EMA_DECAY * prev + (1.0 - EMA_DECAY) * obs)
        # stale clients bubble up for the prioritized sampler
        self.priority[ids] = 1.0 + np.asarray(staleness, np.float32)

    def record_quarantine(self, ids) -> None:
        """An upload was rejected by screening (docs/robustness.md)."""
        self.quarantines[ids] += 1
        self.in_flight[ids] = False
        # quarantined clients sink in the prioritized sampler: repeat
        # offenders decay geometrically toward never-sampled
        self.priority[ids] = self.priority[ids] * np.float32(0.5)

    # -- checkpointing ---------------------------------------------------

    @property
    def nbytes(self) -> int:
        """Host bytes across all per-client arrays (45 B/client)."""
        return sum(getattr(self, f).nbytes for f in _FIELDS)

    def state_dict(self) -> Dict[str, np.ndarray]:
        d: Dict[str, np.ndarray] = {"size": self.size}
        for f in _FIELDS:
            d[f] = getattr(self, f)
        return d

    @classmethod
    def from_state(cls, d: Dict[str, np.ndarray]) -> "ClientRegistry":
        reg = cls.__new__(cls)
        reg.size = int(d["size"])
        for f in _FIELDS:
            if f not in d:  # field newer than the checkpoint
                dt, fill = _FIELD_DEFAULTS[f]
                setattr(reg, f, np.full(reg.size, fill, dt))
                continue
            # np.array (not asarray): checkpoint restore hands back
            # read-only device-backed arrays; registry rows are mutable
            setattr(reg, f, np.array(d[f]))
        return reg

    def load_state(self, d: Dict[str, np.ndarray]) -> None:
        if int(d["size"]) != self.size:
            raise ValueError(f"registry size mismatch: checkpoint has "
                             f"{d['size']}, run has {self.size}")
        for f in _FIELDS:
            cur = getattr(self, f)
            if f not in d:
                dt, fill = _FIELD_DEFAULTS[f]
                setattr(self, f, np.full(self.size, fill, dt))
                continue
            setattr(self, f, np.array(d[f], dtype=cur.dtype))

"""Low-bit client models (paper §4.3, Table 4): binarized weights trained
with the straight-through estimator [Bengio et al.; Hubara et al.].

The client maintains a full-precision master copy; the forward pass sees
``sign(w) * mean|w|`` (XNOR-Net scaling); the backward pass is identity
(STE), implemented with ``stop_gradient`` so the same quantizer works inside
any ``jax.grad``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binarize_leaf(w: jax.Array) -> jax.Array:
    scale = jnp.mean(jnp.abs(w))
    q = jnp.sign(w) * scale
    return w + jax.lax.stop_gradient(q - w)  # STE


def binarize(params: dict, min_size: int = 32) -> dict:
    """Binarize weight matrices; leave vectors (norms, biases, BN stats)
    full-precision, as is standard for binary nets."""

    def q(x):
        if (jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2
                and x.size >= min_size):
            return binarize_leaf(x)
        return x

    return jax.tree.map(q, params)


def comm_bytes(params: dict, binarized: bool = False) -> int:
    """Per-round uplink cost — the Table 4 motivation (1-bit vs 32-bit)."""
    total = 0
    for x in jax.tree.leaves(params):
        if binarized and x.ndim >= 2 and x.size >= 32:
            total += (x.size + 7) // 8 + 4  # 1 bit each + fp32 scale
        else:
            total += x.size * x.dtype.itemsize
    return int(total)

"""FedDF core behaviour: fusion improves on parameter averaging under
non-iid clients; drop-worst removes dummies; hetero fusion runs; FedAvgM /
FedProx behave as specified."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FLConfig, FusionConfig, run_federated, mlp,
                        ensemble_accuracy)
from repro.core.client import build_batches, evaluate, make_local_update
from repro.core.dropworst import drop_worst
from repro.core.feddf import feddf_fuse_homogeneous
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.optim.optimizers import sgd


@pytest.fixture(scope="module")
def setup():
    ds = gaussian_mixture(3000, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, n_clients=6, alpha=0.1, seed=0)
    net = mlp(2, 3, hidden=(24, 24))
    src = UnlabeledDataset(
        np.random.default_rng(1).uniform(-3, 3, (800, 2)).astype(np.float32))
    return net, train, val, test, parts, src


def _train_clients(net, train, parts, rounds_key=0, epochs=15):
    upd = make_local_update(net, sgd(0.05))
    g = net.init(jax.random.PRNGKey(rounds_key))
    out, w = [], []
    for k, idx in enumerate(parts):
        xb, yb = build_batches(train.x[idx], train.y[idx], 32, epochs, seed=k)
        out.append(upd(g, jnp.asarray(xb), jnp.asarray(yb), g))
        w.append(float(len(idx)))
    return g, out, w


def test_fusion_beats_plain_average(setup):
    net, train, val, test, parts, src = setup
    _, client_params, weights = _train_clients(net, train, parts)
    from repro.common.pytree import tree_weighted_mean
    avg = tree_weighted_mean(client_params, weights)
    acc_avg = evaluate(net, avg, test.x, test.y)
    fused, info = feddf_fuse_homogeneous(
        net, client_params, weights, src,
        FusionConfig(max_steps=600, patience=300, eval_every=50,
                     batch_size=64), val.x, val.y)
    acc_fused = evaluate(net, fused, test.x, test.y)
    acc_ens = ensemble_accuracy([(net, client_params)], test.x, test.y)
    # under alpha=0.1 non-iid, distillation must recover a chunk of the
    # ensemble-vs-average gap
    assert acc_fused >= acc_avg - 0.02
    assert acc_ens >= acc_avg - 0.02
    assert info["steps"] > 0


def test_dropworst_filters_dummy(setup):
    net, train, val, test, parts, src = setup
    _, client_params, weights = _train_clients(net, train, parts)
    # inject a destroyed model (random predictor)
    bad = jax.tree.map(lambda x: jnp.zeros_like(x), client_params[0])
    plist = client_params + [bad]
    wlist = weights + [999.0]
    kept_p, kept_w, kept_i = drop_worst(net, plist, wlist, val.x, val.y, 3)
    assert len(plist) - 1 not in kept_i  # the dummy was dropped
    assert len(kept_p) >= 1


def test_fedavgm_momentum_update():
    """dv = beta*v + dx; x = x - dv reduces to fedavg at beta=0."""
    ds = gaussian_mixture(800, n_classes=3, dim=2, seed=1)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 4, 1.0, seed=0)
    net = mlp(2, 3, hidden=(16,))
    common = dict(rounds=2, client_fraction=1.0, local_epochs=4,
                  local_batch_size=32, local_lr=0.05, seed=0)
    r_avg = run_federated(net, train, parts, val, test,
                          FLConfig(strategy="fedavg", **common))
    r_m0 = run_federated(net, train, parts, val, test,
                         FLConfig(strategy="fedavgm", server_momentum=0.0,
                                  **common))
    for a, b in zip(jax.tree.leaves(r_avg.global_params),
                    jax.tree.leaves(r_m0.global_params)):
        assert jnp.allclose(a, b, atol=1e-5)


def test_fedprox_pulls_towards_anchor():
    ds = gaussian_mixture(600, n_classes=3, dim=2, seed=2)
    net = mlp(2, 3, hidden=(16,))
    g = net.init(jax.random.PRNGKey(0))
    xb, yb = build_batches(ds.x, ds.y, 32, 5, seed=0)
    free = make_local_update(net, sgd(0.1), prox_mu=0.0)(
        g, jnp.asarray(xb), jnp.asarray(yb), g)
    prox = make_local_update(net, sgd(0.1), prox_mu=10.0)(
        g, jnp.asarray(xb), jnp.asarray(yb), g)
    from repro.common.pytree import tree_sq_dist
    assert float(tree_sq_dist(prox, g)) < float(tree_sq_dist(free, g))


def test_rounds_to_target_tracking():
    ds = gaussian_mixture(1500, n_classes=3, dim=2, seed=3)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 4, 100.0, seed=0)
    net = mlp(2, 3, hidden=(24,))
    res = run_federated(net, train, parts, val, test,
                        FLConfig(strategy="fedavg", rounds=8,
                                 client_fraction=1.0, local_epochs=8,
                                 local_batch_size=32, local_lr=0.1,
                                 target_accuracy=0.70, seed=0))
    if res.rounds_to_target is not None:
        assert res.logs[-1].test_acc >= 0.70
        assert res.rounds_to_target == len(res.logs)

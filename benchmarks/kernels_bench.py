"""Microbenchmarks of the Pallas kernels vs their jnp references.

NOTE: on this CPU container the kernels run in INTERPRET mode (a Python
loop over grid cells) — wall time here is a correctness-path benchmark,
not TPU performance; the TPU roofline story lives in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ref
from repro.kernels.ensemble_kl import ensemble_kl
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.swa_attn import swa_attn_pallas


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    results = {}

    # ensemble_kl: FedDF loss at K=8 teachers, 16k vocab
    k1, k2 = jax.random.split(key)
    s = jax.random.normal(k1, (16, 16384))
    t = jax.random.normal(k2, (8, 16, 16384))
    jr = jax.jit(lambda a, b: ref.ensemble_kl(a, b, 1.0))
    tk = _time(lambda a, b: ensemble_kl(a, b, 1.0, 8, True), s, t)
    tr = _time(jr, s, t)
    err = abs(float(ensemble_kl(s, t, 1.0) - ref.ensemble_kl(s, t, 1.0)))
    emit("kernel_ensemble_kl_interp", tk, f"ref_jit={tr*1e6:.0f}us,err={err:.1e}",
         {"kernel_s": tk, "ref_s": tr, "err": err})
    results["ensemble_kl"] = {"kernel_s": tk, "ref_s": tr, "err": err}

    # ssd_scan
    ks = jax.random.split(key, 5)
    b, ss, h, p, n = 1, 256, 4, 32, 16
    x = jax.random.normal(ks[0], (b, ss, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, ss, h))) * 0.1
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, ss, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, ss, n)) * 0.5
    jrs = jax.jit(lambda *a: ref.ssd_scan(*a, 64))
    tks = _time(lambda *a: ssd_scan_pallas(*a, chunk=64, block_h=4),
                x, dt, a_log, bm, cm)
    trs = _time(jrs, x, dt, a_log, bm, cm)
    emit("kernel_ssd_scan_interp", tks, f"ref_jit={trs*1e6:.0f}us",
         {"kernel_s": tks, "ref_s": trs})
    results["ssd_scan"] = {"kernel_s": tks, "ref_s": trs}

    # swa_attn
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    kk = jax.random.normal(ks[1], (1, 4, 512, 64))
    v = jax.random.normal(ks[2], (1, 4, 512, 64))
    jra = jax.jit(lambda *a: ref.swa_attn(*a, 128))
    tka = _time(lambda *a: swa_attn_pallas(*a, 128, block=128), q, kk, v)
    tra = _time(jra, q, kk, v)
    emit("kernel_swa_attn_interp", tka, f"ref_jit={tra*1e6:.0f}us",
         {"kernel_s": tka, "ref_s": tra})
    results["swa_attn"] = {"kernel_s": tka, "ref_s": tra}
    return results


if __name__ == "__main__":
    run()

from repro.checkpoint.io import (load_obj, metadata, restore, save,
                                 save_obj)

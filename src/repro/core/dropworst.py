"""Drop-worst filtering (paper §4.2, Table 3): before aggregation, drop
received models whose server-validation accuracy is indistinguishable from
random guessing — stabilises unnormalised architectures (VGG-analogue) under
non-iid local data."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.common.pytree import tree_take
from repro.core.client import evaluate, evaluate_stacked
from repro.core.nets import Net


def drop_worst(net: Net, client_params: List[dict],
               client_weights: Sequence[float], val_x: np.ndarray,
               val_y: np.ndarray, n_classes: int,
               threshold_factor: float = 1.5
               ) -> Tuple[List[dict], List[float], List[int]]:
    """Keep models with val acc > threshold_factor * chance.

    Returns (kept params, kept weights, kept indices).  If everything would
    be dropped, keep the single best model (the server must emit something).
    """
    chance = 1.0 / n_classes
    accs = [evaluate(net, p, val_x, val_y) for p in client_params]
    keep = [i for i, a in enumerate(accs) if a > threshold_factor * chance]
    if not keep:
        keep = [int(np.argmax(accs))]
    return ([client_params[i] for i in keep],
            [client_weights[i] for i in keep], keep)


def drop_worst_stacked(net: Net, stack, client_weights: Sequence[float],
                       val_x: np.ndarray, val_y: np.ndarray, n_classes: int,
                       threshold_factor: float = 1.5):
    """Drop-worst on a stacked [K, ...] client pytree: all K validation
    accuracies come from ONE vmapped forward; survivors are gathered along
    the client axis.  Returns (kept stack, kept weights, kept indices)."""
    chance = 1.0 / n_classes
    accs = evaluate_stacked(net, stack, val_x, val_y)
    keep = [i for i, a in enumerate(accs) if a > threshold_factor * chance]
    if not keep:
        keep = [int(np.argmax(accs))]
    return (tree_take(stack, np.asarray(keep)),
            [client_weights[i] for i in keep], keep)

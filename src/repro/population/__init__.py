"""Population subsystem: traffic-driven cohorts over the round engine.

Sits between the spec layer and the drivers (docs/population.md):

- :mod:`repro.population.registry`  — struct-of-arrays client state
- :mod:`repro.population.traffic`   — counter-based arrival/latency model
- :mod:`repro.population.scheduler` — cohort sampler registry
  (uniform / capacity_aware / prioritized sum-tree)
- :mod:`repro.population.manager`   — upload buffer + virtual clock
  backing the ``buffered_async`` driver
- :mod:`repro.population.faults`    — counter-based fault injection +
  upload screening (docs/robustness.md)
"""
from repro.population.config import (FaultConfig, PopulationConfig,
                                     TrafficConfig)
from repro.population.faults import FaultModel, NormScreen
from repro.population.manager import PopulationManager, Upload
from repro.population.registry import ClientRegistry
from repro.population.scheduler import (CohortSampler, SamplerContext,
                                        available_samplers, get_sampler,
                                        make_sampler, register_sampler)
from repro.population.sumtree import SumTree
from repro.population.traffic import TrafficModel

__all__ = [
    "FaultConfig", "PopulationConfig", "TrafficConfig", "PopulationManager",
    "Upload", "ClientRegistry", "CohortSampler", "SamplerContext",
    "available_samplers", "get_sampler", "make_sampler", "register_sampler",
    "SumTree", "TrafficModel", "FaultModel", "NormScreen",
]

"""Step-count bucketing + padded-group mesh sharding (docs/bucketing.md).

 1. Bucket capacity construction: ascending, bounded by ``max_buckets``,
    last capacity exactly the group maximum, every client fits.
 2. Trajectory equivalence on a SKEWED Dirichlet alpha=0.1 split:
    bucketed (pow2 / quantile) round logs and globals are bit-identical
    to the unbucketed path, homogeneous AND heterogeneous — bucketing
    only regroups the vmap axis.
 3. Compile count: ``CLIENT_COMPILES`` (a trace-time counter) stays
    <= buckets x prototypes for a whole run.
 4. Mesh divisibility padding: heterogeneous cohorts now ACCEPT a client
    mesh — per-bucket client capacities pad up to the mesh axis, padded
    lanes carry all-False step masks and are sliced off — and per-round
    results equal the unsharded run on a 4-device simulated mesh
    (subprocess with forced host devices).
 5. ``BucketSpec`` round-trips as JSON, validates kind / max_buckets,
    and threads through ``Experiment`` / ``to_fl_config``.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import BucketSpec, Experiment, ExperimentSpec
from repro.core import BucketConfig, FLConfig, FusionConfig, mlp, run_rounds
from repro.core.client import (CLIENT_COMPILES, assign_buckets,
                               bucket_capacities, build_bucketed_batches,
                               build_batched_batches)
from repro.core.engine import RoundEngine
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

K = 16
ALPHA = 0.1


@pytest.fixture(scope="module")
def skewed():
    """Dirichlet alpha=0.1 over K=16 clients: the largest client has tens
    of times the local steps of the median (the padded-scan waste case)."""
    ds = gaussian_mixture(3000, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, K, ALPHA, seed=0)
    sizes = sorted(len(p) for p in parts)
    assert sizes[-1] >= 5 * sizes[K // 2]  # really skewed
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


def cfg_for(bucketing, strategy="fedavg", rounds=2, **kw):
    base = dict(client_fraction=0.5, local_epochs=3, local_batch_size=32,
                local_lr=0.05, seed=0,
                fusion=FusionConfig(max_steps=50, patience=50,
                                    eval_every=25, batch_size=32))
    base.update(kw)
    return FLConfig(strategy=strategy, rounds=rounds, bucketing=bucketing,
                    **base)


def _assert_same_run(a, b):
    res_a, glob_a, rtt_a = a
    res_b, glob_b, rtt_b = b
    assert rtt_a == rtt_b
    for ra, rb in zip(res_a, res_b):
        assert ra.logs == rb.logs
    for ga, gb in zip(glob_a, glob_b):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# capacity construction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["pow2", "quantile"])
def test_bucket_capacities_properties(kind):
    rng = np.random.default_rng(0)
    for _ in range(20):
        steps = rng.integers(1, 500, size=rng.integers(1, 40)).tolist()
        for m in (1, 2, 4, 8):
            caps = bucket_capacities(steps, kind, m)
            assert caps == sorted(caps)           # ascending
            assert len(caps) == len(set(caps))    # unique
            assert len(caps) <= m                 # bounded
            assert caps[-1] == max(steps)         # exact max: no extra pad
            which = assign_buckets(steps, caps)
            for s, b in zip(steps, which):
                assert s <= caps[b]               # every client fits
                if b > 0:
                    assert s > caps[b - 1]        # ...in its SMALLEST bucket


def test_bucket_capacities_none_and_degenerate():
    assert bucket_capacities([7, 7, 7], "pow2", 4) == [7]
    assert bucket_capacities([3, 9, 30], "none", 4) == [30]
    assert bucket_capacities([], "pow2", 4) == [1]
    with pytest.raises(ValueError, match="bucket kind"):
        bucket_capacities([1, 2], "fib", 4)
    with pytest.raises(ValueError, match="exceed"):
        assign_buckets([10], [4, 8])


def test_build_bucketed_batches_matches_flat():
    """Each client's batch stream is byte-identical to the unbucketed
    stack — only the zero-padded tail is shorter."""
    rng = np.random.default_rng(0)
    sizes = [300, 40, 37, 170]
    x = rng.normal(size=(sum(sizes), 2)).astype(np.float32)
    y = rng.integers(0, 3, size=sum(sizes))
    parts, off = [], 0
    for n in sizes:
        parts.append(np.arange(off, off + n))
        off += n
    seeds = list(range(4))
    from repro.core.client import n_local_steps
    flat_x, flat_y, flat_m = build_batched_batches(x, y, parts, 32, 3,
                                                   seeds=seeds)
    caps = bucket_capacities([n_local_steps(len(p), 32, 3) for p in parts],
                             "pow2", 4)
    seen = set()
    for b, pos, xb, yb, mask in build_bucketed_batches(
            x, y, parts, 32, 3, seeds, caps):
        for row, i in enumerate(pos):
            seen.add(int(i))
            n = int(flat_m[i].sum())
            assert int(mask[row].sum()) == n
            np.testing.assert_array_equal(xb[row, :n], flat_x[i, :n])
            np.testing.assert_array_equal(yb[row, :n], flat_y[i, :n])
            assert not mask[row, n:].any()
    assert seen == set(range(4))


# ---------------------------------------------------------------------------
# trajectory equivalence on the skewed split
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["pow2", "quantile"])
def test_bucketed_matches_unbucketed_homogeneous(skewed, kind):
    train, val, test, parts, src = skewed
    net = mlp(2, 3, hidden=(16,))

    def run(bucketing):
        return run_rounds([net], [0] * K, train, parts, val, test,
                          cfg_for(bucketing))

    _assert_same_run(run(BucketConfig()),
                     run(BucketConfig(kind=kind, max_buckets=4)))


def test_bucketed_matches_unbucketed_heterogeneous(skewed):
    train, val, test, parts, src = skewed
    nets = [mlp(2, 3, hidden=(12,), name="p-s"),
            mlp(2, 3, hidden=(24,), name="p-m")]
    proto = [k % 2 for k in range(K)]

    def run(bucketing):
        return run_rounds(nets, proto, train, parts, val, test,
                          cfg_for(bucketing), heterogeneous=True)

    _assert_same_run(run(BucketConfig()),
                     run(BucketConfig(kind="pow2", max_buckets=4)))


def test_bucketed_matches_unbucketed_feddf(skewed):
    """The distillation strategy consumes re-joined stacks — order and
    values must survive bucketing bit-for-bit through fusion too."""
    train, val, test, parts, src = skewed
    net = mlp(2, 3, hidden=(16,))

    def run(bucketing):
        return run_rounds([net], [0] * K, train, parts, val, test,
                          cfg_for(bucketing, strategy="feddf"), source=src)

    _assert_same_run(run(BucketConfig()),
                     run(BucketConfig(kind="quantile", max_buckets=3)))


def test_bucketing_reduces_padded_slots(skewed):
    """The point of the exercise: fewer padded scan slots per round."""
    train, val, test, parts, src = skewed
    nets = [mlp(2, 3, hidden=(12,), name="p-s"),
            mlp(2, 3, hidden=(24,), name="p-m")]
    proto = [k % 2 for k in range(K)]

    def slots(bucketing):
        engine = RoundEngine(nets, proto, train, parts, val, test,
                             cfg_for(bucketing, client_fraction=1.0),
                             heterogeneous=True)
        batches = engine.build_round_batches(
            1, engine.sample_cohort(engine.make_rng()))
        real = sum(rb.real_steps for rb in batches if rb is not None)
        padded = sum(rb.padded_slots for rb in batches if rb is not None)
        return real, padded

    real_u, padded_u = slots(BucketConfig())
    real_b, padded_b = slots(BucketConfig(kind="pow2", max_buckets=4))
    assert real_u == real_b                       # same true work
    assert padded_b - real_b < (padded_u - real_u) / 2  # >= 2x less waste


def test_bucketing_threads_through_async_driver(skewed):
    """Bucketed batches are prefetched and trained by the async driver
    exactly like the sync driver's (staleness=0 == sync, bucketed)."""
    from repro.drivers import make_driver
    train, val, test, parts, src = skewed
    net = mlp(2, 3, hidden=(16,))
    bucketing = BucketConfig(kind="pow2", max_buckets=4)

    def run(driver):
        return run_rounds([net], [0] * K, train, parts, val, test,
                          cfg_for(bucketing), driver=driver)

    _assert_same_run(run("sync"),
                     run(make_driver("async_pipelined", staleness=0,
                                     prefetch=2)))


# ---------------------------------------------------------------------------
# compile count
# ---------------------------------------------------------------------------

def test_client_compiles_bounded_by_buckets_times_prototypes(skewed):
    train, val, test, parts, src = skewed
    nets = [mlp(2, 3, hidden=(12,), name="p-s"),
            mlp(2, 3, hidden=(24,), name="p-m")]
    proto = [k % 2 for k in range(K)]
    bucketing = BucketConfig(kind="pow2", max_buckets=4)
    engine = RoundEngine(nets, proto, train, parts, val, test,
                         cfg_for(bucketing, rounds=3), heterogeneous=True)
    bound = sum(len(caps) for caps in engine.bucket_caps)
    assert bound <= 4 * len(nets)

    CLIENT_COMPILES.reset()
    run_rounds(nets, proto, train, parts, val, test,
               cfg_for(bucketing, rounds=3), heterogeneous=True)
    assert 0 < CLIENT_COMPILES.count <= bound, CLIENT_COMPILES.count


def test_client_compiles_one_per_prototype_unbucketed(skewed):
    train, val, test, parts, src = skewed
    net = mlp(2, 3, hidden=(16,))
    CLIENT_COMPILES.reset()
    run_rounds([net], [0] * K, train, parts, val, test,
               cfg_for(BucketConfig(), rounds=3))
    assert CLIENT_COMPILES.count == 1, CLIENT_COMPILES.count


# ---------------------------------------------------------------------------
# mesh divisibility padding (forced host devices in a subprocess)
# ---------------------------------------------------------------------------

def test_hetero_and_bucketed_mesh_match_unsharded_on_4_devices():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
import jax
import numpy as np
from repro.core import BucketConfig, FLConfig, mlp, run_rounds
from repro.data import (dirichlet_partition, gaussian_mixture,
                        train_val_test_split)

assert len(jax.devices()) == 4
ds = gaussian_mixture(2000, n_classes=3, dim=2, seed=0)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, 8, 0.1, seed=0)
nets = [mlp(2, 3, hidden=(12,), name="s"), mlp(2, 3, hidden=(24,), name="m"),
        mlp(2, 3, hidden=(32,), name="l")]
proto = [k % 3 for k in range(8)]  # group sizes 3/3/2: none divide 4

def run(driver, kind):
    cfg = FLConfig(strategy="fedavg", rounds=2, client_fraction=1.0,
                   local_epochs=2, local_batch_size=32, local_lr=0.05,
                   seed=0, bucketing=BucketConfig(kind=kind, max_buckets=3))
    return run_rounds(nets, proto, train, parts, val, test, cfg,
                      heterogeneous=True, driver=driver)

for kind in ("none", "pow2"):
    sync = run("sync", kind)
    mh = run("multihost", kind)
    assert all(ra.logs == rb.logs for ra, rb in zip(sync[0], mh[0])), kind
    for ga, gb in zip(sync[1], mh[1]):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("HETERO_MESH_OK")
""".format(src=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.stdout.count("HETERO_MESH_OK") == 1, r.stdout + r.stderr


def test_padded_clients_masked_under_mesh_padding(skewed):
    """A 1-device mesh exercises the same padded-capacity path: capacities
    round up, the padded lanes carry all-False masks, and the output
    equals the meshless run."""
    from repro.launch.mesh import make_client_mesh
    train, val, test, parts, src = skewed
    nets = [mlp(2, 3, hidden=(12,), name="p-s"),
            mlp(2, 3, hidden=(24,), name="p-m")]
    proto = [k % 2 for k in range(K)]
    bucketing = BucketConfig(kind="pow2", max_buckets=3)

    engine = RoundEngine(nets, proto, train, parts, val, test,
                         cfg_for(bucketing), heterogeneous=True,
                         mesh=make_client_mesh(1))
    batches = engine.build_round_batches(
        1, engine.sample_cohort(engine.make_rng()))
    for rb in batches:
        if rb is None:
            continue
        for bb in rb.buckets:
            assert bb.xb.shape[0] == bb.cap_clients
            # every padded lane is fully masked out
            assert not bb.step_mask[bb.k_real:].any()

    base = run_rounds(nets, proto, train, parts, val, test,
                      cfg_for(bucketing), heterogeneous=True)
    sharded = run_rounds(nets, proto, train, parts, val, test,
                         cfg_for(bucketing), heterogeneous=True,
                         mesh=make_client_mesh(1))
    _assert_same_run(base, sharded)


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_bucket_spec_round_trips_and_validates():
    spec = ExperimentSpec(bucket=BucketSpec(kind="pow2", max_buckets=6))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.to_dict()["bucket"] == {"kind": "pow2", "max_buckets": 6}
    # specs predating the bucket axis still load (default: none)
    d = spec.to_dict()
    del d["bucket"]
    assert ExperimentSpec.from_dict(d).bucket == BucketSpec()

    with pytest.raises(ValueError, match="bucket.kind"):
        ExperimentSpec(bucket=BucketSpec(kind="fib")).validate()
    with pytest.raises(ValueError, match="max_buckets"):
        ExperimentSpec(bucket=BucketSpec(max_buckets=0)).validate()


def test_bucket_spec_threads_through_experiment():
    from repro.api import (CohortSpec, ModelSpec, PartitionSpec,
                           StrategySpec, TaskSpec)

    def spec(bucket):
        return ExperimentSpec(
            task=TaskSpec(name="blobs", n_samples=1200),
            partition=PartitionSpec(n_clients=8, alpha=0.1),
            cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                    {"hidden": [16]})]),
            strategy=StrategySpec(name="fedavg"), source=None,
            bucket=bucket, rounds=2, client_fraction=0.5, local_epochs=2,
            local_batch_size=32, local_lr=0.05, seed=0)

    a = Experiment(spec(BucketSpec())).run()
    b = Experiment(spec(BucketSpec(kind="quantile", max_buckets=3))).run()
    assert a.result.logs == b.result.logs
    for x, y in zip(jax.tree.leaves(a.global_params[0]),
                    jax.tree.leaves(b.global_params[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# ensemble_kl: FedDF's AVGLOGITS distillation loss
# ---------------------------------------------------------------------------

def ensemble_kl(student_logits: jax.Array, teacher_logits: jax.Array,
                temperature: float = 1.0) -> jax.Array:
    """KL( softmax(mean_k teachers / T), softmax(student / T) ) * T^2,
    mean over batch rows.  student: [B, V]; teachers: [K, B, V]."""
    t = jnp.mean(teacher_logits.astype(jnp.float32), axis=0) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    logp_t = jax.nn.log_softmax(t, axis=-1)
    logp_s = jax.nn.log_softmax(s, axis=-1)
    kl = jnp.sum(jnp.exp(logp_t) * (logp_t - logp_s), axis=-1)
    return jnp.mean(kl) * temperature ** 2


def ensemble_kl_grad(student_logits: jax.Array, teacher_logits: jax.Array,
                     temperature: float = 1.0) -> jax.Array:
    """d loss / d student_logits = (softmax(s/T) - softmax(t̄/T)) * T / B."""
    b = student_logits.shape[0]
    t = jnp.mean(teacher_logits.astype(jnp.float32), axis=0) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    g = (jax.nn.softmax(s, -1) - jax.nn.softmax(t, -1)) * temperature / b
    return g.astype(student_logits.dtype)


def ensemble_kl_bank(student_logits: jax.Array, bank_rows: jax.Array,
                     row_scale: jax.Array, idx: jax.Array,
                     temperature: float = 1.0) -> jax.Array:
    """Oracle for the fused bank kernel: gather the sampled bank rows,
    dequantize with their per-row scales, then the plain AVGLOGITS KL.
    bank_rows: [N, V] any storage dtype; row_scale/idx: [B]."""
    t = bank_rows[idx].astype(jnp.float32) * row_scale[:, None]
    return ensemble_kl(student_logits, t[None], temperature)


# ---------------------------------------------------------------------------
# ssd_scan: Mamba2 chunked state-space scan (single sequence block)
# ---------------------------------------------------------------------------

def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array, bmat: jax.Array,
             cmat: jax.Array, chunk: int) -> jax.Array:
    """Reference SSD. x:[B,S,H,P] dt:[B,S,H] a_log:[H] b/c:[B,S,N] -> y."""
    from repro.models.ssm import ssd_chunked
    y, _ = ssd_chunked(x, dt, a_log, bmat, cmat, chunk)
    return y


def ssd_scan_sequential(x, dt, a_log, bmat, cmat):
    """Step-by-step recurrence (independent second oracle for the chunked
    algorithm itself)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * a)  # [B,H]
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cmat.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


# ---------------------------------------------------------------------------
# swa_attn: sliding-window (or full causal) flash attention
# ---------------------------------------------------------------------------

def swa_attn(q: jax.Array, k: jax.Array, v: jax.Array,
             window: int | None) -> jax.Array:
    """q/k/v: [B, H, S, D]; causal, optionally limited to |i-j| < window."""
    s = q.shape[2]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if window is not None:
        mask = mask & (i - j < window)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)

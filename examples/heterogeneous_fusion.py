"""Heterogeneous model fusion (paper Algorithm 3 / Figure 4).

Three distinct client prototypes (different widths/depths — the
ResNet-20/32/ShuffleNetV2 analogue).  Parameter averaging can only operate
within a prototype group; FedDF distils the cross-group ensemble into every
prototype, so small models learn from big ones and vice versa.

With the declarative API, heterogeneous FL is just a multi-prototype
cohort — the same ``Experiment.run()`` serves both algorithms.

    PYTHONPATH=src python examples/heterogeneous_fusion.py
"""
import dataclasses

from repro.api import (CohortSpec, Experiment, ExperimentSpec, FusionSpec,
                       ModelSpec, PartitionSpec, SourceSpec, StrategySpec,
                       TaskSpec)

spec = ExperimentSpec(
    task=TaskSpec(name="blobs", n_samples=6000),
    partition=PartitionSpec(n_clients=9, alpha=1.0),
    cohort=CohortSpec(prototypes=[
        ModelSpec("mlp", {"hidden": [32, 32], "name": "proto-small"}),
        ModelSpec("mlp", {"hidden": [64, 64], "name": "proto-medium"}),
        ModelSpec("mlp", {"hidden": [48, 48, 48], "name": "proto-deep"}),
    ]),  # assignment defaults to round_robin: client k -> prototype k % 3
    strategy=StrategySpec(name="feddf",
                          fusion=FusionSpec(max_steps=400, patience=200,
                                            eval_every=50, batch_size=64)),
    source=SourceSpec(name="unlabeled", params={"n": 4000}),
    rounds=6, client_fraction=0.67, local_epochs=20, local_batch_size=32,
    local_lr=0.05, seed=1)

for strategy in ("fedavg", "feddf"):
    s = dataclasses.replace(
        spec, strategy=dataclasses.replace(spec.strategy, name=strategy),
        source=spec.source if strategy == "feddf" else None)
    res = Experiment(s).run()
    print(f"--- {strategy}")
    for name, r in zip(res.net_names, res.results):
        print(f"  {name:13s} best={r.best_acc:.3f} "
              f"ensemble_ub={max(l.ensemble_acc for l in r.logs):.3f}")

"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU
they compile by default.  ``REPRO_PALLAS_COMPILE=1``/``0`` forces either
mode on any backend.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ensemble_kl import ensemble_kl as _ensemble_kl
from repro.kernels.ensemble_kl import ensemble_kl_bank as _ensemble_kl_bank
from repro.kernels.ensemble_kl import ensemble_kl_pre as _ensemble_kl_pre
from repro.kernels.ssd_scan import ssd_scan_pallas as _ssd
from repro.kernels.swa_attn import swa_attn_pallas as _swa


def _interpret() -> bool:
    """Interpret-mode default: compiled on TPU (so ``use_fused_kernel=
    'auto'`` actually lands on the fast kernel), interpret elsewhere.
    ``REPRO_PALLAS_COMPILE=1``/``0`` overrides either way."""
    env = os.environ.get("REPRO_PALLAS_COMPILE")
    if env is not None:
        return env != "1"
    return jax.default_backend() != "tpu"


def use_pallas(flag) -> bool:
    """Resolve a ``use_fused_kernel`` setting.  ``'auto'`` selects the
    Pallas kernels on TPU and the plain-jnp reference path elsewhere
    (interpret mode exists for testing, not speed); booleans are taken
    literally; any other string is a loud error (``bool("off")`` would
    silently enable the kernel)."""
    from repro.common.options import FUSED_KERNEL_MODES
    if flag == "auto":
        return jax.default_backend() == "tpu"
    if not isinstance(flag, bool):
        raise ValueError(f"use_fused_kernel must be one of "
                         f"{FUSED_KERNEL_MODES}, got {flag!r}")
    return flag


def ensemble_kl_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                     temperature: float = 1.0) -> jax.Array:
    """FedDF AVGLOGITS loss. student: [..., V]; teachers: [K, ..., V].

    Leading dims are flattened into rows; differentiable w.r.t. the student
    logits via the fused backward kernel.
    """
    v = student_logits.shape[-1]
    k = teacher_logits.shape[0]
    s2 = student_logits.reshape(-1, v)
    t2 = teacher_logits.reshape(k, -1, v)
    return _ensemble_kl(s2, t2, temperature, 8, _interpret())


def ensemble_kl_loss_pre(student_logits: jax.Array,
                         teacher_avg_logits: jax.Array,
                         temperature: float = 1.0) -> jax.Array:
    """AVGLOGITS loss against PRE-AVERAGED teacher rows (the logit-bank
    fast path).  student: [..., V]; teacher_avg: [..., V] — e.g. bank rows
    gathered by sampled index; no [K, ..., V] tensor is materialized."""
    v = student_logits.shape[-1]
    s2 = student_logits.reshape(-1, v)
    t2 = teacher_avg_logits.reshape(-1, v)
    return _ensemble_kl_pre(s2, t2, temperature, 8, _interpret())


def ensemble_kl_loss_bank(student_logits: jax.Array, bank_rows: jax.Array,
                          scales, idx: jax.Array,
                          temperature: float = 1.0) -> jax.Array:
    """AVGLOGITS loss fused with the bank gather + dequantize.

    student: [..., V]; bank_rows: [N, V] in the bank's storage dtype
    (fp32 / bf16 / int8 / fp8); scales: per-ROW [N] fp32 dequant scales
    or None for unquantized banks; idx: [...] sampled bank indices.
    Dispatches exactly like :func:`ensemble_kl_loss_pre` (compiled on
    TPU, interpret elsewhere, ``REPRO_PALLAS_COMPILE`` override) — only
    the [B]-sized per-sample scale gather happens outside the kernel.
    """
    v = student_logits.shape[-1]
    s2 = student_logits.reshape(-1, v)
    idx2 = idx.reshape(-1)
    row_scale = (jnp.ones(idx2.shape, jnp.float32) if scales is None
                 else scales[idx2].astype(jnp.float32))
    return _ensemble_kl_bank(s2, bank_rows, row_scale, idx2, temperature,
                             _interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, bmat, cmat, chunk: int = 128):
    """Mamba2 SSD scan: x [B,S,H,P], dt [B,S,H], a_log [H], b/c [B,S,N]."""
    return _ssd(x, dt, a_log, bmat, cmat, chunk=chunk,
                interpret=_interpret())


@partial(jax.jit, static_argnames=("window", "block"))
def swa_attention(q, k, v, window: int | None = None, block: int = 128):
    """Flash sliding-window attention: q/k/v [B,H,S,D]."""
    return _swa(q, k, v, window, block=block, interpret=_interpret())

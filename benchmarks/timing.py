"""Shared timing/marginal-measure helpers for the engine benchmarks.

``driver_bench`` and ``round_engine_bench`` historically carried two
divergent copies of the same two idioms; they live here now:

* :func:`time_rounds` — steady-state per-call wall clock: one warm-up
  call absorbs the jit compile, then the mean over ``rounds`` repeats.
* :func:`min_wall` / :func:`marginal_rate` — the distill_bench idiom for
  whole-run measurements: wall-clock a SHORT and a LONG run of the same
  config (min over ``reps`` each, so a GC pause or noisy neighbour can't
  corrupt one side) and report the marginal units/second between them —
  the identical per-run compile cost appears in both lengths and cancels
  in the difference, leaving the steady-state throughput.
* :func:`finish_bench` — the one shared OUTPUT path: every
  ``*_bench.py`` hands its record here, which (a) keeps writing the
  bench's historic ``BENCH_*.json`` byte-compatible file and (b)
  appends one schema'd, machine/config-fingerprinted record to
  ``BENCH_history.jsonl`` (``repro.obs.history``) — the perf-history
  contract ``benchmarks/check_history.py`` gates in CI.  Benches no
  longer hand-roll their output dicts' plumbing.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional, Tuple


def _jsonable(o):
    """numpy scalars etc. -> JSON natives (mirrors benchmarks.common)."""
    import numpy as np
    if isinstance(o, (np.bool_,)):
        return bool(o)
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    return str(o)


def finish_bench(bench: str, metrics: dict, *, config: Optional[dict] = None,
                 case: str = "default", out: Optional[str] = None,
                 history_path: Optional[str] = None) -> dict:
    """Emit one bench result through the shared record path.

    Writes ``metrics`` verbatim to the legacy ``out`` JSON file (same
    bytes the bench always produced — committed artifacts and downstream
    readers keep working), then validates + appends the canonical
    history record to ``BENCH_history.jsonl`` (env
    ``BENCH_HISTORY_OUT``, or ``history_path``).  Returns the record.
    """
    from repro.obs import history
    metrics = json.loads(json.dumps(metrics, default=_jsonable))
    if out:
        with open(out, "w") as f:
            json.dump(metrics, f, indent=2)
    cfg = {"full": bool(os.environ.get("REPRO_BENCH_FULL"))}
    cfg.update(json.loads(json.dumps(config or {}, default=_jsonable)))
    rec = history.make_record(bench, metrics, config=cfg, case=case)
    history.append(rec, path=history_path)
    return rec


def time_rounds(fn: Callable[[], None], rounds: int) -> float:
    """Mean seconds per ``fn()`` call over ``rounds`` calls, after one
    un-timed warm-up call (the compile)."""
    fn()  # warm-up: compile
    t0 = time.time()
    for _ in range(rounds):
        fn()
    return (time.time() - t0) / rounds


def min_wall(fn: Callable[[], object], reps: int = 2
             ) -> Tuple[float, object]:
    """``(best wall seconds, result of the best rep)`` over ``reps`` runs."""
    best, result = None, None
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        wall = time.time() - t0
        if best is None or wall < best:
            best, result = wall, out
    return best, result


def marginal_rate(make_run: Callable[[int], object], n_short: int,
                  n_long: int, reps: int = 2) -> Tuple[Dict, object]:
    """Marginal units/second between a short and a long run.

    ``make_run(n)`` executes a fresh ``n``-unit run (fresh engine, fresh
    jits) and returns its result.  Returns ``(stats, long-run result)``
    where stats carries ``wall_short_s`` / ``wall_long_s`` / ``per_s``.
    """
    t_s, _ = min_wall(lambda: make_run(n_short), reps)
    t_l, result = min_wall(lambda: make_run(n_long), reps)
    return {"wall_short_s": t_s, "wall_long_s": t_l,
            "per_s": (n_long - n_short) / max(t_l - t_s, 1e-3)}, result

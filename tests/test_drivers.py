"""Round-driver subsystem (docs/drivers.md).

 1. The ``sync`` driver IS the historic loop: trajectories through
    ``run_rounds``/``Experiment.run`` are bit-identical to the legacy
    entry points, and ``async_pipelined`` with ``staleness=0`` matches
    them exactly too (pinning sync == async(0) == legacy).
 2. ``async_pipelined`` with ``staleness=1`` overlaps round t's fusion
    with round t+1's training; killed mid-pipeline and resumed, the
    trajectory equals an uninterrupted async run (the checkpoint carries
    the stale training base).
 3. ``DriverSpec`` round-trips as JSON and validates kind / staleness /
    prefetch against the driver registry.
 4. Early stopping: ``target_accuracy`` now stops HETEROGENEOUS runs
    too, and any observer can stop a run via
    ``RoundEvent.request_stop``.
 5. The jitted FedDF chunk is cached ACROSS rounds — the compile counter
    shows one trace for a whole multi-round run.
 6. The ``multihost`` driver reproduces sync trajectories on a 4-way
    simulated host mesh, and ``drive_fed_rounds`` actually drives the
    production ``make_fed_round_step`` loop (subprocesses with forced
    host devices).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import (CohortSpec, DriverSpec, Experiment, ExperimentSpec,
                       FusionSpec, ModelSpec, PartitionSpec, SourceSpec,
                       StrategySpec, TaskSpec)
from repro.core import (FLConfig, FusionConfig, mlp, run_federated,
                        run_rounds)
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)
from repro.drivers import (AsyncPipelinedDriver, Driver, MultiHostDriver,
                           SyncDriver, available_drivers, get_driver,
                           make_driver, resolve_driver, unwrap_state,
                           wrap_state)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (500, 2)).astype(np.float32))
    return train, val, test, parts, src


def small_cfg(strategy="feddf", rounds=2, **kw):
    return FLConfig(strategy=strategy, rounds=rounds, client_fraction=0.5,
                    local_epochs=3, local_batch_size=32, local_lr=0.05,
                    seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32), **kw)


def _assert_same_run(a, b):
    """(results, globals, rtt) triples must match bit-for-bit."""
    res_a, glob_a, rtt_a = a
    res_b, glob_b, rtt_b = b
    assert rtt_a == rtt_b
    assert len(res_a) == len(res_b)
    for ra, rb in zip(res_a, res_b):
        assert ra.logs == rb.logs
    for ga, gb in zip(glob_a, glob_b):
        for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_builtins():
    assert {"sync", "async_pipelined", "multihost"} <= \
        set(available_drivers())
    assert get_driver("sync") is SyncDriver
    assert isinstance(make_driver("async_pipelined", staleness=1),
                      AsyncPipelinedDriver)
    with pytest.raises(ValueError, match="unknown driver"):
        get_driver("no-such-driver")


def test_resolve_driver():
    assert isinstance(resolve_driver(None), SyncDriver)
    assert isinstance(resolve_driver("multihost"), MultiHostDriver)
    drv = AsyncPipelinedDriver(staleness=1)
    assert resolve_driver(drv) is drv
    with pytest.raises(TypeError, match="driver must be"):
        resolve_driver(42)


def test_driver_knob_validation():
    with pytest.raises(ValueError, match="staleness"):
        AsyncPipelinedDriver(staleness=-1)
    # bounded staleness is a ring now: any S >= 0 constructs
    assert AsyncPipelinedDriver(staleness=3).staleness == 3
    with pytest.raises(ValueError, match="prefetch"):
        SyncDriver(prefetch=-1)
    # sync-semantics drivers refuse a staleness they would silently
    # ignore (mirrors DriverSpec validation)
    with pytest.raises(ValueError, match="async_pipelined"):
        SyncDriver(staleness=1)
    with pytest.raises(ValueError, match="async_pipelined"):
        MultiHostDriver(staleness=1)


def test_wrap_unwrap_state_round_trip():
    st, prev = unwrap_state(wrap_state([1, 2], {"w": 3}))
    assert st == [1, 2] and prev == {"w": 3}
    assert unwrap_state("plain") == ("plain", None)
    assert unwrap_state({"strategy_state": 1}) == ({"strategy_state": 1},
                                                   None)


# ---------------------------------------------------------------------------
# trajectory pinning: sync == async(staleness=0) == legacy
# ---------------------------------------------------------------------------

def test_sync_and_async0_match_legacy(problem):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg()

    legacy = run_federated(net, train, parts, val, test, cfg, source=src)

    def run(driver):
        return run_rounds([net], [0] * len(parts), train, parts, val, test,
                          cfg, source=src, driver=driver)

    sync = run("sync")
    async0 = run(make_driver("async_pipelined", staleness=0, prefetch=2))
    _assert_same_run(sync, async0)
    assert sync[0][0].logs == legacy.logs
    for x, y in zip(jax.tree.leaves(sync[1][0]),
                    jax.tree.leaves(legacy.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async0_matches_sync_heterogeneous(problem):
    train, val, test, parts, src = problem
    nets = [mlp(2, 3, hidden=(12,), name="p-s"),
            mlp(2, 3, hidden=(24,), name="p-m")]
    proto = [k % 2 for k in range(len(parts))]
    cfg = small_cfg()

    def run(driver):
        return run_rounds(nets, proto, train, parts, val, test, cfg,
                          source=src, heterogeneous=True, driver=driver)

    _assert_same_run(run("sync"),
                     run(make_driver("async_pipelined", staleness=0)))


def test_async_staleness1_completes_all_rounds(problem):
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(rounds=3)
    results, globals_, rtt = run_rounds(
        [net], [0] * len(parts), train, parts, val, test, cfg, source=src,
        driver=make_driver("async_pipelined", staleness=1, prefetch=2))
    assert [l.round for l in results[0].logs] == [1, 2, 3]
    assert rtt is None
    assert results[0].final_acc > 1.0 / 3  # above chance despite staleness


# ---------------------------------------------------------------------------
# DriverSpec: serialization + validation + Experiment wiring
# ---------------------------------------------------------------------------

def api_spec(driver=None, strategy="fedavgm", rounds=2, **kw):
    return ExperimentSpec(
        task=TaskSpec(name="blobs", n_samples=1200),
        partition=PartitionSpec(n_clients=6, alpha=1.0),
        cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                {"hidden": [16, 16]})]),
        strategy=StrategySpec(name=strategy,
                              fusion=FusionSpec(max_steps=50, patience=50,
                                                eval_every=25,
                                                batch_size=32)),
        source=(SourceSpec(name="unlabeled", params={"n": 500})
                if strategy == "feddf" else None),
        driver=driver if driver is not None else DriverSpec(),
        rounds=rounds, client_fraction=0.5, local_epochs=3,
        local_batch_size=32, local_lr=0.05, seed=0, **kw)


def test_driver_spec_round_trips():
    spec = api_spec(DriverSpec(kind="async_pipelined", staleness=1,
                               prefetch=3))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.to_dict()["driver"] == {"kind": "async_pipelined",
                                        "staleness": 1, "prefetch": 3}
    # specs predating the driver axis still load (default: sync)
    d = spec.to_dict()
    del d["driver"]
    assert ExperimentSpec.from_dict(d).driver == DriverSpec()


@pytest.mark.parametrize("driver,match", [
    (DriverSpec(kind="no-such-driver"), "unknown driver"),
    (DriverSpec(kind="async_pipelined", staleness=-1), "staleness"),
    (DriverSpec(kind="buffered_async", staleness=2), "buffered_async"),
    (DriverSpec(kind="sync", staleness=1), "only applies"),
    (DriverSpec(kind="async_pipelined", prefetch=-1), "prefetch"),
])
def test_driver_spec_validation(driver, match):
    with pytest.raises(ValueError, match=match):
        api_spec(driver).validate()


def test_experiment_async0_matches_sync_exactly():
    sync = Experiment(api_spec(strategy="feddf")).run()
    async0 = Experiment(api_spec(
        DriverSpec(kind="async_pipelined", staleness=0, prefetch=2),
        strategy="feddf")).run()
    assert async0.result.logs == sync.result.logs
    for a, b in zip(jax.tree.leaves(async0.global_params[0]),
                    jax.tree.leaves(sync.global_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# async resume: kill mid-pipeline, resume, trajectory equality
# ---------------------------------------------------------------------------

class _StopAfter(Exception):
    pass


@pytest.mark.parametrize("strategy,staleness", [("fedavgm", 1),
                                                ("feddf", 1),
                                                ("feddf", 0)])
def test_async_resume_matches_uninterrupted(tmp_path, strategy, staleness):
    """Kill an async-pipelined checkpointed run mid-pipeline (round t+1's
    training already dispatched when round t's hook fires); the resumed
    run must reproduce the uninterrupted async trajectory exactly — the
    staleness=1 checkpoint carries the stale base the in-flight round
    trained from."""
    spec = api_spec(DriverSpec(kind="async_pipelined", staleness=staleness,
                               prefetch=2),
                    strategy=strategy, rounds=5)
    baseline = Experiment(spec).run()
    assert [l.round for l in baseline.result.logs] == [1, 2, 3, 4, 5]

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    ckpt_dir = str(tmp_path / f"run-{strategy}-{staleness}")
    with pytest.raises(_StopAfter):
        Experiment(spec).run(observers=[bomb], checkpoint_dir=ckpt_dir)
    assert os.path.isdir(os.path.join(ckpt_dir, "rounds", "00002"))

    resumed = Experiment.resume(ckpt_dir)
    assert resumed.result.logs == baseline.result.logs
    for a, b in zip(jax.tree.leaves(resumed.global_params[0]),
                    jax.tree.leaves(baseline.global_params[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# early stopping: heterogeneous target_accuracy + observer request_stop
# ---------------------------------------------------------------------------

def test_heterogeneous_target_accuracy_stops_early():
    spec = dataclasses.replace(
        api_spec(strategy="fedavg", rounds=6),
        cohort=CohortSpec(prototypes=[
            ModelSpec("mlp", {"hidden": [12], "name": "p-s"}),
            ModelSpec("mlp", {"hidden": [24], "name": "p-m"})]),
        target_accuracy=0.34)  # just above chance: reached immediately
    res = Experiment(spec).run()
    assert res.heterogeneous
    assert res.rounds_to_target is not None
    assert res.rounds_to_target < 6
    for r in res.results:  # the run really stopped, all groups truncated
        assert len(r.logs) == res.rounds_to_target
    assert max(l.test_acc for l in
               [r.logs[-1] for r in res.results]) >= 0.34


def test_observer_request_stop_ends_run():
    events = []

    def stopper(event):
        events.append(event.round)
        if event.round == 2:
            event.request_stop()

    res = Experiment(api_spec(strategy="fedavg", rounds=5)).run(
        observers=[stopper])
    assert [l.round for l in res.result.logs] == [1, 2]
    # observer stops are soft: no rounds-to-target claim
    assert res.rounds_to_target is None


def test_observer_request_stop_under_async(problem):
    spec = api_spec(DriverSpec(kind="async_pipelined", staleness=1),
                    strategy="fedavg", rounds=5)

    def stopper(event):
        if event.round == 2:
            event.request_stop()

    res = Experiment(spec).run(observers=[stopper])
    assert [l.round for l in res.result.logs] == [1, 2]


# ---------------------------------------------------------------------------
# cross-round compiled-chunk reuse (the recompile-per-round fix)
# ---------------------------------------------------------------------------

def test_feddf_chunk_compiles_once_across_rounds(problem):
    from repro.core.feddf import CHUNK_COMPILES
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    CHUNK_COMPILES.reset()
    run_federated(net, train, parts, val, test, small_cfg(rounds=3),
                  source=src)
    # one trace for the whole run: rounds 2..3 reuse round 1's program
    assert CHUNK_COMPILES.count == 1, CHUNK_COMPILES.count


def test_feddf_chunk_cache_shared_across_drivers(problem):
    """The async driver's fusion thread must reuse the same compiled
    chunk the sync path built (same net/source/fusion config)."""
    from repro.core.feddf import CHUNK_COMPILES
    train, val, test, parts, src = problem
    net = mlp(2, 3, hidden=(16, 16))
    cfg = small_cfg(rounds=2)
    run_rounds([net], [0] * len(parts), train, parts, val, test, cfg,
               source=src, driver="sync")
    CHUNK_COMPILES.reset()
    run_rounds([net], [0] * len(parts), train, parts, val, test, cfg,
               source=src,
               driver=make_driver("async_pipelined", staleness=1))
    assert CHUNK_COMPILES.count == 0, CHUNK_COMPILES.count


# ---------------------------------------------------------------------------
# multihost driver (forced host devices in subprocesses)
# ---------------------------------------------------------------------------

def test_multihost_driver_matches_sync_on_4_device_mesh():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
import numpy as np
import jax
from repro.core import FLConfig, mlp, run_rounds
from repro.data import (dirichlet_partition, gaussian_mixture,
                        train_val_test_split)

assert len(jax.devices()) == 4
ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, 8, 1.0, seed=0)
cfg = FLConfig(strategy="fedavg", rounds=2, client_fraction=0.5,
               local_epochs=2, local_batch_size=32, local_lr=0.05, seed=0)
net = mlp(2, 3, hidden=(16,))
sync, _, _ = run_rounds([net], [0] * 8, train, parts, val, test, cfg,
                        driver="sync")
mh, _, _ = run_rounds([net], [0] * 8, train, parts, val, test, cfg,
                      driver="multihost")
assert [l.test_acc for l in mh[0].logs] == \\
    [l.test_acc for l in sync[0].logs], (mh[0].logs, sync[0].logs)
# indivisible cohorts fail loudly, not deep inside shard_map
cfg_bad = FLConfig(strategy="fedavg", rounds=1, client_fraction=0.375,
                   local_epochs=1, seed=0)  # 3 active on 4 devices
try:
    run_rounds([net], [0] * 8, train, parts, val, test, cfg_bad,
               driver="multihost")
except ValueError as e:
    assert "do not divide" in str(e), e
else:
    raise AssertionError("expected divisibility ValueError")
print("MULTIHOST_DRIVER_OK")
""".format(src=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.stdout.count("MULTIHOST_DRIVER_OK") == 1, r.stdout + r.stderr


def test_drive_fed_rounds_production_loop():
    """make_fed_round_step finally has a driver: compile once, push the
    global to the stacked client axis, local-SGD on the mesh, FedAvg the
    uploads — two real rounds on a 4-device simulated host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.configs.qwen3_8b import CONFIG
from repro.drivers import drive_fed_rounds
from repro.launch.mesh import make_host_mesh
cfg = dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=128, vocab_size=256,
                          head_dim=16)
mesh = make_host_mesh(2, 2)
params, stats = drive_fed_rounds(cfg, mesh, rounds=2, n_clients=4,
                                 local_steps=2, batch_size=2, seq_len=16)
assert [s["round"] for s in stats] == [1, 2], stats
assert all(np.isfinite(s["update_norm"]) and s["update_norm"] > 0
           for s in stats), stats
print("FED_ROUND_DRIVER_OK")
""".format(src=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.stdout.count("FED_ROUND_DRIVER_OK") == 1, r.stdout + r.stderr

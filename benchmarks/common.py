"""Shared scaffolding for the paper-table benchmarks.

Each benchmark reproduces one table/figure of Lin et al. 2020 at CPU scale
(synthetic data, small nets — see DESIGN.md "changed assumptions") and emits
(a) CSV lines ``name,us_per_call,derived`` on stdout and (b) a JSON record
under experiments/paper/ plus one schema'd ``BENCH_history.jsonl`` record
(``bench="paper"``, ``case=<table name>`` — via
``benchmarks.timing.finish_bench``, same path the perf benches use).

Scale knob: REPRO_BENCH_FULL=1 doubles rounds/samples for tighter numbers.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional

import numpy as np

from repro.api import get_source, get_task
from repro.core import FLConfig, FusionConfig, mlp, run_federated
from repro.data import dirichlet_partition, train_val_test_split

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "paper")


def scale(fast: int, full: int) -> int:
    return full if FULL else fast


def default_problem(seed=0, n=4000, alpha=1.0, n_clients=10, n_classes=3):
    """The benchmarks' shared problem, built through the experiment API's
    task/source registries (``repro/api/registries.py``)."""
    bundle = get_task("blobs")(n_samples=n, seed=seed, n_classes=n_classes)
    train, val, test = train_val_test_split(bundle.dataset, seed=seed)
    parts = dirichlet_partition(train.y, n_clients, alpha, seed=seed)
    src = get_source("unlabeled")(bundle, train, seed=seed, n=3000)
    return train, val, test, parts, src


def fusion_cfg(steps=400) -> FusionConfig:
    return FusionConfig(max_steps=steps, patience=max(steps // 3, 100),
                        eval_every=50, batch_size=64)


def fl_cfg(strategy: str, rounds: int, **kw) -> FLConfig:
    """Engine-level config (what an ``ExperimentSpec`` compiles into via
    ``repro.api.to_fl_config``); benchmarks stay at this level because
    they sweep callables (``quantize=``) and prebuilt ``FusionConfig``s."""
    base = dict(rounds=rounds, client_fraction=0.4, local_epochs=20,
                local_batch_size=32, local_lr=0.05, seed=0,
                fusion=fusion_cfg())
    base.update(kw)
    return FLConfig(strategy=strategy, **base)


def emit(name: str, seconds: float, derived: str, record: Optional[Dict] = None):
    print(f"{name},{seconds * 1e6:.0f},{derived}")
    if record is not None:
        from benchmarks.timing import finish_bench
        os.makedirs(OUT_DIR, exist_ok=True)
        # same legacy per-table JSON under experiments/paper/, plus one
        # schema'd record in BENCH_history.jsonl (bench="paper",
        # case=<table name>) so check_history.py gates the paper tables
        # alongside the perf benches
        finish_bench("paper",
                     {"name": name, "wall_s": seconds, "derived": derived,
                      **record},
                     case=name,
                     out=os.path.join(OUT_DIR, f"{name}.json"))


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, time.time() - t0

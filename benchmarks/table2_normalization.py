"""Table 2: the BatchNorm non-iid 'quagmire' — FedAvg+BN degrades under
non-iid data; GN alleviates it; FedDF+BN beats both without touching the
architecture."""
from __future__ import annotations

import time

from benchmarks.common import default_problem, emit, fl_cfg, scale
from repro.core import mlp, run_federated


def run(seed: int = 0) -> dict:
    rounds = scale(6, 15)
    results = {}
    t0 = time.time()
    for alpha in (1.0, 0.1):
        train, val, test, parts, src = default_problem(seed=seed, alpha=alpha,
                                                       n=4000)
        cases = {
            "fedavg_bn": ("fedavg", "bn", None),
            "fedavg_gn": ("fedavg", "gn", None),
            "fedprox_gn": ("fedprox", "gn", None),
            "fedavgm_gn": ("fedavgm", "gn", None),
            "feddf_bn": ("feddf", "bn", src),
        }
        for name, (strat, norm, source) in cases.items():
            net = mlp(2, 3, hidden=(48, 48), norm=norm)
            res = run_federated(net, train, parts, val, test,
                                fl_cfg(strat, rounds, seed=seed),
                                source=source)
            results[f"alpha={alpha}/{name}"] = {
                "best_acc": res.best_acc, "final_acc": res.final_acc}
    dt = time.time() - t0
    claims = {
        # FedDF w/ BN >= FedAvg w/ BN under non-iid (paper: +9 pts)
        "feddf_bn_beats_fedavg_bn_noniid":
            results["alpha=0.1/feddf_bn"]["best_acc"]
            >= results["alpha=0.1/fedavg_bn"]["best_acc"] - 0.01,
        # FedDF w/ BN >= GN-repaired baselines (paper: +3 pts)
        "feddf_bn_beats_gn_baselines_noniid":
            results["alpha=0.1/feddf_bn"]["best_acc"]
            >= max(results["alpha=0.1/fedavg_gn"]["best_acc"],
                   results["alpha=0.1/fedavgm_gn"]["best_acc"]) - 0.02,
    }
    emit("table2_normalization", dt, f"claims_ok={sum(claims.values())}/2",
         {"results": results, "claims": claims})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

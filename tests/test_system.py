"""End-to-end behaviour tests for the paper's system.

The headline claims, at CPU scale:
 1. FedDF's distillation step improves over its own FedAvg initialisation.
 2. The server pipeline (sample -> local train -> drop-worst -> fuse ->
    early-stop) runs end to end for every strategy.
 3. The sharded production step builders lower on a small mesh (subprocess
    with forced host devices, so this process stays single-device).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import FLConfig, FusionConfig, mlp, run_federated
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def problem():
    ds = gaussian_mixture(3000, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, n_clients=8, alpha=0.1, seed=0)
    src = UnlabeledDataset(np.random.default_rng(1).uniform(
        -3, 3, (1500, 2)).astype(np.float32))
    return train, val, test, parts, src


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "fedavgm",
                                      "feddf"])
def test_every_strategy_runs_and_learns(problem, strategy):
    train, val, test, parts, src = problem
    cfg = FLConfig(strategy=strategy, rounds=4, client_fraction=0.5,
                   local_epochs=10, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=200, patience=100,
                                               eval_every=50, batch_size=64))
    net = mlp(2, 3, hidden=(32, 32))
    res = run_federated(net, train, parts, val, test, cfg,
                        source=src if strategy == "feddf" else None)
    assert len(res.logs) == 4
    assert res.best_acc > 0.55  # well above 1/3 chance


def test_feddf_improves_over_its_own_init(problem):
    """The paper's core mechanism: post-distillation accuracy >= the
    weighted-average initialisation, per round (allowing small noise)."""
    train, val, test, parts, src = problem
    cfg = FLConfig(strategy="feddf", rounds=4, client_fraction=0.5,
                   local_epochs=15, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=300, patience=150,
                                               eval_every=50, batch_size=64))
    net = mlp(2, 3, hidden=(32, 32))
    res = run_federated(net, train, parts, val, test, cfg, source=src)
    gains = [l.test_acc - l.pre_distill_acc for l in res.logs]
    assert np.mean(gains) > -0.01, f"distillation hurt on average: {gains}"
    assert max(gains) > 0.0, "distillation never helped"


LOWER_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import configs
from repro.common.arch_config import reduced
from repro.launch import steps as steps_mod
import dataclasses

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = dataclasses.replace(configs.get_shape("train_4k"), seq_len=32,
                            global_batch=4)
for arch in ("qwen3-8b", "granite-moe-1b-a400m", "zamba2-1.2b"):
    cfg = reduced(configs.get(arch))
    bundle = steps_mod.make_step(cfg, shape, mesh, fsdp=True, remat=True)
    compiled = bundle.lower(mesh).compile()
    assert compiled.cost_analysis() is not None
    print("LOWER_OK", arch)
ds = dataclasses.replace(configs.get_shape("decode_32k"), seq_len=64,
                         global_batch=4)
cfg = reduced(configs.get("gemma3-4b"))
bundle = steps_mod.make_step(cfg, ds, mesh, fsdp=True)
compiled = bundle.lower(mesh).compile()
print("LOWER_OK decode")
"""


def test_step_builders_lower_on_mesh():
    res = subprocess.run(
        [sys.executable, "-c", LOWER_SNIPPET], capture_output=True,
        text=True, timeout=600, env={**os.environ, "PYTHONPATH": "src"},
        cwd=ROOT)
    assert res.stdout.count("LOWER_OK") == 4, res.stdout + res.stderr


def test_train_driver_cli_smoke(tmp_path):
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--strategy", "feddf",
         "--rounds", "2", "--clients", "4", "-C", "1.0", "--alpha", "1.0",
         "--local-epochs", "3", "--n-samples", "800", "--distill-steps",
         "100", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)
    assert res.returncode == 0, res.stdout + res.stderr
    assert (tmp_path / "summary.json").exists()

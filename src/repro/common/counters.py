"""Process-wide trace/work counters used as test + bench evidence.

A :class:`TraceCounter` bumped via a *python side effect inside a traced
function body* only moves when jax actually re-traces (and therefore
re-compiles) the function — which makes it the cheapest possible proof
that a compiled program is being reused instead of rebuilt.  The same
class doubles as a plain work counter when bumped from host code
(teacher batch-forward accounting in ``core/logit_bank.py``).

Since the flight-recorder PR this is an alias for
:class:`repro.obs.metrics.Counter`: the module-level singletons next to
what they count (``CLIENT_COMPILES`` in ``core/client.py``,
``CHUNK_COMPILES`` in ``core/feddf.py``, ``TEACHER_FORWARDS`` in
``core/logit_bank.py``) are now registered in the unified
:data:`repro.obs.metrics.REGISTRY` under dotted names, so per-round
metric records and ``RunResult.summary()["obs"]`` can enumerate them —
while tests keep calling ``reset()`` / reading ``.count`` on the
aliases exactly as before.
"""
from __future__ import annotations

from repro.obs.metrics import Counter as TraceCounter

__all__ = ["TraceCounter"]

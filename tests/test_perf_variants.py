"""§Perf variant levers: lowering coverage + numeric equivalence.

The optimized step-builder options (constrain_acts, chunked attention,
dp_heavy/dp_heavy_z3 layouts, microbatching) must (a) lower+compile on a
debug mesh for representative reduced architectures and (b) compute the
same mathematics as the baseline (microbatch accumulation == single batch).
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

VARIANT_LOWER_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
from repro import configs
from repro.common.arch_config import reduced
from repro.launch import steps as steps_mod

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = dataclasses.replace(configs.get_shape("train_4k"), seq_len=32,
                            global_batch=8)
pshape = dataclasses.replace(configs.get_shape("prefill_32k"), seq_len=64,
                             global_batch=8)

# every §Perf lever x a representative arch (dense w/ SWA, MoE, hybrid)
for arch, kw, shp in [
    ("gemma3-4b", dict(constrain_acts=True), shape),
    ("minicpm-2b", dict(constrain_acts=True, layout="dp_heavy"), shape),
    ("phi3-medium-14b", dict(constrain_acts=True, layout="dp_heavy_z3"),
     shape),
    ("qwen3-8b", dict(constrain_acts=True, microbatch=2), shape),
    ("granite-moe-1b-a400m", dict(constrain_acts=True), pshape),
    ("zamba2-1.2b", dict(constrain_acts=True), shape),
]:
    cfg = dataclasses.replace(reduced(configs.get(arch)),
                              attn_impl="chunked", attn_chunk=16)
    bundle = steps_mod.make_step(cfg, shp, mesh, fsdp=True, **kw)
    compiled = bundle.lower(mesh).compile()
    assert compiled.cost_analysis() is not None
    print("LOWER_OK", arch)

# distill step with constraints (the §Perf-C configuration)
cfg = reduced(configs.get("gemma3-4b"))
bundle = steps_mod.make_distill_step(cfg, mesh, n_teachers=2, batch_size=8,
                                     seq_len=16, constrain_acts=True)
bundle.lower(mesh).compile()
print("LOWER_OK distill")
"""

MICROBATCH_EQUIV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.common.arch_config import reduced
from repro.launch import steps as steps_mod

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = dataclasses.replace(configs.get_shape("train_4k"), seq_len=16,
                            global_batch=8)
cfg = reduced(configs.get("qwen3-8b"))

def materialize(tree, seed=0):
    leaves, treedef = jax.tree.flatten(tree)
    rng = np.random.default_rng(seed)
    out = []
    for s in leaves:
        if jnp.issubdtype(s.dtype, jnp.integer):
            out.append(jnp.asarray(rng.integers(0, 7, s.shape), s.dtype))
        else:
            out.append(jnp.asarray(0.02 * rng.normal(size=s.shape), s.dtype))
    return jax.tree.unflatten(treedef, out)

results = {}
for mb in (1, 2):
    b = steps_mod.make_step(cfg, shape, mesh, fsdp=True, microbatch=mb,
                            constrain_acts=True, param_dtype=jnp.float32)
    args = materialize(b.args)
    with mesh:
        fn = jax.jit(b.fn, in_shardings=b.in_shardings,
                     out_shardings=b.out_shardings)
        params, opt_state, step, metrics = fn(*args)
    results[mb] = (jax.tree.leaves(params)[0], metrics["loss"])

p1, l1 = results[1]
p2, l2 = results[2]
np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)
print("MICROBATCH_EQUIV_OK")
"""


def _run(snippet):
    return subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True,
        timeout=900, env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)


def test_perf_variant_steps_lower():
    res = _run(VARIANT_LOWER_SNIPPET)
    assert res.stdout.count("LOWER_OK") == 7, res.stdout + res.stderr


def test_microbatch_accumulation_matches_single_batch():
    res = _run(MICROBATCH_EQUIV_SNIPPET)
    assert "MICROBATCH_EQUIV_OK" in res.stdout, res.stdout + res.stderr

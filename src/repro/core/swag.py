"""SWAG-style teacher augmentation for ensemble distillation (Table 7).

FedDistill (Chen & Chao, 2020 — [10] in the paper) fits a Gaussian
posterior over the *received client models* (SWAG; Maddox et al., 2019)
and distills from models sampled out of it, instead of only the received
models themselves.  The paper's Table 7 compares this against the default
Adam-on-averaged-logits choice of FedDF and finds it roughly on par, with
two extra hyperparameters (sampling scale, #samples).

We implement the diagonal SWAG form over the K received client models:

    mean  = 1/K sum_k theta_k
    var   = 1/K sum_k theta_k^2 - mean^2          (diagonal)
    theta_s ~ N(mean, scale * var / 2)

Sampled models join the received models as additional distillation
teachers (the ensemble still averages logits over ALL teachers).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_stack


def swag_fit_stacked(stack):
    """Diagonal Gaussian directly over a stacked [K, ...] pytree."""
    mean = jax.tree.map(lambda s: jnp.mean(s, axis=0), stack)
    var = jax.tree.map(
        lambda s: jnp.clip(jnp.var(s, axis=0), 0.0, None), stack)
    return mean, var


def swag_fit(client_params: Sequence[dict]):
    """Diagonal Gaussian over the received models -> (mean, var) pytrees."""
    return swag_fit_stacked(tree_stack(client_params))


def swag_sample(mean, var, n_samples: int, *, scale: float = 0.5,
                seed: int = 0) -> List[dict]:
    """Draw ``n_samples`` models from N(mean, scale * var / 2)."""
    out = []
    key = jax.random.PRNGKey(seed)
    for _ in range(n_samples):
        key, sub = jax.random.split(key)
        leaves, treedef = jax.tree.flatten(mean)
        var_leaves = jax.tree.leaves(var)
        keys = jax.random.split(sub, len(leaves))
        sampled = [
            m + jnp.sqrt(scale * v / 2.0) * jax.random.normal(
                k, m.shape, m.dtype)
            for m, v, k in zip(leaves, var_leaves, keys)
        ]
        out.append(jax.tree.unflatten(treedef, sampled))
    return out


def swag_teachers(client_params: Sequence[dict], n_samples: int, *,
                  scale: float = 0.5, seed: int = 0) -> List[dict]:
    """Received client models + SWAG-sampled models (Table 7 'SWAG' row)."""
    if n_samples <= 0:
        return list(client_params)
    mean, var = swag_fit(client_params)
    return list(client_params) + swag_sample(mean, var, n_samples,
                                             scale=scale, seed=seed)


def swag_teachers_stacked(stack, n_samples: int, *, scale: float = 0.5,
                          seed: int = 0):
    """Stacked-pytree variant of :func:`swag_teachers`: [K, ...] ->
    [K + n_samples, ...] without unstacking the received models, so the
    teacher-logit bank path keeps teachers stacked end to end.  Same key
    schedule and draws as ``tree_stack(swag_teachers(tree_unstack(stack),
    ...))`` — the SWAG teachers fold into the bank identically."""
    if n_samples <= 0:
        return stack
    mean, var = swag_fit_stacked(stack)
    samples = swag_sample(mean, var, n_samples, scale=scale, seed=seed)
    return jax.tree.map(
        lambda s, *xs: jnp.concatenate([s, jnp.stack(xs)], axis=0),
        stack, *samples)

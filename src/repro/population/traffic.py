"""Deterministic, counter-based traffic model for the client population.

Every draw is keyed on ``(salt, seed, domain, wave)`` through
``np.random.default_rng``'s SeedSequence, so the trace is a pure function
of (config, seed): there is no sequential RNG state to checkpoint, no
replay on resume, and wave ``w``'s arrivals/latencies/dropouts are
identical whether the run reached ``w`` in one go or through five
resumes.

Static per-client character (a lognormal speed multiplier and a
persistent straggler flag) is drawn once from the ``static`` domain;
per-wave noise (online mask, upload jitter, dropout) comes from
wave-indexed domains.
"""
from __future__ import annotations

import numpy as np

from repro.population.config import TrafficConfig

_SALT = 0x5EEDFEED
_DOMAINS = {"static": 0, "online": 1, "upload": 2}


class TrafficModel:
    """Arrival / latency / dropout draws for ``n`` registered clients."""

    def __init__(self, cfg: TrafficConfig, seed: int, n: int):
        cfg.validate()
        self.cfg = cfg
        self.seed = int(seed)
        self.n = int(n)
        rng = self._rng("static")
        self.speed = (np.exp(rng.normal(0.0, cfg.jitter, self.n))
                      if cfg.jitter > 0 else np.ones(self.n))
        self.straggler = (rng.random(self.n) < cfg.straggler_frac
                          if cfg.straggler_frac > 0
                          else np.zeros(self.n, np.bool_))
        mult = np.where(self.straggler, cfg.straggler_mult, 1.0)
        self.base_latency = (cfg.latency * self.speed * mult).astype(
            np.float64)

    def _rng(self, domain: str, wave: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            (_SALT, self.seed, _DOMAINS[domain], int(wave)))

    def online_mask(self, wave: int) -> np.ndarray:
        """Boolean [n]: which clients are reachable for wave ``wave``."""
        if self.cfg.arrival == "always":
            return np.ones(self.n, np.bool_)
        return self._rng("online", wave).random(self.n) < self.cfg.rate

    def upload_draws(self, wave: int, clients: np.ndarray):
        """Latency and dropout draws for one dispatched cohort.

        Returns ``(latency[float64 k], dropped[bool k])`` aligned with
        ``clients``.  Deterministic given (seed, wave, cohort order).
        """
        clients = np.asarray(clients)
        k = len(clients)
        rng = self._rng("upload", wave)
        lat = self.base_latency[clients].copy()
        if self.cfg.jitter > 0:
            lat *= np.exp(rng.normal(0.0, self.cfg.jitter, k))
        dropped = (rng.random(k) < self.cfg.dropout
                   if self.cfg.dropout > 0 else np.zeros(k, np.bool_))
        return lat, dropped

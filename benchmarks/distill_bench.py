"""Distillation fast-path benchmark (ISSUE 3 acceptance).

Two measurements of the teacher-logit bank (``core/logit_bank.py``)
against the on-the-fly teacher-forward path:

 * homogeneous K=8 toy config: steady-state distill steps/sec, measured
   as MARGINAL throughput between a short and a long run of the same
   config — the one-time jit compile and bank build cancel in the
   difference (both are also reported).  The bank path must be >= 2x on
   CPU.
 * one G=3 heterogeneous round: teacher batch-forwards counted via
   ``TEACHER_FORWARDS`` — the bank is built once and shared by all G
   group-students, so the count must drop >= G x.

Writes ``BENCH_distill.json`` (override with ``BENCH_DISTILL_OUT``) so CI's
bench-smoke job records the perf trajectory, and emits the usual CSV lines
via ``benchmarks.common.emit``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, scale
from repro.common.pytree import tree_stack, tree_weighted_mean_stacked
from repro.core import mlp
from repro.core.feddf import (FusionConfig, distill,
                              feddf_fuse_heterogeneous_stacked,
                              make_teacher_logits_fn)
from repro.core.logit_bank import TEACHER_FORWARDS
from repro.data.distill_sources import UnlabeledDataset

K = 8
POOL_N = 2048
DIM, CLASSES = 16, 10
OUT = os.environ.get("BENCH_DISTILL_OUT", "BENCH_distill.json")


def _teachers(net, k, seed0=0):
    return tree_stack([net.init(jax.random.PRNGKey(seed0 + i))
                       for i in range(k)])


def _pool(n, dim, seed=0):
    return np.random.default_rng(seed).uniform(
        -3, 3, (n, dim)).astype(np.float32)


def _fusion(steps, mode, batch):
    return FusionConfig(max_steps=steps, patience=10 * steps,
                        eval_every=100, batch_size=batch,
                        use_fused_kernel=False, logit_bank=mode)


def homogeneous(short, long_):
    net = mlp(DIM, CLASSES, hidden=(128, 128))
    stack = _teachers(net, K)
    tfn = make_teacher_logits_fn(net, stack)
    student = tree_weighted_mean_stacked(stack, np.ones(K))
    src = UnlabeledDataset(_pool(POOL_N, DIM))

    def timed(steps, mode, reps=2):
        # min over reps: a GC pause / noisy neighbour inflating one run
        # would otherwise corrupt the marginal estimate below
        best, info = None, None
        for _ in range(reps):
            t0 = time.time()
            params, info = distill(net, student, [tfn], src,
                                   _fusion(steps, mode, 256), seed=0)
            jax.block_until_ready(jax.tree.leaves(params)[0])
            wall = time.time() - t0
            best = wall if best is None else min(best, wall)
        return best, info

    out = {}
    for mode in ("off", "on"):
        t_short, _ = timed(short, mode)
        t_long, info = timed(long_, mode)
        out[mode] = {
            "wall_short_s": t_short, "wall_long_s": t_long,
            # compile (and for the bank path, the build) cancels in the
            # difference: this is the per-step loop throughput.  The floor
            # keeps a pathological timer inversion from emitting a
            # negative/absurd rate
            "steps_per_s": (long_ - short) / max(t_long - t_short, 1e-3),
            "bank_build_s": info["bank_build_s"],
            "teacher_batch_forwards": info["teacher_batch_forwards"]}
    speedup = out["on"]["steps_per_s"] / out["off"]["steps_per_s"]
    rec = {"K": K, "dim": DIM, "classes": CLASSES, "hidden": [128, 128],
           "batch": 256, "steps_short": short, "steps_long": long_,
           "pool_n": POOL_N, "speedup": speedup,
           "onthefly": out["off"], "bank": out["on"]}
    emit("distill_homog_K8", 1.0 / out["on"]["steps_per_s"],
         f"speedup_x{speedup:.2f}", record=rec)
    return rec


def heterogeneous(steps):
    G = 3
    nets = [mlp(2, 3, hidden=(32,), name="s"),
            mlp(2, 3, hidden=(48, 48), name="m"),
            mlp(2, 3, hidden=(64,), name="l")]
    protos = [(nets[g], _teachers(nets[g], 2, seed0=10 * g), [1.0, 1.0])
              for g in range(G)]
    src = UnlabeledDataset(_pool(POOL_N, 2, seed=1))

    counts, walls = {}, {}
    for mode in ("off", "on"):
        TEACHER_FORWARDS.reset()
        t0 = time.time()
        fused, _ = feddf_fuse_heterogeneous_stacked(
            protos, src, _fusion(steps, mode, 128), seed=0)
        jax.block_until_ready(jax.tree.leaves(fused[-1])[0])
        walls[mode] = time.time() - t0
        counts[mode] = TEACHER_FORWARDS.count
    rec = {"G": G, "steps": steps,
           "teacher_forwards_onthefly": counts["off"],
           "teacher_forwards_bank": counts["on"],
           "forward_reduction_x": counts["off"] / max(1, counts["on"]),
           "wall_onthefly_s": walls["off"], "wall_bank_s": walls["on"]}
    emit("distill_hetero_G3", walls["on"],
         f"fwd_reduction_x{rec['forward_reduction_x']:.0f}", record=rec)
    return rec


def run() -> None:
    result = {"homogeneous": homogeneous(scale(200, 400), scale(1200, 2400)),
              "heterogeneous": heterogeneous(scale(300, 1000))}
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {OUT}: homog speedup "
          f"x{result['homogeneous']['speedup']:.2f}, hetero forward "
          f"reduction x{result['heterogeneous']['forward_reduction_x']:.0f}")


if __name__ == "__main__":
    run()

"""Table 1: communication rounds to reach target accuracy — FedAvg vs
FedProx vs FedAvgM vs FedDF under non-iid local data (Dirichlet alpha).

Paper claim (CIFAR-10/ResNet-8): FedDF needs significantly fewer rounds in
every scenario and is markedly more robust to data heterogeneity (FedAvg's
round curve oscillates; FedDF's is stable).

Offline stand-in: 5-class, 8-d Gaussian mixture with class overlap; 10
clients, C=0.4, 20 local epochs.  Rounds-to-target is computed post hoc
from the full round curve (no early stop), averaged over seeds.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fl_cfg, fusion_cfg, scale
from repro.core import FLConfig, mlp, run_federated
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

STRATS = ("fedavg", "fedprox", "fedavgm", "feddf")


def _problem(alpha, seed):
    ds = gaussian_mixture(4000, n_classes=5, dim=8, spread=2.4, noise=1.1,
                          seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, 10, alpha, seed=seed)
    src = UnlabeledDataset(np.random.default_rng(seed + 7).uniform(
        -4, 4, (3000, 8)).astype(np.float32))
    return train, val, test, parts, src


def _r2t(curve, target):
    for i, acc in enumerate(curve, start=1):
        if acc >= target:
            return i
    return None


def run(seed: int = 0) -> dict:
    rounds = scale(10, 20)
    n_seeds = scale(2, 3)
    target = 0.65
    t0 = time.time()
    results = {}
    for alpha in (1.0, 0.1):
        for strat in STRATS:
            curves, r2ts, bests, tails = [], [], [], []
            for s in range(n_seeds):
                train, val, test, parts, src = _problem(alpha, seed + s)
                net = mlp(8, 5, hidden=(48, 48))
                cfg = fl_cfg(strat, rounds, seed=seed + s,
                             local_batch_size=32)
                res = run_federated(net, train, parts, val, test, cfg,
                                    source=src if strat == "feddf" else None)
                curve = [l.test_acc for l in res.logs]
                curves.append(curve)
                r2ts.append(_r2t(curve, target))
                bests.append(res.best_acc)
                tails.append(float(np.mean(curve[rounds // 2:])))
            r2t_num = [r if r is not None else rounds + 5 for r in r2ts]
            results[f"alpha={alpha}/{strat}"] = {
                "rounds_to_target": r2ts,
                "mean_r2t_capped": float(np.mean(r2t_num)),
                "best_acc": float(np.mean(bests)),
                "tail_mean_acc": float(np.mean(tails)),
                "curves": curves,
            }
    dt = time.time() - t0

    def g(alpha, strat, key):
        return results[f"alpha={alpha}/{strat}"][key]

    claims = {
        # FedDF reaches target in no more rounds than the best baseline (iid-ish)
        "feddf_competitive_r2t_iid":
            g(1.0, "feddf", "mean_r2t_capped")
            <= min(g(1.0, s, "mean_r2t_capped")
                   for s in STRATS[:3]) + 1.0,
        "feddf_fewer_rounds_noniid":
            g(0.1, "feddf", "mean_r2t_capped")
            <= g(0.1, "fedavg", "mean_r2t_capped"),
        # stability: FedDF's late-round accuracy >= baselines' under non-iid
        "feddf_stable_noniid":
            g(0.1, "feddf", "tail_mean_acc")
            >= max(g(0.1, s, "tail_mean_acc") for s in STRATS[:3]) - 0.015,
    }
    emit("table1_rounds_to_target", dt,
         f"claims_ok={sum(claims.values())}/3",
         {"results": results, "claims": claims, "target": target})
    return {"results": results, "claims": claims}


if __name__ == "__main__":
    run()

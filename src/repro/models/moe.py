"""Mixture-of-Experts block (Qwen3-MoE / Granite-MoE style).

Three execution paths, one math:

* ``_moe_capacity`` — sort-based capacity dispatch (no [T,E,C] one-hots, no
  fake dense-expert FLOPs).  Used for train / prefill.
* ``_moe_gather``  — per-token expert-weight gathering.  Used when
  ``T * top_k < n_experts`` (single-token decode): reads only the touched
  experts' weights, which is the true memory behaviour of MoE decode.
* ``moe_shard_map`` — expert-parallel wrapper: experts sharded over the
  "model" mesh axis, activations replicated over it, partial outputs
  psum-combined (communication pattern of TP-style expert parallelism).

Router: softmax gates, top-k, renormalised weights, Switch-style load-balance
auxiliary loss.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.arch_config import ArchConfig
from repro.common.sharding import shard_map
from repro.models.layers import ParamSpec


def moe_specs(cfg: ArchConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "wi_gate": ParamSpec((e, d, ff), ("experts", "embed", "mlp")),
        "wi_up": ParamSpec((e, d, ff), ("experts", "embed", "mlp")),
        "wo": ParamSpec((e, ff, d), ("experts", "mlp", "embed")),
    }


def _route(p: dict, cfg: ArchConfig, x: jax.Array):
    """x: [T, d] -> (weights [T,k], idx [T,k], aux_loss scalar)."""
    logits = (x @ p["router"]).astype(jnp.float32)  # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style load balance: E * sum_e f_e * P_e
    e = cfg.n_experts
    assign = jnp.zeros((x.shape[0], e), gates.dtype)
    assign = assign.at[jnp.arange(x.shape[0])[:, None], idx].set(1.0)
    f = jnp.mean(assign, axis=0)  # fraction routed (over top-k slots)
    pe = jnp.mean(gates, axis=0)
    aux = e * jnp.sum(f * pe) / cfg.top_k
    return w.astype(x.dtype), idx, aux


def _expert_ffn(p: dict, buf: jax.Array) -> jax.Array:
    """buf: [E_local, C, d] -> [E_local, C, d] (per-expert SwiGLU)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["wo"])


def _moe_capacity(p: dict, cfg: ArchConfig, x: jax.Array, w, idx,
                  e_start: int, e_local: int) -> jax.Array:
    """Sort-based capacity dispatch over the local expert slice."""
    t, d = x.shape
    k = cfg.top_k
    n = t * k
    cap = max(1, int(math.ceil(t * k / cfg.n_experts * cfg.capacity_factor)))

    fe = idx.reshape(n)
    fw = w.reshape(n)
    tok = jnp.arange(n) // k
    mine = (fe >= e_start) & (fe < e_start + e_local)
    le = jnp.where(mine, fe - e_start, e_local)  # e_local == drop bucket

    order = jnp.argsort(le)  # stable
    le_s = le[order]
    starts = jnp.searchsorted(le_s, jnp.arange(e_local))
    pos = jnp.arange(n) - starts[jnp.clip(le_s, 0, e_local - 1)]
    valid = (le_s < e_local) & (pos < cap)
    src = tok[order]

    e_idx = jnp.where(valid, le_s, e_local)  # out of range -> dropped
    p_idx = jnp.where(valid, pos, 0)
    buf = jnp.zeros((e_local, cap, d), x.dtype)
    buf = buf.at[e_idx, p_idx].set(x[src], mode="drop")

    y = _expert_ffn(p, buf)  # [e_local, cap, d]
    y_tok = y[jnp.clip(e_idx, 0, e_local - 1), p_idx]  # [n, d]
    y_tok = y_tok * (fw[order] * valid)[:, None]
    out = jnp.zeros((t, d), x.dtype).at[src].add(y_tok)
    return out


def _moe_gather(p: dict, cfg: ArchConfig, x: jax.Array, w, idx) -> jax.Array:
    """Tiny-T decode path: gather only the touched experts' weights."""
    wg = jnp.take(p["wi_gate"], idx, axis=0)  # [T, k, d, ff]
    wu = jnp.take(p["wi_up"], idx, axis=0)
    wo = jnp.take(p["wo"], idx, axis=0)  # [T, k, ff, d]
    g = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x, wg))
    u = jnp.einsum("td,tkdf->tkf", x, wu)
    y = jnp.einsum("tkf,tkfd->tkd", g * u, wo)
    return jnp.einsum("tkd,tk->td", y, w)


def moe_block(p: dict, cfg: ArchConfig, x: jax.Array,
              mesh=None, dp_axes: Tuple[str, ...] = ()) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux loss).

    If ``mesh`` is given and the token count divides the data axes, run
    expert-parallel via shard_map; otherwise run the local path (correct on
    one device, and what serve_step uses).
    """
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    t = b * s

    if mesh is not None and "model" in mesh.axis_names:
        dp = tuple(a for a in dp_axes if a in mesh.axis_names)
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        m_size = mesh.shape["model"]
        if (t % max(dp_size, 1) == 0 and cfg.n_experts % m_size == 0
                and t >= dp_size and t * cfg.top_k >= cfg.n_experts):
            out, aux = _moe_shard_map(p, cfg, x2, mesh, dp)
            return out.reshape(b, s, d), aux

    w, idx, aux = _route(p, cfg, x2)
    if t * cfg.top_k < cfg.n_experts:
        out = _moe_gather(p, cfg, x2, w, idx)
    else:
        out = _moe_capacity(p, cfg, x2, w, idx, 0, cfg.n_experts)
    return out.reshape(b, s, d), aux


def _moe_shard_map(p: dict, cfg: ArchConfig, x2: jax.Array, mesh, dp):
    m_size = mesh.shape["model"]
    e_local = cfg.n_experts // m_size

    def local_fn(router, wg, wu, wo, xl):
        # xl: [T_local, d]; expert weights: local slice [e_local, ...]
        pl = {"router": router, "wi_gate": wg, "wi_up": wu, "wo": wo}
        w, idx, aux = _route(pl, cfg, xl)
        midx = jax.lax.axis_index("model")
        out = _moe_capacity(pl, cfg, xl, w, idx, midx * e_local, e_local)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return out, aux

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    in_specs = (
        P(None, None),                 # router replicated
        P("model", None, None),        # experts sharded
        P("model", None, None),
        P("model", None, None),
        P(dp_spec, None),              # tokens over data axes
    )
    out_specs = (P(dp_spec, None), P())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check=False)
    return fn(p["router"], p["wi_gate"], p["wi_up"], p["wo"], x2)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, prove it fits, and extract the roofline terms.

MUST be run as a module with nothing else having initialised jax first
(the two lines above lock the device count before any other import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --distill

Outputs one JSON per pair under experiments/dryrun/.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (SPMD-partitioned)
    HLO.  Shapes in the partitioned module are PER-DEVICE; we report
    per-device bytes moved, keyed by op kind.  ``-done`` halves of async
    pairs are skipped (the ``-start`` already carries the payload shape)."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)$", ls)
        if not m:
            continue
        rest = m.group(1)
        for kind in COLLECTIVES:
            # match the op name, not substrings of other ops; skip -done
            if re.search(rf"\b{kind}-done\(", rest):
                break
            if re.search(rf"\b{kind}(?:-start)?\(", rest):
                # result type(s) appear before the op name
                pre = rest.split(kind)[0]
                out[kind] += _shape_bytes(pre)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_CALL_RE = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> dict:
    """name -> list[str] of body lines, by brace tracking (metadata={...}
    braces are balanced within a line, so net depth is reliable)."""
    comps: dict = {}
    name, depth, buf = None, 0, []
    for line in hlo_text.splitlines():
        if name is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                name, depth, buf = m.group(1), 1, []
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[name] = buf
            name = None
        else:
            buf.append(line)
    return comps


def collective_bytes_scanned(hlo_text: str, trip_count: float) -> dict:
    """Collective bytes of the PRODUCTION (scan-over-layers) program.

    XLA prints a while-loop body once; its collectives run ``trip_count``
    times.  We attribute each collective to its physical computation, take
    the transitive closure of computations reachable from any while body,
    and weight those by trip_count.  This replaces the depth-1/depth-2
    probe extrapolation for collectives — the SPMD partitioner picks
    *different* collective strategies at different depths (measured:
    qwen3-8b prefill lowers to 6.3 GB of all-gathers at depth 1 but 5.4 GB
    of all-reduces at depth 2), so cross-depth extrapolation is unsound
    for communication, while measuring the real scanned program is exact
    up to the (known) trip count."""
    comps = _split_computations(hlo_text)
    bodies = set()
    for lines in comps.values():
        for line in lines:
            bodies.update(_WHILE_BODY_RE.findall(line))

    def callees(cname: str) -> set:
        out: set = set()
        for line in comps.get(cname, ()):
            out.update(_CALL_RE.findall(line))
            bm = _BRANCH_RE.search(line)
            if bm:
                out.update(x.strip().lstrip("%")
                           for x in bm.group(1).split(","))
        return out

    in_loop: set = set()
    stack = list(bodies)
    while stack:
        n = stack.pop()
        if n in in_loop:
            continue
        in_loop.add(n)
        stack.extend(callees(n))

    by_kind = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    in_loop_bytes = 0.0
    for cname, lines in comps.items():
        cb = collective_bytes("\n".join(lines))
        mult = trip_count if cname in in_loop else 1.0
        for k in COLLECTIVES:
            by_kind[k] += mult * cb["bytes"][k]
            counts[k] += cb["counts"][k]
        if cname in in_loop:
            in_loop_bytes += cb["total_bytes"]
    return {"bytes": by_kind, "counts": counts,
            "total_bytes": sum(by_kind.values()),
            "in_loop_bytes_once": in_loop_bytes,
            "trip_count": trip_count}


def roofline(cfg, shape, mesh, cost, coll_total_per_dev) -> dict:
    """cost_analysis values come from the SPMD-partitioned module, i.e. they
    are PER-DEVICE (verified: qwen3-8b train flops == 6ND/chips).  The spec
    formulas term = GLOBAL / (chips * rate) reduce to per_device / rate."""
    chips = mesh.devices.size
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    compute_t = flops_dev / mesh_mod.PEAK_FLOPS_BF16
    memory_t = bytes_dev / mesh_mod.HBM_BW
    collective_t = coll_total_per_dev / mesh_mod.ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    if shape.kind == "distill":
        # FedDF AVGLOGITS step: K teacher forwards (2ND each) + one student
        # forward+backward (6ND); K=4 teachers in the dry-run bundle.
        mult = 2 * 4 + 6
    else:
        mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * d_tokens
    hlo_flops_global = flops_dev * chips
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_global": hlo_flops_global,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_total_per_dev,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else None),
        "params": n_params,
        "active_params": n_active,
    }


def _compile_and_measure(bundle, mesh) -> dict:
    lowered = bundle.lower(mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = dict(cost) if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "memory": _mem_dict(compiled.memory_analysis()),
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "compiled": compiled,
    }


def depth_corrected_cost(cfg, make_bundle, mesh, full: dict) -> dict:
    """XLA cost_analysis counts a while-loop (lax.scan) body ONCE, not
    trip-count times.  Correct by linear depth extrapolation: compile a
    1-repeat scanned variant (m1 — exact at depth 1) and a 2-repeat
    *unrolled* variant (m2 — exact at depth 2); every repeat costs the same,
    so  cost(n_layers) = m1 + (n_layers/P - 1) * (m2 - m1).
    Returns corrected {flops, bytes, collective_bytes} plus the raws."""
    p = len(cfg.pattern)
    n_eff = cfg.n_layers / p
    cfg1 = dataclasses.replace(cfg, n_layers=p, name=cfg.name + "@d1u")
    cfg2 = dataclasses.replace(cfg, n_layers=2 * p, name=cfg.name + "@d2u")
    # both probes UNROLLED and WITHOUT remat: while-loop bodies are counted
    # once by cost_analysis, and remat recompute inside a scan body distorts
    # the per-repeat delta (XLA CSEs it away when unrolled).  The production
    # config (full compile above) keeps scan+remat; remat adds ~1 extra
    # forward per layer, i.e. x4/3 on the layer compute term — noted in
    # EXPERIMENTS.md instead of double-counted here.
    m1 = _compile_and_measure(make_bundle(cfg1, True), mesh)
    m2 = _compile_and_measure(make_bundle(cfg2, True), mesh)

    def extrap(v1, v2):
        return v1 + (n_eff - 1.0) * (v2 - v1)

    out = {
        "n_effective_repeats": n_eff,
        "flops": extrap(m1["cost"].get("flops", 0.0),
                        m2["cost"].get("flops", 0.0)),
        "bytes": extrap(m1["cost"].get("bytes accessed", 0.0),
                        m2["cost"].get("bytes accessed", 0.0)),
        "collective_bytes": extrap(m1["collectives"]["total_bytes"],
                                   m2["collectives"]["total_bytes"]),
        "collective_bytes_by_kind": {
            k: extrap(m1["collectives"]["bytes"][k],
                      m2["collectives"]["bytes"][k]) for k in COLLECTIVES},
        "m1_flops": m1["cost"].get("flops", 0.0),
        "m2_flops": m2["cost"].get("flops", 0.0),
        "m1_collective_bytes": m1["collectives"]["total_bytes"],
        "m2_collective_bytes": m2["collectives"]["total_bytes"],
        "full_raw_flops": full["cost"].get("flops", 0.0),
        "full_raw_collective_bytes": full["collectives"]["total_bytes"],
    }
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, *, fsdp=True,
            remat=True, distill=False, out_dir="experiments/dryrun",
            variant="baseline", skip_depth_extrap=False,
            step_kw=None, cfg_overrides=None) -> dict:
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant, "ok": False}
    t0 = time.time()
    try:
        if distill:
            # pseudo-shape for the roofline terms: the fusion batch is what
            # the server streams per AVGLOGITS step (4 teachers fwd +
            # 1 student fwd/bwd counted via kind="train" multiplier is wrong
            # — use kind="distill" handled in roofline()).
            dk = dict(n_teachers=4, batch_size=128, seq_len=512)
            dk.update({k: v for k, v in (step_kw or {}).items()
                       if k in ("n_teachers", "batch_size", "seq_len")})
            shape = configs.InputShape("distill_fusion", dk["seq_len"],
                                       dk["batch_size"], "distill")

            def make_bundle(c, unroll):
                return steps_mod.make_distill_step(
                    c, mesh, fsdp=fsdp, unroll=unroll, remat=remat, **dk,
                    **{k: v for k, v in (step_kw or {}).items()
                       if k not in ("n_teachers", "batch_size", "seq_len",
                                    "microbatch", "naive_xent", "layout")})
            bundle = make_bundle(cfg, False)
            rec["shape"] = shape_name = "distill_fusion"
            rec["distill_kw"] = dk
        else:
            shape = configs.get_shape(shape_name)
            ok, reason = configs.applicable(cfg, shape)
            if not ok:
                rec["skipped"] = reason
                rec["ok"] = True
                return _finish(rec, out_dir, t0)

            def make_bundle(c, unroll):
                return steps_mod.make_step(c, shape, mesh, fsdp=fsdp,
                                           remat=remat and not unroll,
                                           unroll=unroll, **(step_kw or {}))
            bundle = make_bundle(cfg, False)

        full = _compile_and_measure(bundle, mesh)
        rec["lower_compile_s"] = time.time() - t0
        rec["memory_analysis"] = full["memory"]
        rec["cost_analysis_raw"] = full["cost"]
        rec["collectives_raw"] = full["collectives"]
        print(full["memory"])

        # collectives: measure the production scanned program directly —
        # while-body collectives x trip count (see collective_bytes_scanned)
        n_eff = cfg.n_layers / len(cfg.pattern)
        scanned = collective_bytes_scanned(full["compiled"].as_text(), n_eff)
        rec["collectives_scanned"] = scanned
        coll_total = scanned["total_bytes"]

        if not skip_depth_extrap:
            corr = depth_corrected_cost(cfg, make_bundle, mesh, full)
            rec["depth_corrected"] = corr
            cost = {"flops": corr["flops"], "bytes accessed": corr["bytes"]}
        else:
            cost = full["cost"]

        if shape is not None:
            rec["roofline"] = roofline(cfg, shape, mesh, cost, coll_total)
            print({k: rec["roofline"][k] for k in
                   ("compute_s", "memory_s", "collective_s", "dominant")})
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return _finish(rec, out_dir, t0)


def update_collectives(arch: str, shape_name: str, multi_pod: bool, *,
                       fsdp=True, remat=True,
                       out_dir="experiments/dryrun") -> dict:
    """Recompute ONLY the scanned-collective bytes (and the roofline) for an
    existing baseline JSON: one production compile, no depth probes — the
    saved depth_corrected flops/bytes remain valid."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    fname = os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_name}__baseline.json")
    rec = json.load(open(fname))
    if "skipped" in rec or not rec.get("ok"):
        print(f"[coll-update] {arch} x {shape_name} @ {mesh_name} -> "
              f"{'SKIP' if 'skipped' in rec else 'was-FAIL'}")
        return rec
    cfg = configs.get(arch)
    shape = configs.get_shape(shape_name)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = steps_mod.make_step(cfg, shape, mesh, fsdp=fsdp, remat=remat)
    compiled = bundle.lower(mesh).compile()
    n_eff = cfg.n_layers / len(cfg.pattern)
    scanned = collective_bytes_scanned(compiled.as_text(), n_eff)
    rec["collectives_scanned"] = scanned
    corr = rec.get("depth_corrected")
    cost = ({"flops": corr["flops"], "bytes accessed": corr["bytes"]}
            if corr else rec["cost_analysis_raw"])
    rec["roofline"] = roofline(cfg, shape, mesh, cost,
                               scanned["total_bytes"])
    with open(fname, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[coll-update] {arch} x {shape_name} @ {mesh_name} -> "
          f"coll={scanned['total_bytes']/1e9:.2f}GB/dev "
          f"({time.time()-t0:.0f}s)")
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def _finish(rec: dict, out_dir: str, t0: float) -> dict:
    rec["total_s"] = time.time() - t0
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['variant']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=2)
    status = ("SKIP: " + rec.get("skipped", "") if "skipped" in rec
              else "OK" if rec["ok"] else "FAIL: " + rec.get("error", "?"))
    print(f"[dryrun] {rec['arch']} x {rec['shape']} @ {rec['mesh']} "
          f"({rec['variant']}) -> {status} ({rec['total_s']:.1f}s)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--distill", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--naive-xent", action="store_true",
                    help="v0 loss for the §Perf record")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp_heavy", "dp_heavy_z3"],
                    help="sharding layout preset (see common/sharding.py)")
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"],
                    help="attention impl (chunked = flash-pattern scan)")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--constrain-acts", action="store_true",
                    help="assert batch-sharded activations at every block "
                         "boundary (§Perf variant)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches (train only)")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--update-collectives", action="store_true",
                    help="recompute scanned collectives + roofline in "
                         "existing baseline JSONs (one compile per pair)")
    args = ap.parse_args(argv)

    if args.update_collectives:
        for arch in configs.ASSIGNED:
            for shape in configs.SHAPES:
                try:
                    update_collectives(arch, shape, args.multi_pod,
                                       out_dir=args.out_dir)
                except Exception as e:  # noqa: BLE001
                    print(f"[coll-update] {arch} x {shape} FAILED: {e}")
        sys.exit(0)

    kw = dict(fsdp=not args.no_fsdp, remat=not args.no_remat,
              out_dir=args.out_dir, variant=args.variant,
              step_kw={**({"naive_xent": True} if args.naive_xent else {}),
                       **({"constrain_acts": True}
                          if args.constrain_acts else {}),
                       **({"microbatch": args.microbatch}
                          if args.microbatch > 1 else {}),
                       **({"layout": args.layout}
                          if args.layout != "tp" else {})} or None,
              cfg_overrides=({"attn_impl": args.attn,
                              "attn_chunk": args.attn_chunk}
                             if args.attn != "naive" else None))
    failures = 0
    if args.all:
        for arch in configs.ASSIGNED:
            for shape in configs.SHAPES:
                rec = run_one(arch, shape, args.multi_pod, **kw)
                failures += 0 if rec["ok"] else 1
    else:
        assert args.arch, "--arch required unless --all"
        if args.distill:
            rec = run_one(args.arch, "distill_fusion", args.multi_pod,
                          distill=True, **kw)
        else:
            assert args.shape, "--shape required"
            rec = run_one(args.arch, args.shape, args.multi_pod, **kw)
        failures += 0 if rec["ok"] else 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(<=2 pattern repeats, d_model<=128, <=4 experts) runs one forward and one
train step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.common.arch_config import reduced
from repro.launch.steps import token_xent
from repro.models import transformer as T

ARCHS = configs.ASSIGNED


def _make_batch(cfg, key, b=2, s=16, with_labels=False):
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if with_labels:
        total = s + (cfg.n_frontend_tokens
                     if cfg.frontend == "vision_patches" else 0)
        batch["labels"] = jax.random.randint(key, (b, total), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward(arch):
    cfg = reduced(configs.get(arch))
    assert cfg.d_model <= 512 and (cfg.n_experts or 0) <= 4
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    batch = _make_batch(cfg, key)
    logits, aux = T.forward(params, cfg, batch)
    exp_s = 16 + (cfg.n_frontend_tokens
                  if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced(configs.get(arch))
    key = jax.random.PRNGKey(1)
    params = T.init(cfg, key)
    batch = _make_batch(cfg, key, with_labels=True)

    def loss_fn(p):
        logits, aux = T.forward(p, cfg, batch)
        return token_xent(logits, batch["labels"], cfg) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)) and loss > 0
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # at least one nonzero gradient
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = configs.get(arch)
    expected = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "mamba2-2.7b": (64, 2560, 8, 8, 0, 50280),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected


def test_moe_configs():
    q = configs.get("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.top_k) == (128, 8)
    g = configs.get("granite-moe-1b-a400m")
    assert (g.n_experts, g.top_k) == (32, 8)


def test_ssm_configs():
    assert configs.get("mamba2-2.7b").ssm_state == 128
    assert configs.get("zamba2-1.2b").ssm_state == 64


def test_param_counts_plausible():
    """Analytic param counts should be in the right ballpark for the names."""
    import math
    expect = {"qwen3-8b": (6e9, 11e9), "phi3-medium-14b": (11e9, 17e9),
              "qwen3-moe-235b-a22b": (180e9, 280e9),
              "mamba2-2.7b": (2.0e9, 3.4e9), "gemma3-4b": (3.0e9, 5.5e9),
              "zamba2-1.2b": (0.9e9, 1.9e9)}
    for name, (lo, hi) in expect.items():
        n = configs.get(name).param_count()
        assert lo < n < hi, f"{name}: {n:.2e} outside [{lo:.1e},{hi:.1e}]"
    moe = configs.get("qwen3-moe-235b-a22b")
    assert moe.active_param_count() < 0.2 * moe.param_count()


def test_applicability_matrix():
    skips = []
    for arch in ARCHS:
        for shape in configs.SHAPES.values():
            ok, why = configs.applicable(configs.get(arch), shape)
            if not ok:
                skips.append((arch, shape.name))
    # hubert has no decode; 6 full-attention archs skip long_500k
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    assert ("qwen3-8b", "long_500k") in skips
    assert ("gemma3-4b", "long_500k") not in skips  # sliding window
    assert ("mamba2-2.7b", "long_500k") not in skips
    assert ("zamba2-1.2b", "long_500k") not in skips
    assert len(skips) == 8

"""Component registries for the declarative experiment API.

Mirrors the proven server-strategy registry (``core/strategies.py``):
every axis a spec references by name — task, client model, distillation
source, upload quantizer — resolves through one of these tables, so
extending the system is one decorator, no if/elif chain:

    from repro.api import register_task, TaskBundle

    @register_task("my-task")
    def build(n_samples=1000, seed=0, **params) -> TaskBundle: ...

Builder contracts
-----------------
task(name)    ``fn(n_samples, seed, **params) -> TaskBundle``
model(name)   ``fn(task: TaskBundle, **params) -> Net``
source(name)  ``fn(task: TaskBundle, train: Dataset, seed, **params)
              -> DistillSource``
quantizer(name)  a ``params -> params`` callable (jit-safe)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.nets import Net, mlp, tiny_transformer
from repro.core.quantize import binarize
from repro.data.distill_sources import (DistillSource, GeneratorSource,
                                        RandomNoiseSource, UnlabeledDataset)
from repro.data.synthetic import Dataset, gaussian_mixture, token_sequences


@dataclasses.dataclass
class TaskBundle:
    """What a task builder hands downstream components: the full dataset
    (splitting is the experiment compiler's job), the shape of the
    distillation inputs, the token vocabulary (None for dense inputs)
    and the kwargs model builders derive their I/O dimensions from."""

    dataset: Dataset
    distill_shape: tuple
    vocab: Optional[int]
    model_kwargs: Dict[str, Any]


def _make_registry(kind: str):
    table: Dict[str, Callable] = {}

    def register(name: str):
        def deco(fn):
            table[name] = fn
            return fn
        return deco

    def get(name: str) -> Callable:
        if name not in table:
            raise ValueError(f"unknown {kind} {name!r}; registered: "
                             f"{sorted(table)}")
        return table[name]

    def available() -> List[str]:
        return sorted(table)

    return register, get, available


register_task, get_task, available_tasks = _make_registry("task")
register_model, get_model, available_models = _make_registry("model")
register_source, get_source, available_sources = _make_registry("source")
register_quantizer, get_quantizer, available_quantizers = \
    _make_registry("quantizer")


# ---------------------------------------------------------------------------
# built-in tasks
# ---------------------------------------------------------------------------

@register_task("blobs")
def _blobs_task(n_samples: int = 6000, seed: int = 0, n_classes: int = 3,
                dim: int = 2, spread: float = 2.2,
                noise: float = 1.0) -> TaskBundle:
    """M-class Gaussian mixture in R^d (the paper's Fig. 1 toy)."""
    ds = gaussian_mixture(n_samples, n_classes=n_classes, dim=dim,
                          spread=spread, noise=noise, seed=seed)
    return TaskBundle(ds, (dim,), None,
                      {"in_dim": dim, "n_classes": n_classes})


@register_task("tokens")
def _tokens_task(n_samples: int = 6000, seed: int = 0, n_classes: int = 4,
                 vocab: int = 64, seq_len: int = 16,
                 marker_rate: float = 0.3) -> TaskBundle:
    """Synthetic token classification (the AG News stand-in)."""
    ds = token_sequences(n_samples, n_classes=n_classes, vocab=vocab,
                         seq_len=seq_len, marker_rate=marker_rate, seed=seed)
    return TaskBundle(ds, (seq_len,), vocab,
                      {"vocab": vocab, "n_classes": n_classes,
                       "seq_len": seq_len})


# ---------------------------------------------------------------------------
# built-in models
# ---------------------------------------------------------------------------

@register_model("mlp")
def _mlp_model(task: TaskBundle, hidden=(64, 64, 64), norm: str = "none",
               groups: int = 8, name: Optional[str] = None) -> Net:
    kw = task.model_kwargs
    if "in_dim" not in kw:
        raise ValueError("model 'mlp' needs a dense-input task (got task "
                         f"kwargs {sorted(kw)})")
    return mlp(kw["in_dim"], kw["n_classes"], hidden=tuple(hidden),
               norm=norm, groups=groups, name=name)


@register_model("tiny_transformer")
def _tiny_transformer_model(task: TaskBundle, d_model: int = 64,
                            n_layers: int = 2, n_heads: int = 4,
                            name: Optional[str] = None) -> Net:
    kw = task.model_kwargs
    if "vocab" not in kw:
        raise ValueError("model 'tiny_transformer' needs a token task (got "
                         f"task kwargs {sorted(kw)})")
    return tiny_transformer(kw["vocab"], kw["n_classes"], kw["seq_len"],
                            d_model=d_model, n_layers=n_layers,
                            n_heads=n_heads, name=name)


def default_prototype_ladder(task_name: str) -> List[dict]:
    """The historic small/medium/large heterogeneous prototype ladders
    (paper Fig. 4's ResNet-20/32/ShuffleNetV2 analogue) as ModelSpec
    dicts, per task family."""
    if task_name == "blobs":
        return [
            {"name": "mlp", "params": {"hidden": [48, 48],
                                       "name": "proto-s"}},
            {"name": "mlp", "params": {"hidden": [64, 64, 64],
                                       "name": "proto-m"}},
            {"name": "mlp", "params": {"hidden": [96, 96],
                                       "name": "proto-l"}},
        ]
    if task_name == "tokens":
        return [
            {"name": "tiny_transformer", "params": {"d_model": 48,
                                                    "n_layers": 1}},
            {"name": "tiny_transformer", "params": {"d_model": 64,
                                                    "n_layers": 2}},
            {"name": "tiny_transformer", "params": {"d_model": 96,
                                                    "n_layers": 2}},
        ]
    raise ValueError(f"no default prototype ladder for task {task_name!r}")


# ---------------------------------------------------------------------------
# built-in distillation sources
# ---------------------------------------------------------------------------

@register_source("unlabeled")
def _unlabeled_source(task: TaskBundle, train: Dataset, seed: int = 0,
                      n: int = 4000, low: float = -3.0,
                      high: float = 3.0) -> DistillSource:
    """Out-of-domain unlabeled pool (different seed = different
    manifold) — the paper's default CIFAR-100-as-distillation-data
    setting."""
    if task.vocab is None:
        x = np.random.default_rng(seed + 7).uniform(
            low, high, (n,) + tuple(task.distill_shape)).astype(np.float32)
    else:
        x = token_sequences(n, n_classes=task.model_kwargs["n_classes"],
                            vocab=task.vocab,
                            seq_len=task.distill_shape[0],
                            seed=seed + 7).x
    return UnlabeledDataset(x)


@register_source("in_domain")
def _in_domain_source(task: TaskBundle, train: Dataset,
                      seed: int = 0) -> DistillSource:
    """The training inputs themselves, labels discarded (Fig. 5's
    best-case control)."""
    return UnlabeledDataset(train.x)


@register_source("generator")
def _generator_source(task: TaskBundle, train: Dataset, seed: int = 0,
                      mean: float = 0.0, std: float = 1.5,
                      latent_dim: int = 16,
                      hidden: int = 64) -> DistillSource:
    return GeneratorSource(tuple(task.distill_shape),
                           discrete_vocab=task.vocab, mean=mean, std=std,
                           latent_dim=latent_dim, hidden=hidden, seed=seed)


@register_source("noise")
def _noise_source(task: TaskBundle, train: Dataset, seed: int = 0,
                  low: float = -3.0, high: float = 3.0) -> DistillSource:
    return RandomNoiseSource(tuple(task.distill_shape), low=low, high=high,
                             discrete_vocab=task.vocab)


# ---------------------------------------------------------------------------
# built-in upload quantizers
# ---------------------------------------------------------------------------

register_quantizer("binarize")(binarize)

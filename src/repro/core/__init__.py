from repro.core.feddf import (FusionConfig, avg_logits_kl,
                              avg_logits_kl_pre, distill,
                              feddf_fuse_homogeneous,
                              feddf_fuse_heterogeneous,
                              feddf_fuse_heterogeneous_stacked,
                              feddf_fuse_stacked)
from repro.core.logit_bank import (PERSISTENT_BANK, TEACHER_FORWARDS,
                                   LogitBank, bank_for_fusion,
                                   build_logit_bank, resolve_bank)
from repro.core.engine import BucketConfig
from repro.core.server import (FLConfig, FLResult, RoundLog, run_federated,
                               run_federated_heterogeneous, run_rounds)
from repro.core.strategies import (ServerStrategy, available_strategies,
                                   get_strategy, register_strategy)
from repro.core.nets import Net, mlp, tiny_transformer
from repro.core.ensemble import ensemble_accuracy, ensemble_accuracy_stacked
from repro.core.dropworst import drop_worst, drop_worst_stacked
from repro.core.quantize import binarize, comm_bytes

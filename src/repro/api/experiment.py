"""`Experiment` — the unified run/resume entry point over the round engine.

One facade subsumes both historic drivers: a one-prototype cohort runs
Algorithm 1 exactly as ``run_federated`` did, a multi-prototype cohort
runs Algorithm 3 exactly as ``run_federated_heterogeneous`` did (same
seeds, same batch streams, same aggregation — the equivalence is pinned
by ``tests/test_experiment_api.py``), and both return one
:class:`RunResult`.

Observation is typed: instead of the historic ``log_fn`` whose payload
changed shape between the two drivers (``RoundLog`` vs
``(group, RoundLog)``), observers receive a :class:`RoundEvent` in both
cases.

Resume: ``Experiment.run(checkpoint_dir=...)`` writes the spec plus
per-round snapshots (globals per prototype, server-strategy state,
round logs) through ``checkpoint/io.py``; ``Experiment.resume(dir)``
rebuilds everything from the spec, reloads the latest snapshot and
continues — the engine replays the cohort-sampling rng for completed
rounds, so the resumed trajectory is identical to an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.api.registries import (TaskBundle, get_model, get_quantizer,
                                  get_source, get_task)
from repro.api.spec import ExperimentSpec
from repro.checkpoint import io as ckpt
from repro.core.engine import (_UNSET, BucketConfig, FLConfig, FLResult,
                               RoundLog, run_rounds)
from repro.core.feddf import FusionConfig
from repro.core.nets import Net
from repro.dist.config import DistConfig
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import Dataset, train_val_test_split
from repro.obs import trace as _trace
from repro.obs.metrics import CSVSink, JSONLSink, MetricsObserver
from repro.population.config import (FaultConfig, PopulationConfig,
                                     TrafficConfig)


@dataclasses.dataclass
class RoundEvent:
    """One prototype group's per-round observation, uniform across
    homogeneous and heterogeneous runs (group is 0 for the former).

    An observer may call :meth:`request_stop` to end the run after the
    current round — the per-round eval seam for custom early-stopping
    criteria beyond ``target_accuracy`` (which the engine itself checks,
    for heterogeneous cohorts too).  Observer stops are soft: they do not
    set ``rounds_to_target``, and a checkpointed run resumes past them.
    """

    round: int
    group: int
    n_groups: int
    heterogeneous: bool
    log: RoundLog
    stop_requested: bool = dataclasses.field(default=False, compare=False)

    def request_stop(self) -> None:
        self.stop_requested = True


Observer = Callable[[RoundEvent], None]


@dataclasses.dataclass
class RunResult:
    """Unified result: one :class:`FLResult` per prototype group."""

    spec: ExperimentSpec
    results: List[FLResult]
    global_params: List[dict]
    rounds_to_target: Optional[int]
    net_names: List[str]
    #: flight-recorder summary (phase totals, per-round breakdown, async
    #: idle gap) — set only when the run was traced (spec.obs / ObsSpec)
    obs: Optional[dict] = None

    @property
    def heterogeneous(self) -> bool:
        return len(self.results) > 1

    @property
    def result(self) -> FLResult:
        """The single group's result (homogeneous convenience)."""
        if self.heterogeneous:
            raise ValueError("heterogeneous run: use .results[group]")
        return self.results[0]

    @property
    def final_acc(self) -> float:
        return max(r.final_acc for r in self.results)

    @property
    def best_acc(self) -> float:
        return max(r.best_acc for r in self.results)

    @staticmethod
    def _bank_summary(logs) -> dict:
        """Last round's bank observables (decision / storage dtype /
        device bytes) — how the quantized-bank memory saving surfaces in
        summary.json without a debugger."""
        last = logs[-1] if logs else None
        return {"decision": getattr(last, "bank", ""),
                "dtype": getattr(last, "bank_dtype", ""),
                "nbytes": getattr(last, "bank_nbytes", 0)}

    @staticmethod
    def _population_summary(logs) -> Optional[dict]:
        """Aggregate buffered-async population telemetry, or None for
        runs that never set it (sync / async drivers)."""
        plogs = [l for l in logs
                 if getattr(l, "staleness_hist", None) is not None]
        if not plogs:
            return None
        hist = [0] * max(len(l.staleness_hist) for l in plogs)
        for l in plogs:
            for s, c in enumerate(l.staleness_hist):
                hist[s] += int(c)
        total = sum(hist)
        mean_s = (sum(s * c for s, c in enumerate(hist)) / total
                  if total else 0.0)
        return {
            "uploads_fused": total,
            "mean_staleness": mean_s,
            "staleness_hist": hist,
            "last_buffer_fill": int(plogs[-1].buffer_fill),
            "last_straggling": int(plogs[-1].n_straggling),
            "dropped_uploads": sum(int(l.n_dropped_uploads)
                                   for l in plogs),
            "stale_dropped": sum(int(l.n_stale_dropped) for l in plogs),
            "mean_eff_participants": float(
                np.mean([l.eff_participants for l in plogs])),
        }

    @staticmethod
    def _fault_summary(logs) -> Optional[dict]:
        """Aggregate fault/defense telemetry (docs/robustness.md), or
        None for runs where the fault seam never fired — their
        summary.json keeps the historic shape exactly."""
        corrupted = sum(int(getattr(l, "n_corrupted", 0)) for l in logs)
        quarantined = sum(int(getattr(l, "n_quarantined", 0)) for l in logs)
        retries = sum(int(getattr(l, "n_retries", 0)) for l in logs)
        filtered = sum(int(getattr(l, "n_teachers_filtered", 0))
                       for l in logs)
        skipped = sum(1 for l in logs if not getattr(l, "fused", True))
        rollbacks = sum(1 for l in logs if getattr(l, "rolled_back", False))
        if not (corrupted or quarantined or retries or filtered
                or skipped or rollbacks):
            return None
        return {
            "corrupted_uploads": corrupted,
            "quarantined_uploads": quarantined,
            "retries": retries,
            "teachers_filtered": filtered,
            "rounds_skipped": skipped,
            "rollbacks": rollbacks,
        }

    @staticmethod
    def _dist_summary(logs) -> Optional[dict]:
        """Aggregate wire-protocol telemetry (docs/distributed.md), or
        None for runs that never touched the wire (every other driver) —
        their summary.json keeps the historic shape exactly."""
        bytes_up = sum(int(getattr(l, "wire_bytes_up", 0)) for l in logs)
        bytes_down = sum(int(getattr(l, "wire_bytes_down", 0)) for l in logs)
        if not (bytes_up or bytes_down):
            return None
        return {
            "bytes_up": bytes_up,
            "bytes_down": bytes_down,
            "wire_retries": sum(int(getattr(l, "n_wire_retries", 0))
                                for l in logs),
            "crc_failures": sum(int(getattr(l, "n_crc_failures", 0))
                                for l in logs),
            "deadline_misses": sum(int(getattr(l, "n_deadline_misses", 0))
                                   for l in logs),
            "wire_lost": sum(int(getattr(l, "n_wire_lost", 0))
                             for l in logs),
            "min_pods_alive": min(int(getattr(l, "n_pods_alive", 0))
                                  for l in logs),
        }

    def summary(self) -> dict:
        """Summary dict in the historic ``launch/train.py`` shapes.
        Buffered-async runs additionally carry a ``population`` section
        (docs/population.md) and fault-injected runs a ``faults``
        section (docs/robustness.md); their absence keeps older shapes
        intact."""
        if not self.heterogeneous:
            r = self.results[0]
            out = {"final": r.final_acc, "best": r.best_acc,
                   "rounds_to_target": self.rounds_to_target,
                   "per_round": [l.test_acc for l in r.logs],
                   "bank": self._bank_summary(r.logs)}
            pop = self._population_summary(r.logs)
            if pop is not None:
                out["population"] = pop
            faults = self._fault_summary(r.logs)
            if faults is not None:
                out["faults"] = faults
            dist = self._dist_summary(r.logs)
            if dist is not None:
                out["dist"] = dist
            if self.obs is not None:
                out["obs"] = self.obs
            return out
        out = {f"proto_{g}": {"final": r.final_acc, "best": r.best_acc,
                              "per_round": [l.test_acc for l in r.logs],
                              "bank": self._bank_summary(r.logs)}
               for g, r in enumerate(self.results)}
        pop = self._population_summary(self.results[0].logs)
        if pop is not None:
            out["population"] = pop
        faults = self._fault_summary(
            [l for r in self.results for l in r.logs])
        if faults is not None:
            out["faults"] = faults
        # wire telemetry is round-level (every group's log of round t
        # carries the same counters), so aggregate one group only
        dist = self._dist_summary(self.results[0].logs)
        if dist is not None:
            out["dist"] = dist
        if self.obs is not None:
            out["obs"] = self.obs
        return out


# ---------------------------------------------------------------------------
# spec -> components (the compile step)
# ---------------------------------------------------------------------------

def build_task_bundle(spec: ExperimentSpec) -> TaskBundle:
    seed = spec.task.seed if spec.task.seed is not None else spec.seed
    return get_task(spec.task.name)(
        n_samples=spec.task.n_samples, seed=seed, **spec.task.params)


def build_splits(spec: ExperimentSpec, bundle: TaskBundle
                 ) -> Tuple[Dataset, Dataset, Dataset, List[np.ndarray]]:
    train, val, test = train_val_test_split(bundle.dataset, seed=spec.seed)
    pseed = (spec.partition.seed if spec.partition.seed is not None
             else spec.seed)
    parts = dirichlet_partition(
        train.y, spec.partition.n_clients, spec.partition.alpha, seed=pseed,
        min_per_client=spec.partition.min_per_client)
    return train, val, test, parts


def build_cohort(spec: ExperimentSpec, bundle: TaskBundle
                 ) -> Tuple[List[Net], List[int]]:
    nets = [get_model(m.name)(bundle, **m.params)
            for m in spec.cohort.prototypes]
    return nets, spec.cohort.client_prototypes(spec.partition.n_clients)


def build_source(spec: ExperimentSpec, bundle: TaskBundle, train: Dataset):
    if spec.source is None:
        return None
    return get_source(spec.source.name)(bundle, train, seed=spec.seed,
                                        **spec.source.params)


def to_fl_config(spec: ExperimentSpec) -> FLConfig:
    """Compile the declarative spec into the engine-level config."""
    s = spec.strategy
    quantize = (None if spec.privacy.quantizer is None
                else get_quantizer(spec.privacy.quantizer))
    faults = FaultConfig(**spec.faults.to_dict())
    # tcp client pods rebuild their engine from the serialized spec, so
    # the fusion pod carries it into the config it hands the driver
    dist = DistConfig(
        transport=spec.dist.transport, wire_codec=spec.dist.wire_codec,
        n_pods=spec.dist.n_pods, heartbeat_s=spec.dist.heartbeat_s,
        upload_deadline_s=spec.dist.upload_deadline_s,
        verify_crc=spec.dist.verify_crc, wire_log=spec.dist.wire_log,
        spec_json=(spec.to_json()
                   if spec.dist.transport == "tcp" else None))
    # the distill divergence guard rides the fault axis: a per-chunk
    # finite-ness check + rollback only when faults can actually fire,
    # so fault-free fusions keep the guard-free (bit-identical) path
    fusion = FusionConfig(**s.fusion.to_dict(),
                          divergence_guard=faults.enabled)
    return FLConfig(
        rounds=spec.rounds, client_fraction=spec.client_fraction,
        local_epochs=spec.local_epochs,
        local_batch_size=spec.local_batch_size, local_lr=spec.local_lr,
        strategy=s.name, prox_mu=s.prox_mu,
        server_momentum=s.server_momentum, drop_worst=s.drop_worst,
        trim_frac=s.trim_frac, faults=faults, dist=dist,
        seed=spec.seed, local_optimizer=spec.local_optimizer,
        local_adam_lr=spec.local_adam_lr, quantize=quantize,
        fusion=fusion,
        feddf_init_from=s.feddf_init_from,
        target_accuracy=spec.target_accuracy,
        dp_clip=spec.privacy.clip,
        dp_noise_multiplier=spec.privacy.noise_multiplier,
        bucketing=BucketConfig(kind=spec.bucket.kind,
                               max_buckets=spec.bucket.max_buckets),
        population=PopulationConfig(
            size=spec.population.size,
            sampler=spec.population.sampler,
            buffer_size=spec.population.buffer_size,
            max_staleness=spec.population.max_staleness,
            staleness_exponent=spec.population.staleness_exponent,
            traffic=TrafficConfig(**spec.population.traffic.to_dict())))


def build_mesh(spec: ExperimentSpec):
    if not spec.sharding.shard_clients:
        return None
    from repro.launch.mesh import make_client_mesh
    return make_client_mesh()


def build_engine(spec: ExperimentSpec):
    """Compile a validated spec all the way to a :class:`RoundEngine`.

    This is how a tcp client pod (``python -m repro.dist.pods``) rebuilds
    the exact engine the fusion pod runs: the spec is the single source
    of truth, so both sides derive identical data splits, prototypes and
    compiled client updates from it."""
    from repro.core.engine import RoundEngine

    spec = spec.validate()
    bundle = build_task_bundle(spec)
    train, val, test, parts = build_splits(spec, bundle)
    nets, client_proto = build_cohort(spec, bundle)
    source = build_source(spec, bundle, train)
    return RoundEngine(nets, client_proto, train, parts, val, test,
                       to_fl_config(spec), source=source,
                       heterogeneous=len(nets) > 1, mesh=build_mesh(spec),
                       client_axis=spec.sharding.client_axis)


# ---------------------------------------------------------------------------
# checkpoint round-trip helpers
# ---------------------------------------------------------------------------

def _jsonable(o):
    if isinstance(o, (np.bool_,)):
        return bool(o)
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, (np.floating, jax.Array)):
        return float(o)
    return str(o)


def _round_dir(checkpoint_dir: str, t: int) -> str:
    return os.path.join(checkpoint_dir, "rounds", f"{t:05d}")


_KEEP_ROUND_DIRS = 2  # latest + one fallback against partial writes


def _save_round(checkpoint_dir: str, t: int, globals_: List[dict], state,
                logs: List[List[RoundLog]],
                rounds_to_target: Optional[int]) -> None:
    with _trace.span("checkpoint_write", round=int(t)):
        _save_round_body(checkpoint_dir, t, globals_, state, logs,
                         rounds_to_target)


def _save_round_body(checkpoint_dir, t, globals_, state, logs,
                     rounds_to_target) -> None:
    rd = _round_dir(checkpoint_dir, t)
    os.makedirs(rd, exist_ok=True)
    for g, params in enumerate(globals_):
        ckpt.save(os.path.join(rd, f"global_{g}"), params)
    ckpt.save_obj(os.path.join(rd, "state"), state)
    # logs.json is written LAST and atomically: its presence marks the
    # snapshot complete, so a crash mid-checkpoint leaves a dir the
    # loader recognises as partial and skips
    tmp = os.path.join(rd, "logs.json.tmp")
    with open(tmp, "w") as f:
        json.dump({"round": t, "rounds_to_target": rounds_to_target,
                   "logs": [[dataclasses.asdict(l) for l in group]
                            for group in logs]},
                  f, default=_jsonable)
    os.replace(tmp, os.path.join(rd, "logs.json"))
    # resume only ever reads the newest snapshot (it holds the full log
    # history), so prune superseded round dirs instead of accumulating
    # one model copy per round
    rounds_dir = os.path.join(checkpoint_dir, "rounds")
    stale = sorted(e for e in os.listdir(rounds_dir)
                   if e.isdigit())[:-_KEEP_ROUND_DIRS]
    for e in stale:
        shutil.rmtree(os.path.join(rounds_dir, e), ignore_errors=True)


def _load_latest_round(checkpoint_dir: str, nets: List[Net]
                       ) -> Tuple[int, List[dict], object,
                                  List[List[RoundLog]], Optional[int]]:
    rounds_dir = os.path.join(checkpoint_dir, "rounds")
    entries = (sorted(e for e in os.listdir(rounds_dir) if e.isdigit())
               if os.path.isdir(rounds_dir) else [])
    # newest complete snapshot wins; dirs without a parseable logs.json
    # are partial writes from a crash mid-checkpoint — fall back past them
    # (this is what _KEEP_ROUND_DIRS > 1 retains the older snapshot for)
    payload = None
    for entry in reversed(entries):
        rd = os.path.join(rounds_dir, entry)
        try:
            with open(os.path.join(rd, "logs.json")) as f:
                payload = json.load(f)
            break
        except (FileNotFoundError, json.JSONDecodeError):
            continue
    if payload is None:
        raise FileNotFoundError(
            f"no complete round checkpoint under {rounds_dir!r} — was "
            f"the run started with checkpoint_dir set?")
    t = int(payload["round"])
    logs = [[RoundLog(**d) for d in group] for group in payload["logs"]]
    globals_ = [
        ckpt.restore(os.path.join(rd, f"global_{g}"),
                     like=net.init(jax.random.PRNGKey(0)))
        for g, net in enumerate(nets)]
    state = ckpt.load_obj(os.path.join(rd, "state"))
    return t, globals_, state, logs, payload.get("rounds_to_target")


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

class Experiment:
    """A validated, runnable experiment.

        spec = ExperimentSpec(...)            # or ExperimentSpec.load(path)
        result = Experiment(spec).run()       # RunResult

    ``run(checkpoint_dir=...)`` persists the spec + per-round state;
    ``Experiment.resume(dir)`` continues an interrupted run to
    ``spec.rounds`` with an identical trajectory.
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec.validate()

    def run(self, *, observers: Sequence[Observer] = (),
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 1) -> RunResult:
        return self._run(observers, checkpoint_dir, checkpoint_every,
                         resume=False)

    @classmethod
    def resume(cls, directory: str, *, observers: Sequence[Observer] = (),
               checkpoint_every: int = 1) -> RunResult:
        """Continue a checkpointed run from ``directory`` (which must
        contain the ``spec.json`` + ``rounds/`` a checkpointed
        :meth:`run` wrote)."""
        spec = ExperimentSpec.load(os.path.join(directory, "spec.json"))
        return cls(spec)._run(observers, directory, checkpoint_every,
                              resume=True)

    def _run(self, observers, checkpoint_dir, checkpoint_every, *,
             resume: bool) -> RunResult:
        spec = self.spec
        bundle = build_task_bundle(spec)
        train, val, test, parts = build_splits(spec, bundle)
        nets, client_proto = build_cohort(spec, bundle)
        source = build_source(spec, bundle, train)
        cfg = to_fl_config(spec)
        mesh = build_mesh(spec)
        heterogeneous = len(nets) > 1

        init_globals, init_state, init_logs = None, _UNSET, None
        start_round = 1
        if resume:
            (last, init_globals, init_state, init_logs,
             stored_rtt) = _load_latest_round(checkpoint_dir, nets)
            start_round = last + 1
            if stored_rtt is not None:
                # the checkpointed run already early-stopped on
                # target_accuracy — do not retrain past the stop
                results = [FLResult(logs=init_logs[g],
                                    global_params=init_globals[g])
                           for g in range(len(nets))]
                return RunResult(spec=spec, results=results,
                                 global_params=init_globals,
                                 rounds_to_target=stored_rtt,
                                 net_names=[n.name for n in nets])

        def log_fn(entry):
            g, log = entry if heterogeneous else (0, entry)
            event = RoundEvent(round=log.round, group=g,
                               n_groups=len(nets),
                               heterogeneous=heterogeneous, log=log)
            for observer in observers:
                observer(event)
            return event.stop_requested  # truthy -> driver stops the run

        round_end_hook = None
        if checkpoint_dir is not None and checkpoint_every > 0:
            os.makedirs(checkpoint_dir, exist_ok=True)
            spec.save(os.path.join(checkpoint_dir, "spec.json"))

            def round_end_hook(t, globals_, state, logs, rounds_to_target):
                if (t % checkpoint_every == 0 or t == cfg.rounds
                        or rounds_to_target is not None):
                    _save_round(checkpoint_dir, t, globals_, state, logs,
                                rounds_to_target)

        from repro.drivers import make_driver
        driver = make_driver(spec.driver.kind,
                             staleness=spec.driver.staleness,
                             prefetch=spec.driver.prefetch)

        # flight recorder: arm per spec.obs, or piggyback on a recorder
        # some caller (bench/test) armed externally.  Disarmed runs take
        # none of these branches and stay bit-identical.
        armed_here = False
        metrics_obs = None
        if spec.obs.enabled:
            _trace.arm(path=spec.obs.trace_path,
                       profile_dir=(spec.obs.profile_dir
                                    if spec.obs.profile else None))
            armed_here = True
            if spec.obs.metrics_dir:
                metrics_obs = MetricsObserver([
                    JSONLSink(os.path.join(spec.obs.metrics_dir,
                                           "metrics.jsonl")),
                    CSVSink(os.path.join(spec.obs.metrics_dir,
                                         "metrics.csv"))])
                observers = list(observers) + [metrics_obs]

        try:
            results, globals_, rounds_to_target = run_rounds(
                nets, client_proto, train, parts, val, test, cfg,
                source=source, log_fn=log_fn, heterogeneous=heterogeneous,
                mesh=mesh, client_axis=spec.sharding.client_axis,
                init_globals=init_globals, init_state=init_state,
                start_round=start_round, init_logs=init_logs,
                round_end_hook=round_end_hook, driver=driver)
            rec = _trace.recorder()
            obs_summary = rec.summary() if rec is not None else None
        finally:
            if metrics_obs is not None:
                metrics_obs.close()
            if armed_here:
                _trace.disarm()
        return RunResult(spec=spec, results=results, global_params=globals_,
                         rounds_to_target=rounds_to_target,
                         net_names=[n.name for n in nets],
                         obs=obs_summary)

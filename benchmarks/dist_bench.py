"""Distributed runtime acceptance bench (ISSUE 10; docs/distributed.md).

Five cases over one fedavg problem, all through the ``distributed``
driver's loopback transport:

  * **degenerate** — loopback, fp32 codec, zero transport faults: must
    be bit-identical to the ``sync`` driver (trajectory and final
    globals);
  * **chaos (defended)** — one client pod killed mid-round plus 5%
    frame corruption under a 0.5 quorum: the defense ladder (CRC retry,
    deadline re-dispatch, heartbeat re-routing, quorum skip) must hold
    the final accuracy within 1pt of the clean run, with the telemetry
    (retries / deadline misses / pod death) proving the faults fired;
  * **undefended** — the same corruption at 30% with ``verify_crc``
    off: corrupted frames decode to garbage parameters and fuse, so the
    run must visibly degrade (that the *defended* arm doesn't is the
    point of the comparison);
  * **wire** — identical runs under the fp32 / int8 / binarize payload
    codecs, recording actual bytes-on-wire: int8 must cut uplink bytes
    >= 3x vs fp32 (~4x payload, minus frame overhead);
  * **restart** — a checkpointed run with a wire log, then a simulated
    fusion-pod crash + restart from the round-2 snapshot: the resumed
    round replays its uploads off the wire log (zero uplink bytes) and
    the trajectory matches the uninterrupted run exactly.

Writes ``BENCH_dist.json`` (override with ``BENCH_DIST_OUT``) plus one
schema'd ``BENCH_history.jsonl`` record gated by
``benchmarks/check_history.py --require dist``.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit, scale
from benchmarks.timing import finish_bench
from repro.core import FLConfig, FusionConfig, mlp, run_rounds
from repro.data import (dirichlet_partition, gaussian_mixture,
                        train_val_test_split)
from repro.dist.config import DistConfig
from repro.obs.metrics import REGISTRY
from repro.population import FaultConfig

K = 8
DIM, CLASSES = 16, 10
OUT = os.environ.get("BENCH_DIST_OUT", "BENCH_dist.json")


def _problem(seed=0):
    ds = gaussian_mixture(3000, n_classes=CLASSES, dim=DIM, seed=seed)
    train, val, test = train_val_test_split(ds, seed=seed)
    parts = dirichlet_partition(train.y, K, 1.0, seed=seed)
    return train, val, test, parts


def _config(rounds, dist=None, faults=None, **kw):
    return FLConfig(
        strategy="fedavg", rounds=rounds, client_fraction=0.5,
        local_epochs=10, local_batch_size=32, local_lr=0.05, seed=0,
        fusion=FusionConfig(max_steps=100, patience=100, eval_every=50,
                            batch_size=64),
        dist=dist if dist is not None else DistConfig(),
        faults=faults if faults is not None else FaultConfig(), **kw)


def run() -> None:
    rounds = scale(4, 8)
    train, val, test, parts = _problem()
    net = mlp(DIM, CLASSES, hidden=(64, 64))

    def one(cfg, driver, **rr_kw):
        t0 = time.perf_counter()
        results, globals_, _ = run_rounds(
            [net], [0] * K, train, parts, val, test, cfg, driver=driver,
            **rr_kw)
        jax.block_until_ready(jax.tree.leaves(globals_[0])[0])
        wall = time.perf_counter() - t0
        logs = results[0].logs
        finite = all(bool(np.isfinite(np.asarray(l)).all())
                     for l in jax.tree.leaves(globals_[0]))
        return {
            "final_acc": results[0].final_acc, "wall_s": wall,
            "finite": finite,
            "per_round": [l.test_acc for l in logs],
            "bytes_up": sum(l.wire_bytes_up for l in logs),
            "bytes_down": sum(l.wire_bytes_down for l in logs),
            "wire_retries": sum(l.n_wire_retries for l in logs),
            "crc_failures": sum(l.n_crc_failures for l in logs),
            "deadline_misses": sum(l.n_deadline_misses for l in logs),
            "wire_lost": sum(l.n_wire_lost for l in logs),
            "min_pods_alive": min((l.n_pods_alive for l in logs),
                                  default=0),
        }, results[0], globals_

    def same_globals(a, b):
        return all(bool((np.asarray(x) == np.asarray(y)).all())
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # -- degenerate: loopback + fp32 + no faults == sync, bitwise --------
    sync_m, sync_r, sync_g = one(_config(rounds), "sync")
    dist_m, dist_r, dist_g = one(
        _config(rounds, dist=DistConfig(n_pods=2)), "distributed")
    degenerate = {
        "trajectory_equal": (
            dist_m["per_round"] == sync_m["per_round"]
            and same_globals(sync_g[0], dist_g[0])),
        "final_acc": dist_m["final_acc"],
    }
    assert degenerate["trajectory_equal"], \
        "degenerate distributed must be bit-identical to sync"

    # -- chaos (defended): pod kill + 5% corruption under quorum ---------
    chaos_m, _, _ = one(
        _config(rounds,
                dist=DistConfig(n_pods=2, heartbeat_s=0.1,
                                upload_deadline_s=1.0,
                                kill_pod=1, kill_after_round=2),
                faults=FaultConfig(transport_corrupt=0.05, quorum=0.5)),
        "distributed")
    chaos = {
        "drift": chaos_m["final_acc"] - sync_m["final_acc"],
        "final_acc": chaos_m["final_acc"],
        "wire_retries": chaos_m["wire_retries"],
        "crc_failures": chaos_m["crc_failures"],
        "deadline_misses": chaos_m["deadline_misses"],
        "min_pods_alive": chaos_m["min_pods_alive"],
        "n_pods": 2,
        "finite": chaos_m["finite"],
    }

    # -- undefended: same corruption class, CRC check off ----------------
    undef_m, _, _ = one(
        _config(rounds,
                dist=DistConfig(n_pods=2, verify_crc=False),
                faults=FaultConfig(transport_corrupt=0.3)),
        "distributed")
    undefended = {
        "final_acc": undef_m["final_acc"],
        "finite": undef_m["finite"],
        "drift": undef_m["final_acc"] - sync_m["final_acc"],
        # degraded = garbage parameters actually landed: non-finite
        # globals, or accuracy more than 1pt under the clean run
        "degraded": (not undef_m["finite"]
                     or undef_m["final_acc"]
                     < sync_m["final_acc"] - 0.01),
    }

    # -- wire: bytes-on-wire per codec (fp32 baseline = degenerate run) --
    int8_m, int8_r, _ = one(
        _config(rounds, dist=DistConfig(n_pods=2, wire_codec="int8")),
        "distributed")
    bin_m, _, _ = one(
        _config(rounds, dist=DistConfig(n_pods=2, wire_codec="binarize")),
        "distributed")
    wire = {
        "fp32_bytes_up": dist_m["bytes_up"],
        "int8_bytes_up": int8_m["bytes_up"],
        "binarize_bytes_up": bin_m["bytes_up"],
        "int8_reduction_x": dist_m["bytes_up"] / max(int8_m["bytes_up"], 1),
        "binarize_reduction_x":
            dist_m["bytes_up"] / max(bin_m["bytes_up"], 1),
        "int8_final_drift": int8_m["final_acc"] - sync_m["final_acc"],
    }

    # -- restart: fusion-pod crash + wire-log replay ---------------------
    import tempfile
    with tempfile.TemporaryDirectory(prefix="dist_bench_") as td:
        wl = os.path.join(td, "wire.log")
        snap = {}

        def hook(t, globals_, state, logs, rtt):
            if t == rounds - 2:
                snap.update(globals_=list(globals_), state=state,
                            logs=[list(g) for g in logs])

        full_m, _, full_g = one(
            _config(rounds, dist=DistConfig(n_pods=2, wire_log=wl)),
            "distributed", round_end_hook=hook)
        replayed0 = REGISTRY.counter("dist.wirelog_replayed").value()
        res_m, res_r, res_g = one(
            _config(rounds, dist=DistConfig(n_pods=2, wire_log=wl)),
            "distributed", init_globals=snap["globals_"],
            init_state=snap["state"], init_logs=snap["logs"],
            start_round=rounds - 1)
        replayed = (REGISTRY.counter("dist.wirelog_replayed").value()
                    - replayed0)
    restart = {
        "trajectory_equal": (res_m["per_round"] == full_m["per_round"]
                             and same_globals(full_g[0], res_g[0])),
        "replayed": int(replayed),
        "resumed_round_bytes_up":
            int(res_r.logs[rounds - 2].wire_bytes_up),
    }

    rec = {
        "K": K, "dim": DIM, "classes": CLASSES, "rounds": rounds,
        "clean_final_acc": sync_m["final_acc"],
        "degenerate": degenerate,
        "chaos": chaos,
        "undefended": undefended,
        "wire": wire,
        "restart": restart,
    }
    emit("dist_chaos_drift", abs(chaos["drift"]) * 1e6,
         f"undef_drift_{undefended['drift']:.3f}", record=rec)
    finish_bench("dist", rec, out=OUT, config={"K": K, "rounds": rounds})
    print(f"wrote {OUT}: clean {sync_m['final_acc']:.4f}, chaos "
          f"{chaos['final_acc']:.4f} (drift {chaos['drift']:+.4f}, "
          f"retries {chaos['wire_retries']}, pods_alive "
          f"{chaos['min_pods_alive']}/2), undefended "
          f"{undefended['final_acc']:.4f} (degraded "
          f"{undefended['degraded']}), int8 wire x"
          f"{wire['int8_reduction_x']:.2f}, restart replayed "
          f"{restart['replayed']}")


if __name__ == "__main__":
    run()

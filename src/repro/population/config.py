"""Engine-level population / traffic configuration (dependency-free).

These mirror the spec-layer :class:`repro.api.spec.PopulationSpec` /
:class:`TrafficSpec` the way ``FLConfig`` mirrors ``ExperimentSpec``:
plain dataclasses the engine and drivers consume, with no knowledge of
JSON round-tripping.  ``docs/population.md`` documents the knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common.options import ARRIVAL_KINDS


@dataclasses.dataclass
class TrafficConfig:
    """Arrival / latency / dropout model for the client population.

    All draws are counter-based (keyed on ``(seed, domain, wave)``), so a
    trace is a pure function of the config + seed: resuming a run never
    replays or shifts the schedule.
    """
    arrival: str = "always"       # always | bernoulli (per-wave online draw)
    rate: float = 1.0             # P(online) per wave under bernoulli
    latency: float = 0.0          # mean upload latency, virtual seconds
    jitter: float = 0.0           # lognormal sigma: per-client speed AND
    #                               per-upload latency noise
    straggler_frac: float = 0.0   # fraction of persistently slow clients
    straggler_mult: float = 8.0   # their latency multiplier
    dropout: float = 0.0          # P(upload lost) per dispatch

    def validate(self) -> None:
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"options: {ARRIVAL_KINDS}")
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"traffic rate must be in (0, 1], got {self.rate}")
        if self.latency < 0 or self.jitter < 0:
            raise ValueError("latency and jitter must be >= 0")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(f"straggler_frac must be in [0, 1], "
                             f"got {self.straggler_frac}")
        if self.straggler_mult < 1.0:
            raise ValueError(f"straggler_mult must be >= 1, "
                             f"got {self.straggler_mult}")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")


@dataclasses.dataclass
class PopulationConfig:
    """Population size, cohort sampling policy and upload-buffer shape."""
    size: Optional[int] = None         # registered clients; None -> one per
    #                                    data partition (the classic roster)
    sampler: str = "uniform"           # population/scheduler.py registry
    buffer_size: Optional[int] = None  # M uploads per aggregation; None -> K
    max_staleness: int = 4             # uploads older than S rounds dropped
    staleness_exponent: float = 0.5    # a in the (1 + s)^-a FedAsync weight
    traffic: TrafficConfig = dataclasses.field(default_factory=TrafficConfig)

    def validate(self) -> None:
        if self.size is not None and self.size < 1:
            raise ValueError(f"population size must be >= 1, got {self.size}")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, "
                             f"got {self.buffer_size}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, "
                             f"got {self.max_staleness}")
        if self.staleness_exponent < 0:
            raise ValueError(f"staleness_exponent must be >= 0, "
                             f"got {self.staleness_exponent}")
        self.traffic.validate()

"""Unified model: dense / MoE / SSM / hybrid / audio / VLM from one config.

Layers are grouped by *pattern position*: ``pattern[j]`` repeats
``n_layers // len(pattern)`` times (stacked params, ``lax.scan`` over
repeats — keeps HLO size depth-independent, which is what makes 512-way SPMD
partitioning of a 94-layer MoE tractable), plus an unrolled remainder so
exact layer counts are preserved.  ``shared_attn`` positions (Zamba2) hold a
single weight set reused on every repeat.

Public surface:
  param_specs / init / logical  — parameters + logical sharding axes
  forward(params, batch)        — full-sequence logits (train / eval)
  prefill(params, batch)        — logits + populated caches
  decode_step(params, batch)    — one-token logits + updated caches
  init_caches / cache_logical   — decode-state construction
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.arch_config import ArchConfig, BlockSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamSpec, gelu_mlp, gelu_mlp_specs, init_params, logical_axes, rmsnorm,
    rmsnorm_spec, stack_specs, swiglu, swiglu_specs)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _mixer_specs(cfg: ArchConfig, spec: BlockSpec) -> dict:
    if spec.mixer == "mamba":
        return ssm_mod.ssm_specs(cfg)
    return attn.attn_specs(cfg)


def _mlp_specs(cfg: ArchConfig, spec: BlockSpec) -> Optional[dict]:
    if spec.mlp == "swiglu":
        return swiglu_specs(cfg.d_model, cfg.d_ff)
    if spec.mlp == "gelu":
        return gelu_mlp_specs(cfg.d_model, cfg.d_ff)
    if spec.mlp == "moe":
        return moe_mod.moe_specs(cfg)
    return None


def _block_specs(cfg: ArchConfig, spec: BlockSpec) -> dict:
    d = {"norm1": rmsnorm_spec(cfg.d_model), "mixer": _mixer_specs(cfg, spec)}
    mlp = _mlp_specs(cfg, spec)
    if mlp is not None:
        d["norm2"] = rmsnorm_spec(cfg.d_model)
        d["mlp"] = mlp
    return d


def _layout(cfg: ArchConfig) -> Tuple[int, int, int]:
    p = len(cfg.pattern)
    return p, cfg.n_layers // p, cfg.n_layers % p


def param_specs(cfg: ArchConfig) -> dict:
    p, n_full, rem = _layout(cfg)
    specs: Dict[str, Any] = {}
    if cfg.frontend != "audio_frames":
        # vocab-sharded ONLY: fsdp-sharding the d_model dim of the
        # embedding/head makes the unembed contraction non-local (XLA
        # all-reduces full-batch fp32 logits, ~40 GB/device — see §Perf)
        specs["embed"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                   ("vocab", None), scale=1.0)
    blocks = []
    for j in range(p):
        bs = cfg.pattern[j]
        if bs.mixer == "shared_attn":
            blocks.append({})  # weights live in specs["shared"]
        else:
            blocks.append(stack_specs(_block_specs(cfg, bs), n_full)
                          if n_full > 0 else {})
    specs["blocks"] = tuple(blocks)
    specs["tail"] = tuple(
        {} if cfg.pattern[j].mixer == "shared_attn"
        else _block_specs(cfg, cfg.pattern[j])
        for j in range(rem))
    if any(b.mixer == "shared_attn" for b in cfg.pattern):
        shared_spec = dataclasses.replace(cfg.pattern[
            next(j for j, b in enumerate(cfg.pattern)
                 if b.mixer == "shared_attn")], mixer="attn_global")
        specs["shared"] = _block_specs(cfg, shared_spec)
    specs["final_norm"] = rmsnorm_spec(cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend == "audio_frames":
        specs["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                  (None, "vocab"))
    return specs


def init(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    return init_params(param_specs(cfg), key, dtype)


def logical(cfg: ArchConfig):
    return logical_axes(param_specs(cfg))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_mixer(bp: dict, cfg: ArchConfig, spec: BlockSpec, h: jax.Array):
    x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
    if spec.mixer == "mamba":
        return h + ssm_mod.ssm_forward(bp["mixer"], cfg, x)
    local = spec.mixer == "attn_local"
    return h + attn.attention(bp["mixer"], cfg, x, local=local)


def _apply_mlp(bp: dict, cfg: ArchConfig, spec: BlockSpec, h: jax.Array,
               mesh, dp_axes):
    if spec.mlp == "none":
        return h, 0.0
    x = rmsnorm(bp["norm2"], h, cfg.norm_eps)
    if spec.mlp == "swiglu":
        return h + swiglu(bp["mlp"], x), 0.0
    if spec.mlp == "gelu":
        return h + gelu_mlp(bp["mlp"], x), 0.0
    out, aux = moe_mod.moe_block(bp["mlp"], cfg, x, mesh, dp_axes)
    return h + out, aux


def _apply_block(bp: dict, cfg: ArchConfig, spec: BlockSpec, h: jax.Array,
                 mesh=None, dp_axes=()):
    h = _apply_mixer(bp, cfg, spec, h)
    h, aux = _apply_mlp(bp, cfg, spec, h, mesh, dp_axes)
    return h, aux


def _resolve(cfg: ArchConfig, j: int, bp: dict, shared: Optional[dict]):
    spec = cfg.pattern[j]
    if spec.mixer == "shared_attn":
        return dataclasses.replace(spec, mixer="attn_global"), shared
    return spec, bp


# ---------------------------------------------------------------------------
# Forward (train / full-sequence eval)
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Build the input hidden states from tokens and/or frontend embeds."""
    if cfg.frontend == "audio_frames":
        return batch["frames"]
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        # decode steps carry no patches — they live in the KV cache
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    return h


def unembed(params: dict, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    if "head" in params:
        return h @ params["head"]
    return h @ params["embed"].T


def _slice_repeat(tree, r: int):
    return jax.tree.map(lambda x: x[r], tree)


def forward(params: dict, cfg: ArchConfig, batch: dict, *, mesh=None,
            dp_axes=(), remat: bool = False,
            unroll: bool = False, act_sharding=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], moe aux loss scalar).

    ``unroll=True`` replaces the layer scan with a python loop — used by the
    dry-run's depth-extrapolation (XLA cost_analysis counts a while body
    once) and available for perf experiments.

    ``act_sharding``: optional sharding (NamedSharding or PartitionSpec) for
    the [B, S, d] hidden states, re-asserted at every block boundary.
    Without it the SPMD partitioner is free to drop to replicated/feature-
    sharded activations inside the layer scan, which lowers to full-batch
    all-reduces (measured: 2.7 GB variadic all-reduces per layer in the
    FedDF distill step — see EXPERIMENTS §Perf-C)."""
    p, n_full, rem = _layout(cfg)
    h = embed_inputs(params, cfg, batch)
    shared = params.get("shared")

    def constrain(x):
        if act_sharding is None:
            return x
        return jax.lax.with_sharding_constraint(x, act_sharding)

    h = constrain(h)

    def repeat_body(carry, xs):
        h, aux = carry
        for j in range(p):
            spec, bp = _resolve(cfg, j, xs[j], shared)
            h, a = _apply_block(bp, cfg, spec, h, mesh, dp_axes)
            h = constrain(h)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(repeat_body) if remat else repeat_body
    aux0 = jnp.zeros((), jnp.float32)
    if n_full > 0 and unroll:
        carry = (h, aux0)
        for r in range(n_full):
            carry, _ = body(carry, _slice_repeat(params["blocks"], r))
        h, aux = carry
    elif n_full > 0:
        (h, aux), _ = jax.lax.scan(body, (h, aux0), params["blocks"])
    else:
        aux = aux0
    for j in range(rem):
        spec, bp = _resolve(cfg, j, params["tail"][j], shared)
        h, a = _apply_block(bp, cfg, spec, h, mesh, dp_axes)
        h = constrain(h)
        aux = aux + a
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Decode: cache construction + prefill + one-token step
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg: ArchConfig, spec: BlockSpec, batch: int,
                      max_seq: int, dtype):
    if spec.mixer == "mamba":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    local = spec.mixer == "attn_local"
    return attn.init_cache(cfg, local, batch, max_seq, dtype)


def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.float32) -> dict:
    p, n_full, rem = _layout(cfg)

    def stackn(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_full,) + x.shape),
                            tree)

    return {
        "blocks": tuple(
            stackn(_layer_cache_init(cfg, cfg.pattern[j], batch, max_seq,
                                     dtype))
            for j in range(p)),
        "tail": tuple(
            _layer_cache_init(cfg, cfg.pattern[j], batch, max_seq, dtype)
            for j in range(rem)),
    }


def cache_logical(cfg: ArchConfig) -> dict:
    p, n_full, rem = _layout(cfg)

    def one(spec: BlockSpec, stacked: bool):
        if spec.mixer == "mamba":
            ax = ssm_mod.ssm_cache_logical_axes()
        else:
            ax = attn.cache_logical_axes(spec.mixer == "attn_local")
        if stacked:
            ax = jax.tree.map(lambda t: ("layers",) + t, ax,
                              is_leaf=lambda x: isinstance(x, tuple)
                              and len(x) > 0
                              and all(isinstance(e, (str, type(None)))
                                      for e in x))
        return ax

    return {
        "blocks": tuple(one(cfg.pattern[j], True) for j in range(p)),
        "tail": tuple(one(cfg.pattern[j], False) for j in range(rem)),
    }


def _layer_decode(bp, cfg, spec, h, cache, cur_len):
    x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
    if spec.mixer == "mamba":
        out, new_cache = ssm_mod.ssm_decode_step(bp["mixer"], cfg, x, cache)
    else:
        local = spec.mixer == "attn_local"
        out, new_cache = attn.decode_step(bp["mixer"], cfg, x, cache, cur_len,
                                          local=local)
    return h + out, new_cache


def decode_step(params: dict, cfg: ArchConfig, batch: dict, caches: dict,
                cur_len: jax.Array, *, mesh=None, dp_axes=(),
                unroll: bool = False):
    """batch: one new token per sequence. Returns (logits [B,1,V], caches)."""
    p, n_full, rem = _layout(cfg)
    h = embed_inputs(params, cfg, batch)
    shared = params.get("shared")

    def repeat_body(carry, xs):
        h = carry
        bps, lcaches = xs
        new_caches = []
        for j in range(p):
            spec, bp = _resolve(cfg, j, bps[j], shared)
            h, nc = _layer_decode(bp, cfg, spec, h, lcaches[j], cur_len)
            h, _ = _apply_mlp(bp, cfg, spec, h, mesh, dp_axes)
            new_caches.append(nc)
        return h, tuple(new_caches)

    if n_full > 0 and unroll:
        outs = []
        for r in range(n_full):
            h, nc = repeat_body(h, (_slice_repeat(params["blocks"], r),
                                    _slice_repeat(caches["blocks"], r)))
            outs.append(nc)
        new_block_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    elif n_full > 0:
        h, new_block_caches = jax.lax.scan(
            repeat_body, h, (params["blocks"], caches["blocks"]))
    else:
        new_block_caches = caches["blocks"]
    new_tail = []
    for j in range(rem):
        spec, bp = _resolve(cfg, j, params["tail"][j], shared)
        h, nc = _layer_decode(bp, cfg, spec, h, caches["tail"][j], cur_len)
        h, _ = _apply_mlp(bp, cfg, spec, h, mesh, dp_axes)
        new_tail.append(nc)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params, cfg, h)
    return logits, {"blocks": new_block_caches, "tail": tuple(new_tail)}


def prefill(params: dict, cfg: ArchConfig, batch: dict, max_seq: int, *,
            mesh=None, dp_axes=(), unroll: bool = False, act_sharding=None):
    """Full-prompt forward that also populates decode caches."""
    p, n_full, rem = _layout(cfg)
    h = embed_inputs(params, cfg, batch)
    shared = params.get("shared")

    def constrain(x):
        if act_sharding is None:
            return x
        return jax.lax.with_sharding_constraint(x, act_sharding)

    h = constrain(h)

    def layer_prefill(bp, spec, h):
        x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
        if spec.mixer == "mamba":
            out, cache = ssm_mod.ssm_forward(bp["mixer"], cfg, x,
                                             return_cache=True)
        else:
            local = spec.mixer == "attn_local"
            out, cache = attn.prefill_cache(bp["mixer"], cfg, x, max_seq,
                                            local=local)
        return h + out, cache

    def repeat_body(h, bps):
        new_caches = []
        for j in range(p):
            spec, bp = _resolve(cfg, j, bps[j], shared)
            h, cache = layer_prefill(bp, spec, h)
            h, _ = _apply_mlp(bp, cfg, spec, h, mesh, dp_axes)
            h = constrain(h)
            new_caches.append(cache)
        return h, tuple(new_caches)

    if n_full > 0 and unroll:
        outs = []
        for r in range(n_full):
            h, nc = repeat_body(h, _slice_repeat(params["blocks"], r))
            outs.append(nc)
        block_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    elif n_full > 0:
        h, block_caches = jax.lax.scan(repeat_body, h, params["blocks"])
    else:
        block_caches = tuple({} for _ in range(p))
    tail_caches = []
    for j in range(rem):
        spec, bp = _resolve(cfg, j, params["tail"][j], shared)
        h, cache = layer_prefill(bp, spec, h)
        h, _ = _apply_mlp(bp, cfg, spec, h, mesh, dp_axes)
        tail_caches.append(cache)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h), {"blocks": block_caches,
                                     "tail": tuple(tail_caches)}

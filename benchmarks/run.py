"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines; JSON records land in
experiments/paper/.  Scale up with REPRO_BENCH_FULL=1.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig5]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (distill_bench, fig2_local_epochs,
                        fig4_heterogeneous, fig5_distill_sources,
                        fig6_distill_steps, kernels_bench, roofline_report,
                        round_engine_bench, table1_rounds_to_target,
                        table2_normalization, table3_dropworst,
                        table4_lowbit, table5_init_ablation,
                        table6_local_adam, table7_distill_optimizer)

MODULES = {
    "distill": distill_bench,
    "table1": table1_rounds_to_target,
    "table2": table2_normalization,
    "table3": table3_dropworst,
    "table4": table4_lowbit,
    "table5": table5_init_ablation,
    "table6": table6_local_adam,
    "table7": table7_distill_optimizer,
    "fig2": fig2_local_epochs,
    "fig4": fig4_heterogeneous,
    "fig5": fig5_distill_sources,
    "fig6": fig6_distill_steps,
    "kernels": kernels_bench,
    "roofline": roofline_report,
    "round_engine": round_engine_bench,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"{name},{(time.time()-t0)*1e6:.0f},FAILED:{type(e).__name__}")
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Quickstart: FedDF vs FedAvg through the declarative experiment API.

20 non-iid clients (Dirichlet alpha=0.1), 3-class toy task (the paper's
Fig. 1 setting), server-side ensemble distillation on an out-of-domain
unlabeled pool.  The entire run is described by one serializable
``ExperimentSpec`` — swap any component by registry name.

    PYTHONPATH=src python examples/quickstart.py

CI knobs: QUICKSTART_ROUNDS / QUICKSTART_SAMPLES shrink the run.
"""
import dataclasses
import os

from repro.api import (CohortSpec, Experiment, ExperimentSpec, FusionSpec,
                       ModelSpec, PartitionSpec, SourceSpec, StrategySpec,
                       TaskSpec)

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "10"))
SAMPLES = int(os.environ.get("QUICKSTART_SAMPLES", "6000"))

# --- one declarative spec: data, cohort, strategy, distillation source
spec = ExperimentSpec(
    # 3-class Gaussian blobs, heavily non-iid across 20 clients
    task=TaskSpec(name="blobs", n_samples=SAMPLES),
    partition=PartitionSpec(n_clients=20, alpha=0.1),
    # the paper's 3-layer MLP
    cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                            {"hidden": [64, 64, 64]})]),
    strategy=StrategySpec(name="feddf",
                          fusion=FusionSpec(max_steps=500, patience=250,
                                            eval_every=50, batch_size=64)),
    # unlabeled distillation data from ANOTHER domain (uniform square)
    source=SourceSpec(name="unlabeled", params={"n": 4000}),
    rounds=ROUNDS, client_fraction=0.4, local_epochs=20,
    local_batch_size=32, local_lr=0.05, seed=0)

print(spec.to_json())  # the run, as data — replayable via --config

for strategy in ("fedavg", "feddf"):
    s = dataclasses.replace(
        spec, strategy=dataclasses.replace(spec.strategy, name=strategy),
        source=spec.source if strategy == "feddf" else None)
    res = Experiment(s).run()
    curve = " ".join(f"{l.test_acc:.3f}" for l in res.result.logs)
    print(f"{strategy:7s} best={res.best_acc:.3f}  per-round: {curve}")

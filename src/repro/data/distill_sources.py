"""Distillation data sources for FedDF's server-side fusion (paper §3, §5,
Fig. 5): (1) an unlabeled dataset from another domain, (2) a frozen
generator's synthetic samples, (3) random noise (the paper's degenerate
control — "abrupt performance declination").

Every source exposes ``sample(key, batch_size) -> inputs`` so the fusion
loop is source-agnostic (the paper's point: FedDF is robust to the choice).

Sources backed by a finite pool additionally expose the indexable
interface the teacher-logit bank (``core/logit_bank.py``) builds on:
``pool()`` returns the full candidate array and ``sample_indices(key, b)``
returns the row indices ``sample`` would have drawn with the same key, so
``sample(key, b) == pool()[sample_indices(key, b)]`` holds exactly and the
fusion loop can gather precomputed teacher logits instead of re-running
the teachers.  Generator and noise sources synthesize inputs on the fly —
their ``pool()`` is None and distillation falls back to per-step teacher
forwards.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class DistillSource:
    def sample(self, key: jax.Array, batch_size: int):
        raise NotImplementedError

    def pool(self) -> Optional[np.ndarray]:
        """Full indexable candidate array [N, ...], or None when samples
        are synthesized on the fly (generator / noise): None disables the
        teacher-logit bank for this source."""
        return None

    def sample_indices(self, key: jax.Array, batch_size: int) -> jax.Array:
        """Row indices into :meth:`pool` such that
        ``sample(key, b) == pool()[sample_indices(key, b)]`` — any source
        returning a non-None pool must implement this (jit-traceable)."""
        raise NotImplementedError(
            f"{type(self).__name__} exposes no indexable pool")


@dataclasses.dataclass
class UnlabeledDataset(DistillSource):
    """Random minibatches from an unlabeled pool (labels, if present in the
    source dataset, are discarded — FedDF never uses them)."""

    x: np.ndarray

    def pool(self):
        return self.x

    def sample_indices(self, key, batch_size):
        return jax.random.randint(key, (batch_size,), 0, len(self.x))

    def sample(self, key, batch_size):
        return jnp.asarray(self.x)[self.sample_indices(key, batch_size)]


@dataclasses.dataclass
class GeneratorSource(DistillSource):
    """Frozen generator: pseudo-data = decoder(noise).

    The paper uses a pre-trained BigGAN generator; offline we use a frozen
    random-init MLP decoder whose outputs are matched to the data's first
    two moments — a *quality-degraded* generator, which is exactly the
    regime Fig. 5 probes (generator < real unlabeled < in-domain).
    """

    out_shape: tuple
    latent_dim: int = 16
    hidden: int = 64
    seed: int = 0
    mean: float = 0.0
    std: float = 1.0
    discrete_vocab: Optional[int] = None  # emit tokens if set

    def __post_init__(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(self.seed))
        out_dim = int(np.prod(self.out_shape))
        self._w1 = jax.random.normal(k1, (self.latent_dim, self.hidden)) * 0.5
        self._w2 = jax.random.normal(k2, (self.hidden, out_dim)) * 0.5

    def sample(self, key, batch_size):
        z = jax.random.normal(key, (batch_size, self.latent_dim))
        h = jnp.tanh(z @ self._w1)
        out = h @ self._w2
        out = self.mean + self.std * out / (jnp.std(out) + 1e-6)
        out = out.reshape((batch_size,) + tuple(self.out_shape))
        if self.discrete_vocab is not None:
            out = jnp.clip(jnp.abs(out * self.discrete_vocab / 3),
                           0, self.discrete_vocab - 1).astype(jnp.int32)
        return out


@dataclasses.dataclass
class RandomNoiseSource(DistillSource):
    """Uniform random inputs — the paper's 'dramatically different manifold'
    control."""

    out_shape: tuple
    low: float = -3.0
    high: float = 3.0
    discrete_vocab: Optional[int] = None

    def sample(self, key, batch_size):
        if self.discrete_vocab is not None:
            return jax.random.randint(
                key, (batch_size,) + tuple(self.out_shape), 0,
                self.discrete_vocab)
        return jax.random.uniform(
            key, (batch_size,) + tuple(self.out_shape),
            minval=self.low, maxval=self.high)

"""Shared option-literal sets for fusion knobs.

Single source of truth consumed by the runtime resolvers
(``core/logit_bank.py``, ``kernels/ops.py``) AND by the jax-free spec
validation (``api/spec.py``) — one place to extend when a new bank dtype
or kernel mode lands, so the two layers cannot drift.  Keep this module
dependency-free: spec.py must stay importable without jax.
"""
from __future__ import annotations

LOGIT_BANK_MODES = ("auto", "on", "off")
# float32 keeps bank trajectories bitwise-identical to on-the-fly; bfloat16
# halves the rows; int8 / fp8_e4m3 store quantized rows plus one fp32 scale
# per row (~4x smaller, dequantized inside the fused kernel)
BANK_DTYPES = ("float32", "bfloat16", "int8", "fp8_e4m3")
# the subset of BANK_DTYPES stored as (quantized rows, per-row fp32 scale)
QUANTIZED_BANK_DTYPES = ("int8", "fp8_e4m3")
FUSED_KERNEL_MODES = (True, False, "auto")

# step-count bucketing of the round engine's client axis
# (core/client.py:bucket_capacities, docs/bucketing.md)
BUCKET_KINDS = ("none", "pow2", "quantile")

# client arrival processes of the population traffic model
# (population/traffic.py, docs/population.md)
ARRIVAL_KINDS = ("always", "bernoulli")

# fault-injection / defense knobs (population/faults.py, docs/robustness.md)
# "auto" activates a defense exactly when any injection rate is > 0, which
# keeps fault-free configs bit-identical to historic trajectories
SCREEN_MODES = ("auto", "on", "off")
BYZANTINE_MODES = ("sign_flip", "scale")

# transports of the distributed runtime (repro.dist, docs/distributed.md):
# "loopback" runs the client pods as in-process threads over queue pairs
# (deterministic, CI-testable); "tcp" spawns one OS process per client pod
# connected over localhost sockets.  Wire-codec names live in the codec
# registry (repro.dist.frames.available_codecs), not here, so a new codec
# registers in exactly one place.
TRANSPORT_KINDS = ("loopback", "tcp")

"""Flight recorder / metrics registry / perf history (docs/observability.md).

 1. Disarmed is FREE and EXACT: ``span()`` hands back a shared no-op,
    and an armed-but-idle run reproduces the disarmed trajectory
    bit-identically across strategies and drivers.
 2. Armed spans are well-formed: monotonic timestamps, correct nesting
    (depth/parent), phase coverage of every RoundEngine phase, driver
    attribution, and a loadable JSONL stream.
 3. The metrics registry is one enumerable home for counters/gauges/
    histograms; the legacy ``TraceCounter`` aliases share its state;
    per-round streaming emits counter DELTAS through pluggable sinks.
 4. ``ObsSpec`` round-trips through JSON, rejects unknown keys, and old
    spec dicts (no ``obs`` section) load with defaults.
 5. Telemetry survives resume: an interrupted traced+streamed run,
    resumed, yields gap-free merged streams and the exact uninterrupted
    trajectory.
 6. The perf history is a validated, versioned contract:
    ``make/append/load/latest`` round-trip, malformed records fail
    loudly, and ``benchmarks.check_history`` gates regressions.
"""
import json
import os

import pytest

from repro.api import (CohortSpec, DriverSpec, Experiment, ExperimentSpec,
                       FusionSpec, ModelSpec, ObsSpec, PartitionSpec,
                       SourceSpec, StrategySpec, TaskSpec)
from repro.obs import history, metrics, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MemorySink,
                               MetricsObserver, MetricsRegistry, REGISTRY)


def small_fusion():
    return FusionSpec(max_steps=50, patience=50, eval_every=25,
                      batch_size=32)


def toy_spec(strategy="fedavg", rounds=2, driver=None, obs=None):
    return ExperimentSpec(
        task=TaskSpec(name="blobs", n_samples=1200),
        partition=PartitionSpec(n_clients=6, alpha=1.0),
        cohort=CohortSpec(prototypes=[ModelSpec("mlp",
                                                {"hidden": [16, 16]})]),
        strategy=StrategySpec(name=strategy, fusion=small_fusion()),
        source=(SourceSpec(name="unlabeled", params={"n": 500})
                if strategy == "feddf" else None),
        driver=driver or DriverSpec(),
        obs=obs or ObsSpec(),
        rounds=rounds, client_fraction=1.0, local_epochs=3,
        local_batch_size=32, local_lr=0.05, seed=0)


@pytest.fixture(autouse=True)
def _clean_recorder():
    trace.disarm()
    yield
    trace.disarm()


# ---------------------------------------------------------------------------
# trace: disarmed no-op, armed span stream
# ---------------------------------------------------------------------------

def test_disarmed_span_is_shared_noop():
    s1 = trace.span("anything", round=3)
    s2 = trace.span("else")
    assert s1 is s2  # one immortal null object, no allocation per call
    with s1 as sp:
        sp.annotate(k=1)  # no-op, no error
    assert trace.recorder() is None


def test_armed_spans_nest_and_load(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    trace.arm(path=path)
    trace.set_context(driver="sync")
    with trace.span("outer", round=0):
        with trace.span("inner", round=0):
            pass
    with trace.span("outer", round=1) as sp:
        sp.annotate(quarantined=2)
    trace.disarm()

    spans = trace.load_spans(path)
    assert [s["name"] for s in spans] == ["inner", "outer", "outer"]
    inner, outer0, outer1 = spans
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert outer0["depth"] == 0 and outer0["parent"] is None
    assert outer1["quarantined"] == 2
    for s in spans:
        assert s["t1"] >= s["t0"] >= 0.0
        assert s["dur_s"] == pytest.approx(s["t1"] - s["t0"])
        assert s["driver"] == "sync"
    # inner nests inside outer0's window
    assert outer0["t0"] <= inner["t0"] and inner["t1"] <= outer0["t1"]


def test_recorder_summary_totals_and_per_round(tmp_path):
    trace.arm(path=str(tmp_path / "s.jsonl"))
    for t in range(2):
        with trace.span("train_clients", round=t):
            pass
        with trace.span("join_fusion", round=t):
            pass
    rec = trace.recorder()
    s = rec.summary()
    assert s["n_spans"] == 4
    assert set(s["phase_totals_s"]) == {"train_clients", "join_fusion"}
    # idle gap is exactly the join seam total
    assert s["idle_gap_s"] == pytest.approx(
        s["phase_totals_s"]["join_fusion"])
    assert set(s["per_round"]) == {"0", "1"}
    assert "train_clients" in s["per_round"]["0"]


def test_rearm_closes_previous_recorder(tmp_path):
    trace.arm(path=str(tmp_path / "a.jsonl"))
    first = trace.recorder()
    trace.arm(path=str(tmp_path / "b.jsonl"))
    assert trace.recorder() is not first
    with trace.span("x"):
        pass
    trace.disarm()
    assert trace.load_spans(str(tmp_path / "a.jsonl")) == []
    assert len(trace.load_spans(str(tmp_path / "b.jsonl"))) == 1


# ---------------------------------------------------------------------------
# metrics registry + sinks
# ---------------------------------------------------------------------------

def test_registry_instruments():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    assert reg.counter("a.b") is c  # get-or-create shares state
    c.add(3)
    g = reg.gauge("g")
    h = reg.histogram("h")
    assert reg.snapshot() == {"a.b": 3}  # unset gauge/hist omitted
    g.set(7.5)
    h.observe(1.0)
    h.observe(3.0)
    snap = reg.snapshot()
    assert snap["g"] == 7.5
    assert snap["h"]["count"] == 2 and snap["h"]["mean"] == 2.0
    assert snap["h"]["min"] == 1.0 and snap["h"]["max"] == 3.0
    reg.reset()
    # reset zeroes counters (still enumerable) and clears gauge/hist
    assert reg.snapshot() == {"a.b": 0}


def test_registry_type_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


def test_trace_counter_alias_is_registry_counter():
    from repro.common.counters import TraceCounter
    assert TraceCounter is Counter
    # the migrated module singletons live in the global registry
    from repro.core.client import CLIENT_COMPILES
    assert REGISTRY.counter("core.client.compiles") is CLIENT_COMPILES


class _Event:
    def __init__(self, round, test_acc, val_acc):
        self.round, self.group = round, 0
        self.log = type("L", (), {"test_acc": test_acc,
                                  "val_acc": val_acc})()


def test_metrics_observer_emits_counter_deltas():
    reg = MetricsRegistry()
    c = reg.counter("n.compiles")
    sink = MemorySink()
    obs = MetricsObserver([sink], registry=reg)
    c.add(5)
    obs(_Event(0, 0.5, 0.4))
    c.add(2)
    obs(_Event(1, 0.6, 0.5))
    obs.close()
    r0, r1 = sink.records
    assert (r0["round"], r0["n.compiles"]) == (0, 5)
    assert (r1["round"], r1["n.compiles"]) == (1, 2)  # delta, not total
    assert r1["test_acc"] == 0.6


# ---------------------------------------------------------------------------
# ObsSpec
# ---------------------------------------------------------------------------

def test_obs_spec_round_trip():
    spec = toy_spec(obs=ObsSpec(trace=True, metrics_dir="m"))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.obs.enabled


def test_obs_spec_unknown_key_rejected():
    d = toy_spec().to_dict()
    d["obs"]["tracing"] = True
    with pytest.raises(ValueError, match="unknown"):
        ExperimentSpec.from_dict(d)


def test_old_spec_without_obs_loads_with_defaults():
    d = toy_spec().to_dict()
    del d["obs"]
    spec = ExperimentSpec.from_dict(d)
    assert spec.obs == ObsSpec()
    assert not spec.obs.enabled


def test_profile_without_dir_fails_validation():
    with pytest.raises(ValueError, match="profile_dir"):
        toy_spec(obs=ObsSpec(profile=True)).validate()


# ---------------------------------------------------------------------------
# end-to-end: bit-identity, summary surface, resume telemetry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,driver", [
    ("fedavg", None),
    ("feddf", None),
    ("fedavg", "buffered_async"),
])
def test_armed_idle_trajectory_bit_identical(tmp_path, strategy, driver):
    drv = DriverSpec(kind=driver) if driver else None
    plain = Experiment(toy_spec(strategy=strategy, driver=drv)).run()
    armed = Experiment(toy_spec(
        strategy=strategy, driver=drv,
        obs=ObsSpec(trace=True,
                    trace_path=str(tmp_path / "spans.jsonl"),
                    metrics_dir=str(tmp_path / "m")))).run()
    assert armed.result.logs == plain.result.logs
    assert plain.obs is None and armed.obs is not None
    assert armed.summary()["obs"]["n_spans"] > 0
    assert "per_round" in armed.summary()["obs"]
    # every engine phase shows up in the armed run's breakdown
    # (buffered_async samples cohorts through the population subsystem,
    # not engine.sample_cohort, and nests waves under "fill")
    phases = set(armed.obs["phase_totals_s"])
    assert {"build_round_batches", "train_clients",
            "aggregate", "evaluate_round"} <= phases
    if driver is None:
        assert "sample_cohort" in phases
    else:
        assert {"fill", "wave"} <= phases
    spans = trace.load_spans(str(tmp_path / "spans.jsonl"))
    assert spans and all("t1" in s for s in spans)
    # metrics stream: one record per (round, group) with counter columns
    lines = [json.loads(l) for l in
             open(tmp_path / "m" / "metrics.jsonl")]
    # rounds are 1-based in RoundEvent
    assert [r["round"] for r in lines] == list(range(1, len(lines) + 1))
    assert all("core.client.compiles" in r for r in lines)
    assert os.path.exists(tmp_path / "m" / "metrics.csv")


class _StopAfter(Exception):
    pass


def test_telemetry_across_resume_gap_free(tmp_path):
    """Kill a traced+streamed run mid-flight; the resumed run appends to
    the same streams (gap-free rounds) and reproduces the uninterrupted
    disarmed trajectory exactly."""
    obs = ObsSpec(trace=True, trace_path=str(tmp_path / "spans.jsonl"),
                  metrics_dir=str(tmp_path / "m"))
    plain = Experiment(toy_spec(strategy="fedavg", rounds=4)).run()

    def bomb(event):
        if event.round == 3:
            raise _StopAfter

    ckpt_dir = str(tmp_path / "run")
    with pytest.raises(_StopAfter):
        Experiment(toy_spec(strategy="fedavg", rounds=4, obs=obs)).run(
            observers=[bomb], checkpoint_dir=ckpt_dir)
    assert trace.recorder() is None  # disarmed even on the error path

    resumed = Experiment.resume(ckpt_dir)
    assert resumed.result.logs == plain.result.logs  # bit-identical

    rounds = [json.loads(l)["round"]
              for l in open(tmp_path / "m" / "metrics.jsonl")]
    # appended, not truncated: both segments present, no round missing
    # (rounds are 1-based in RoundEvent)
    assert sorted(set(rounds)) == [1, 2, 3, 4]
    spans = trace.load_spans(str(tmp_path / "spans.jsonl"))
    seen = {s.get("round") for s in spans if "round" in s}
    assert {1, 2, 3, 4} <= seen  # both segments' engine spans present


# ---------------------------------------------------------------------------
# perf history contract
# ---------------------------------------------------------------------------

def test_history_round_trip(tmp_path):
    path = str(tmp_path / "h.jsonl")
    rec = history.make_record("driver", {"speedup": 1.4}, case="toy",
                              config={"K": 8})
    history.append(rec, path=path)
    history.append(history.make_record("driver", {"speedup": 1.6},
                                       case="toy"), path=path)
    back = history.load(path)
    assert len(back) == 2 and back[0] == rec
    assert back[0]["schema_version"] == history.SCHEMA_VERSION
    assert back[0]["machine"]["python"]
    assert back[0]["config"] == {"K": 8}
    latest = history.latest(path)
    assert latest[("driver", "toy")]["metrics"]["speedup"] == 1.6


def test_history_validation_fails_loudly(tmp_path):
    rec = history.make_record("b", {})
    bad = dict(rec)
    bad["extra_key"] = 1
    with pytest.raises(ValueError, match="unknown"):
        history.validate_record(bad)
    missing = {k: v for k, v in rec.items() if k != "machine"}
    with pytest.raises(ValueError, match="missing"):
        history.validate_record(missing)
    wrong = dict(rec, schema_version=99)
    with pytest.raises(ValueError, match="schema_version"):
        history.validate_record(wrong)
    path = str(tmp_path / "h.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps(rec) + "\n")
        f.write("{\"not\": \"a record\"}\n")
    with pytest.raises(ValueError, match=":2"):
        history.load(path)


def test_history_load_absent_is_empty(tmp_path):
    assert history.load(str(tmp_path / "nope.jsonl")) == []
    assert history.latest(str(tmp_path / "nope.jsonl")) == {}


def _with_cpus(rec, cpus):
    rec = dict(rec)
    rec["machine"] = dict(rec["machine"], cpus=cpus)
    return rec


def test_check_history_gates(tmp_path):
    from benchmarks import check_history
    path = str(tmp_path / "h.jsonl")
    good = {"speedup": 1.4, "async_staleness0": {"trajectory_equal": True}}
    history.append(_with_cpus(history.make_record("driver", good), 4),
                   path=path)
    assert check_history.check(path) == []
    assert check_history.main(["--history", path,
                               "--require", "driver"]) == 0
    # a required-but-absent bench fails
    assert check_history.main(["--history", path,
                               "--require", "bucketing"]) == 1
    # a regressed latest record fails with the same threshold text
    bad = {"speedup": 1.05, "async_staleness0": {"trajectory_equal": True}}
    history.append(_with_cpus(history.make_record("driver", bad), 4),
                   path=path)
    failures = check_history.check(path)
    assert failures and "overlap speedup regressed" in failures[0]
    assert check_history.main(["--history", path]) == 1


def test_check_history_one_core_skips_overlap_gates(tmp_path, capsys):
    """A 1-core machine fingerprint can't demonstrate thread overlap:
    those sub-gates SKIP (visibly) instead of failing — or passing."""
    from benchmarks import check_history
    path = str(tmp_path / "h.jsonl")
    # speedup 1.0 would FAIL on a multi-core record; on one core it skips
    m = {"speedup": 1.0, "async_staleness0": {"trajectory_equal": True}}
    history.append(_with_cpus(history.make_record("driver", m), 1),
                   path=path)
    pop = {"buffered_degenerate": {"trajectory_equal": True},
           "uploads_ratio": 1.0, "final_acc_drift": 0.0}
    history.append(_with_cpus(history.make_record("population", pop), 1),
                   path=path)
    assert check_history.check(path) == []
    out = capsys.readouterr().out
    assert "SKIP driver" in out and "1-core machine" in out
    assert "SKIP population" in out
    # the correctness sub-gates of the same record still fail
    m_bad = {"speedup": 1.0,
             "async_staleness0": {"trajectory_equal": False}}
    history.append(_with_cpus(history.make_record("driver", m_bad), 1),
                   path=path)
    failures = check_history.check(path)
    assert failures and "trajectory drifted" in failures[0]

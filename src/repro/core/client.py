"""Client-side local training (Algorithm 2).

One jit-compiled ``lax.scan`` runs all local steps of a round: the batches
for every epoch are materialised as arrays [n_steps, B, ...] outside and
scanned inside — orders of magnitude faster than a python loop on CPU, and
the compiled function is reused across clients and rounds (same shapes).

Two entry points:

* :func:`make_local_update` — one client per call (the original path, kept
  for tests/benchmarks and as the numerical reference).
* :func:`make_batched_local_update` — ALL active clients of a round at
  once: batch tensors are stacked to [K, n_steps, B, ...] and one jitted
  ``vmap``-over-clients ``lax.scan`` trains every client in a single
  compiled program (see docs/round_engine.md).  FedProx anchoring,
  quantized forwards, and DP privatization of the uploads all run inside
  the jitted path; an optional mesh shards the leading client axis across
  devices (``shard_map``) so clients train data-parallel.

Supports: plain SGD (FedAvg), proximal term (FedProx, Appendix B), arbitrary
optimizers (the paper's Adam-local-training ablation, Table 6), BatchNorm
running-stats maintenance, and a quantize transform for low-bit clients
(Table 4, straight-through estimator).
"""
from __future__ import annotations

import weakref
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import REGISTRY
from repro.common.pytree import tree_sq_dist
from repro.core.nets import Net
from repro.optim.optimizers import Optimizer, apply_updates

# Counts TRACES of the batched client update (the python side effect only
# fires when jax re-traces, i.e. compiles a new program) — the bucketing
# tests' evidence that compile count stays bounded by buckets x prototypes
# per run instead of growing with rng-driven cohort shapes.  Registered
# in the unified metrics registry; this module-level alias keeps the
# historic reset()/.count interface for tests.
CLIENT_COMPILES = REGISTRY.counter("core.client.compiles")


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))


def make_local_update(net: Net, opt: Optimizer, *, prox_mu: float = 0.0,
                      quantize: Optional[Callable] = None):
    """Returns jit'd fn(params, xb [n,B,...], yb [n,B], anchor) -> params.

    ``anchor`` is the round's global model (FedProx pulls towards it; pass
    the initial params when prox_mu == 0, it is ignored).
    """

    def loss_fn(params, x, y):
        p = quantize(params) if quantize is not None else params
        logits, stats = net.apply_with_stats(p, x)
        loss = softmax_xent(logits, y)
        return loss, stats

    @jax.jit
    def run(params, xb, yb, anchor):
        state = opt.init(params)
        mask = net.trainable_mask(params)

        def step(carry, batch):
            params, state, i = carry
            x, y = batch

            def total_loss(p):
                loss, stats = loss_fn(p, x, y)
                if prox_mu > 0.0:
                    loss = loss + 0.5 * prox_mu * tree_sq_dist(p, anchor)
                return loss, stats

            grads, stats = jax.grad(total_loss, has_aux=True)(params)
            grads = jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                                 grads, mask)
            deltas, state = opt.update(grads, state, params, i)
            new_params = apply_updates(params, deltas)
            # take BN running stats from the forward pass (non-trainable)
            new_params = jax.tree.map(
                lambda new, st, m: new if m else st.astype(new.dtype),
                new_params, stats, mask)
            return (new_params, state, i + 1), None

        (params, _, _), _ = jax.lax.scan(step, (params, state, jnp.int32(0)),
                                         (xb, yb))
        return params

    return run


def make_batched_local_update(net: Net, opt: Optimizer, *,
                              prox_mu: float = 0.0,
                              quantize: Optional[Callable] = None,
                              dp_clip: Optional[float] = None,
                              dp_noise_multiplier: float = 0.0,
                              mesh=None, client_axis: str = "data",
                              donate_batches: bool = False):
    """Vectorized local training for all K active clients of a round.

    Returns jit'd ``fn(params, xb [K,n,B,...], yb [K,n,B], anchor,
    step_mask [K,n], dp_keys [K,2]) -> stacked params [K, ...]``.

    ``step_mask`` pads clients with fewer local steps: masked steps leave
    params, optimizer state, and the step counter untouched, so each
    client's trajectory is numerically identical to the sequential
    :func:`make_local_update` run on its own (unpadded) batches.

    When ``dp_clip`` is set, every client's upload is clipped + noised
    (``core/privacy.py``) inside the same jitted program, keyed per client
    by ``dp_keys``.  With a ``mesh``, the leading client axis is sharded
    over ``client_axis`` via ``shard_map`` (K must divide the axis size)
    so clients train data-parallel across devices.

    ``donate_batches=True`` donates the per-round scratch tensors
    (``xb``/``yb``/``step_mask``/``dp_keys``) so XLA reuses their (large)
    buffers instead of reallocating every round — the engine rebuilds
    them each round and never reads them back.  ``params``/``anchor`` are
    deliberately NOT donated: the engine passes the same globals buffer
    to every group and reads it again after training.  Callers that reuse
    their batch arrays across calls (benchmarks) must keep the default.
    """

    def loss_fn(params, x, y):
        p = quantize(params) if quantize is not None else params
        logits, stats = net.apply_with_stats(p, x)
        loss = softmax_xent(logits, y)
        return loss, stats

    def one_client(params, xb, yb, anchor, step_mask, dp_key):
        state = opt.init(params)
        mask = net.trainable_mask(params)

        def step(carry, batch):
            params, state, i = carry
            x, y, valid = batch

            def total_loss(p):
                loss, stats = loss_fn(p, x, y)
                if prox_mu > 0.0:
                    loss = loss + 0.5 * prox_mu * tree_sq_dist(p, anchor)
                return loss, stats

            grads, stats = jax.grad(total_loss, has_aux=True)(params)
            grads = jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                                 grads, mask)
            deltas, new_state = opt.update(grads, state, params, i)
            new_params = apply_updates(params, deltas)
            new_params = jax.tree.map(
                lambda new, st, m: new if m else st.astype(new.dtype),
                new_params, stats, mask)
            # padded steps are no-ops: keep the whole carry unchanged
            keep = lambda n, o: jnp.where(valid, n, o)
            params = jax.tree.map(keep, new_params, params)
            state = jax.tree.map(keep, new_state, state)
            return (params, state, jnp.where(valid, i + 1, i)), None

        (params, _, _), _ = jax.lax.scan(step, (params, state, jnp.int32(0)),
                                         (xb, yb, step_mask))
        if dp_clip is not None:
            from repro.core.privacy import privatize_update
            params = privatize_update(anchor, params, clip=dp_clip,
                                      noise_multiplier=dp_noise_multiplier,
                                      key=dp_key)
        return params

    batched = jax.vmap(one_client, in_axes=(None, 0, 0, None, 0, 0))

    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        from repro.common.sharding import shard_map
        rep, cl = P(), P(client_axis)
        batched = shard_map(batched, mesh,
                            in_specs=(rep, cl, cl, rep, cl, cl),
                            out_specs=cl, check=False)

    def counted(params, xb, yb, anchor, step_mask, dp_keys):
        CLIENT_COMPILES.add(1)  # trace-time side effect: counts compiles
        return batched(params, xb, yb, anchor, step_mask, dp_keys)

    from repro.common.sharding import donation_supported
    donate = ((1, 2, 4, 5) if donate_batches and donation_supported()
              else ())
    return jax.jit(counted, donate_argnums=donate)


def build_batches(x: np.ndarray, y: np.ndarray, batch_size: int, epochs: int,
                  seed: int):
    """[n_steps, B, ...] arrays for the scanned local update."""
    rng = np.random.default_rng(seed)
    n = len(y)
    steps_per_epoch = max(1, n // batch_size)
    xs, ys = [], []
    for _ in range(epochs):
        if n >= batch_size:
            order = rng.permutation(n)[: steps_per_epoch * batch_size]
        else:
            order = rng.choice(n, size=batch_size, replace=True)
        xe = x[order].reshape(steps_per_epoch, batch_size, *x.shape[1:])
        ye = y[order].reshape(steps_per_epoch, batch_size)
        xs.append(xe)
        ys.append(ye)
    return np.concatenate(xs), np.concatenate(ys)


def n_local_steps(n_samples: int, batch_size: int, epochs: int) -> int:
    """Scan length :func:`build_batches` produces for a client of
    ``n_samples`` examples."""
    return epochs * max(1, n_samples // batch_size)


def build_batched_batches(x: np.ndarray, y: np.ndarray,
                          parts: Sequence[np.ndarray], batch_size: int,
                          epochs: int, seeds: Sequence[int],
                          n_steps: Optional[int] = None):
    """Stack every active client's scanned batches to one round tensor.

    Returns ``(xb [K,n,B,...], yb [K,n,B], step_mask [K,n])``.  Clients with
    fewer steps than ``n_steps`` (or the round maximum) are zero-padded at
    the END and masked out, preserving step-for-step equivalence with the
    sequential path.  Pass a fixed ``n_steps`` (max over ALL clients) so
    every round reuses one compiled program.
    """
    per = [build_batches(x[idx], y[idx], batch_size, epochs, seed=s)
           for idx, s in zip(parts, seeds)]
    steps = [xb.shape[0] for xb, _ in per]
    n = max(steps) if n_steps is None else n_steps
    if n < max(steps):
        raise ValueError(f"n_steps={n} < max client steps {max(steps)}")
    k = len(per)
    xb = np.zeros((k, n) + per[0][0].shape[1:], per[0][0].dtype)
    yb = np.zeros((k, n) + per[0][1].shape[1:], per[0][1].dtype)
    step_mask = np.zeros((k, n), bool)
    for i, (xk, yk) in enumerate(per):
        xb[i, : len(xk)] = xk
        yb[i, : len(yk)] = yk
        step_mask[i, : len(xk)] = True
    return xb, yb, step_mask


# ---------------------------------------------------------------------------
# step-count bucketing (docs/bucketing.md)
#
# Padding every client of a prototype group to the group-wide maximum scan
# length is what makes ONE compiled program per prototype possible, but on
# a skewed Dirichlet split the largest client can have 10-50x the steps of
# the median, so most vmapped lanes burn masked no-op FLOPs.  Bucketing
# partitions the clients into a small FIXED set of step capacities
# (computed once per run from the static per-client step counts) and runs
# one vmapped scan per bucket: a 10-step client no longer scans 500 padded
# steps, and the compile count stays bounded by buckets x prototypes.
# ---------------------------------------------------------------------------


def bucket_capacities(step_counts: Sequence[int], kind: str,
                      max_buckets: int = 4) -> List[int]:
    """The run-fixed set of scan-length capacities for one prototype group.

    Returns an ascending list whose LAST entry is exactly
    ``max(step_counts)`` (so a single bucket reproduces the unbucketed
    path bit-for-bit) and whose length is ``<= max_buckets``.

    ``pow2``      capacities are powers of two clipped at the maximum; when
                  that yields more than ``max_buckets``, the LARGEST
                  capacities are kept (small clients fall into bigger
                  buckets — more padding, never a truncated scan).
    ``quantile``  capacities at ``max_buckets`` evenly-spaced quantiles of
                  the step-count distribution (always including the max).
    ``none``      the single group-wide maximum: today's padded path.
    """
    steps = sorted(int(s) for s in step_counts)
    if not steps:
        return [1]
    smax = steps[-1]
    if kind == "none" or max_buckets <= 1 or steps[0] == smax:
        return [smax]
    if kind == "pow2":
        caps = sorted({min(1 << (int(s) - 1).bit_length() if s > 1 else 1,
                           smax) for s in steps} | {smax})
        return caps[-max_buckets:]
    if kind == "quantile":
        qs = [steps[min(len(steps) - 1,
                        int(np.ceil((i + 1) / max_buckets * len(steps))) - 1)]
              for i in range(max_buckets)]
        return sorted(set(qs) | {smax})
    raise ValueError(f"unknown bucket kind {kind!r}; expected one of "
                     f"('none', 'pow2', 'quantile')")


def assign_buckets(step_counts: Sequence[int],
                   caps: Sequence[int]) -> np.ndarray:
    """Index of the smallest capacity holding each client's step count."""
    idx = np.searchsorted(np.asarray(caps), np.asarray(step_counts),
                          side="left")
    if (idx >= len(caps)).any():
        raise ValueError(f"step count(s) exceed the largest bucket "
                         f"capacity {caps[-1]}")
    return idx


def build_bucketed_batches(
        x: np.ndarray, y: np.ndarray, parts: Sequence[np.ndarray],
        batch_size: int, epochs: int, seeds: Sequence[int],
        caps: Sequence[int],
) -> List[Tuple[int, np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Bucketed variant of :func:`build_batched_batches`.

    Partitions the clients over the run-fixed ``caps`` (ascending scan
    capacities, see :func:`bucket_capacities`) and stacks each bucket's
    scanned batches separately, padded only to the BUCKET's capacity.

    Returns one ``(bucket_index, positions, xb, yb, step_mask)`` tuple per
    non-empty bucket, where ``positions`` are the clients' indices into
    ``parts`` — each client's batch stream is byte-identical to the one
    :func:`build_batched_batches` builds (same per-client seeds, same
    order), only the zero-padded tail is shorter.
    """
    steps = [n_local_steps(len(idx), batch_size, epochs) for idx in parts]
    which = assign_buckets(steps, caps)
    out = []
    for b in range(len(caps)):
        pos = np.flatnonzero(which == b)
        if not len(pos):
            continue
        xb, yb, mask = build_batched_batches(
            x, y, [parts[i] for i in pos], batch_size, epochs,
            seeds=[seeds[i] for i in pos], n_steps=int(caps[b]))
        out.append((b, pos, xb, yb, mask))
    return out


# jitted eval fns, cached per Net.  Weak keys: an id()-keyed dict could hand
# back a stale jitted fn for a different net once ids are reused after GC.
_EVAL_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_STACKED_EVAL_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _eval_fn(net: Net):
    fn = _EVAL_CACHE.get(net)
    if fn is None:
        # close over the apply fn, NOT the Net: a value that referenced its
        # weak key would pin the entry alive forever (no eviction)
        apply = net.apply
        fn = jax.jit(lambda pp, xx: jnp.argmax(apply(pp, xx, train=False),
                                               axis=-1))
        _EVAL_CACHE[net] = fn
    return fn


def stacked_logits_fn(net: Net):
    """Cached jitted fn(stacked params [K,...], x [B,...]) -> [K, B, C]."""
    fn = _STACKED_EVAL_CACHE.get(net)
    if fn is None:
        apply = net.apply  # see _eval_fn: never reference the weak key
        fn = jax.jit(jax.vmap(lambda p, xx: apply(p, xx, train=False),
                              in_axes=(0, None)))
        _STACKED_EVAL_CACHE[net] = fn
    return fn


def evaluate_stacked(net: Net, stack, x: np.ndarray, y: np.ndarray,
                     batch_size: int = 512) -> np.ndarray:
    """Per-client top-1 accuracies [K] from a stacked parameter pytree —
    one vmapped forward instead of K python-loop evaluations."""
    fn = stacked_logits_fn(net)
    k = jax.tree.leaves(stack)[0].shape[0]
    correct = np.zeros(k)
    for s in range(0, len(y), batch_size):
        logits = fn(stack, jnp.asarray(x[s : s + batch_size]))
        pred = np.asarray(jnp.argmax(logits, axis=-1))        # [K, b]
        correct += (pred == np.asarray(y[s : s + batch_size])[None]).sum(-1)
    return correct / len(y)


def evaluate(net: Net, params: dict, x: np.ndarray, y: np.ndarray,
             batch_size: int = 512, quantize: Optional[Callable] = None
             ) -> float:
    """Top-1 accuracy in eval mode (BN uses running stats)."""
    p = quantize(params) if quantize is not None else params
    apply = _eval_fn(net)
    correct = 0
    for s in range(0, len(y), batch_size):
        xb = jnp.asarray(x[s : s + batch_size])
        yb = y[s : s + batch_size]
        pred = np.asarray(apply(p, xb))
        correct += int((pred == yb).sum())
    return correct / len(y)

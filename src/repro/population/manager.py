"""Population manager: wave dispatch, upload buffer, virtual clock.

Glue between the traffic model, the client registry, the cohort sampler
and the buffered-async driver.  Time is *virtual*: waves are dispatched
at the current clock, each upload becomes ready ``latency`` seconds
later, and consuming an upload advances the clock to its ready time —
so a trace is fully deterministic and independent of wall time.

The buffer is a min-heap ordered by ``(ready, seq)``: FedBuff-style
aggregation pops the M earliest-ready uploads; anything staler than
``max_staleness`` rounds at pop time is dropped (with telemetry) rather
than fused.  The whole manager state — registry arrays, clock, wave /
sequence counters and the pending heap (client model deltas included) —
round-trips through ``checkpoint/io.py`` so a resumed buffered run
replays the exact same schedule.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.pytree import tree_check_like, tree_take
from repro.population.config import FaultConfig, PopulationConfig
from repro.population.registry import ClientRegistry
from repro.population.scheduler import CohortSampler
from repro.population.traffic import TrafficModel

_UPLOAD_FIELDS = ("client", "part", "proto", "wave", "base_version",
                  "ready", "seq", "latency", "weight", "attempt")

# Upload fields absent from pre-PR 8 checkpoints load with these defaults.
_UPLOAD_DEFAULTS = {"attempt": 0}


@dataclasses.dataclass
class Upload:
    """One client's trained parameters in flight to the server."""
    client: int         # population id
    part: int           # data partition backing the client
    proto: int          # prototype group
    wave: int           # dispatch wave (also the batch-seed round index)
    base_version: int   # completed fusions when the wave was dispatched
    ready: float        # virtual arrival time
    seq: int            # tie-break / FIFO order
    latency: float      # drawn upload latency
    weight: float       # aggregation weight (client data size)
    params: Any         # [1, ...] stacked-pytree slice of trained params
    attempt: int = 0    # retry count that produced this upload

    def to_dict(self) -> Dict[str, Any]:
        d = {f: getattr(self, f) for f in _UPLOAD_FIELDS}
        d["params"] = self.params
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Upload":
        kw = {f: d.get(f, _UPLOAD_DEFAULTS.get(f)) for f in _UPLOAD_FIELDS}
        for f in ("client", "part", "proto", "wave", "base_version", "seq",
                  "attempt"):
            kw[f] = int(kw[f])
        kw["ready"] = float(kw["ready"])
        kw["latency"] = float(kw["latency"])
        kw["weight"] = float(kw["weight"])
        return cls(params=d["params"], **kw)


class PopulationManager:
    """Traffic-driven upload production/consumption over a population."""

    def __init__(self, cfg: PopulationConfig, *, seed: int,
                 n_partitions: int, partition_sizes: Sequence[int],
                 client_steps: Sequence[int], client_proto: Sequence[int],
                 client_bucket: Sequence[int], n_active: int,
                 sampler: CohortSampler,
                 faults: Optional[FaultConfig] = None):
        cfg.validate()
        self.cfg = cfg
        self.size = int(cfg.size or n_partitions)
        self.registry = ClientRegistry(self.size, partition_sizes,
                                       client_steps, client_proto,
                                       client_bucket)
        self.traffic = TrafficModel(cfg.traffic, seed, self.size)
        self.sampler = sampler
        self.n_active = int(n_active)
        self.buffer_size = int(cfg.buffer_size or n_active)
        self.clock = 0.0
        self.wave = 0          # last dispatched wave index
        self.seq = 0           # monotone upload counter
        self._heap: List[Tuple[float, int, Upload]] = []
        # telemetry accumulated between pops
        self._dropped_since = 0
        self._stale_since = 0
        # fault injection + screening (docs/robustness.md); both stay None
        # for fault-free configs so push_wave is byte-for-byte the
        # historic path
        self.faults = faults if faults is not None and faults.enabled \
            else None
        self.fault_model = None
        self.screen = None
        if self.faults is not None:
            from repro.population.faults import FaultModel, NormScreen
            self.fault_model = FaultModel(self.faults, seed, self.size)
            if self.faults.screen_active:
                self.screen = NormScreen(sigma=self.faults.norm_sigma)
        self._corrupted_since = 0
        self._quarantined_since = 0
        self._retries_since = 0
        self._upload_spec: Dict[int, Any] = {}

    # -- dispatch --------------------------------------------------------

    def available(self, wave: int) -> Optional[np.ndarray]:
        """Reachable, not-in-flight clients for ``wave``.

        Returns ``None`` when *every* client is available, so the uniform
        sampler can take its bit-identical historic ``rng.choice(N, k)``
        path.
        """
        online = self.traffic.online_mask(wave)
        free = online & ~self.registry.in_flight
        if free.all():
            return None
        return np.flatnonzero(free)

    def next_wave(self, rng: np.random.Generator):
        """Draw and dispatch the next cohort; returns ``(wave, cohort)``."""
        w = self.wave + 1
        cohort = self.sampler.sample(rng, self.n_active,
                                     available=self.available(w), tick=w)
        if len(cohort) == 0:
            raise RuntimeError(
                f"wave {w}: no clients available to dispatch "
                f"(population={self.size}, in-flight="
                f"{int(self.registry.in_flight.sum())}); grow the "
                f"population or lower the traffic dropout/arrival skew")
        self.wave = w
        self.registry.record_dispatch(cohort, w)
        return w, cohort

    def _check_upload(self, p: int, g, params) -> None:
        """Wire-safety: the upload's pytree must match the prototype's
        expected [1, ...]-stacked structure (shapes, dtypes, leaf paths).
        Metadata-only — no device transfer, no trajectory effect."""
        ref = self._upload_spec.get(p)
        if ref is None:
            import jax
            # the [K, ...] trained stack defines the prototype's wire
            # contract: every upload must be a [1, ...] slice of it
            ref = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((1,) + tuple(x.shape[1:]),
                                               x.dtype), g.stack)
            self._upload_spec[p] = ref
        tree_check_like(params, ref, what=f"proto {p} upload")

    def _inject_and_screen(self, wave: int, c: int, p: int, g, params):
        """Fault seam for one upload: corrupt, screen, retry.

        Returns ``(params, attempt, backoff_delay)`` for an accepted
        upload, or ``None`` when every attempt was rejected (the client is
        quarantined).  Counter-based draws keyed on (wave, client,
        attempt) mean a resumed trace corrupts identically and a retry
        redraws only the transport faults — byzantine clients fail every
        attempt and sink in the sampler.
        """
        import jax
        import jax.numpy as jnp

        from repro.population.faults import delta_norm, leaves_finite
        flat, treedef = jax.tree.flatten(params)
        clean = [np.asarray(l)[0] for l in flat]
        base = [np.asarray(l) for l in jax.tree.leaves(g.prev_global)]
        faults = self.faults
        for attempt in range(faults.retries + 1):
            if attempt > 0:
                self._retries_since += 1
            row, kinds = self.fault_model.corrupt(wave, c, clean, base,
                                                  attempt=attempt)
            if attempt == 0 and kinds:
                self._corrupted_since += 1
            if self.screen is not None:
                if not leaves_finite(row):
                    continue
                ok, _ = self.screen.check(p, delta_norm(row, base))
                if not ok:
                    continue
            if kinds:
                params = jax.tree.unflatten(
                    treedef, [jnp.asarray(r[None]) for r in row])
            # exponential backoff: attempt k re-arrives backoff^k virtual
            # seconds later than the clean upload would have
            delay = (faults.backoff ** attempt) - 1.0 if attempt else 0.0
            return params, attempt, delay
        self.registry.record_quarantine([c])
        self.sampler.penalize([c], float(self.registry.priority[c]))
        self._quarantined_since += 1
        return None

    def push_wave(self, wave: int, cohort: np.ndarray, groups,
                  base_version: int) -> int:
        """Split trained group stacks into per-client buffered uploads.

        ``groups[p].stack`` rows are in cohort order filtered by
        prototype (the engine's ``ks`` order), so a per-proto cursor
        recovers each client's row.  Each upload is structure-validated
        against its prototype, then (when faults are configured) run
        through the inject/screen/retry seam — rejected uploads quarantine
        their client instead of entering the buffer.  Returns the number
        of uploads buffered.
        """
        latency, dropped = self.traffic.upload_draws(wave, cohort)
        cursor = [0] * len(groups)
        pushed = 0
        for j, c in enumerate(cohort):
            c = int(c)
            p = int(self.registry.proto[c])
            row = cursor[p]
            cursor[p] += 1
            if dropped[j]:
                self.registry.record_dropout([c])
                self._dropped_since += 1
                continue
            g = groups[p]
            params = tree_take(g.stack, np.asarray([row]))
            self._check_upload(p, g, params)
            attempt, delay = 0, 0.0
            if self.fault_model is not None:
                res = self._inject_and_screen(wave, c, p, g, params)
                if res is None:
                    continue
                params, attempt, delay = res
            self.seq += 1
            up = Upload(client=c, part=int(self.registry.partition[c]),
                        proto=p, wave=wave, base_version=int(base_version),
                        ready=self.clock + float(latency[j]) + delay,
                        seq=self.seq, latency=float(latency[j]),
                        weight=float(g.weights[row]), params=params,
                        attempt=attempt)
            heapq.heappush(self._heap, (up.ready, up.seq, up))
            pushed += 1
        return pushed

    # -- consumption -----------------------------------------------------

    def _staleness(self, up: Upload, t: int) -> int:
        return (t - 1) - up.base_version

    def usable_pending(self, t: int) -> int:
        """Buffered uploads that would survive the staleness cut at t."""
        s_max = self.cfg.max_staleness
        return sum(1 for _, _, up in self._heap
                   if self._staleness(up, t) <= s_max)

    def pop(self, t: int, m: int):
        """Consume the M earliest-ready usable uploads for round ``t``.

        Advances the virtual clock to the latest arrival consumed (stale
        discards also arrived, so they advance it too).  Returns
        ``(uploads, telemetry)`` where ``uploads`` is a list of
        ``(Upload, staleness)`` and ``telemetry`` feeds ``RoundLog``.
        """
        s_max = self.cfg.max_staleness
        out: List[Tuple[Upload, int]] = []
        hist = [0] * (s_max + 1)
        while len(out) < m and self._heap:
            ready, _, up = heapq.heappop(self._heap)
            self.clock = max(self.clock, ready)
            s = self._staleness(up, t)
            if s > s_max:
                self.registry.record_stale_drop([up.client])
                self._stale_since += 1
                continue
            self.registry.record_upload([up.client], up.latency, s)
            self.sampler.observe([up.client], s)
            hist[s] += 1
            out.append((up, s))
        if len(out) < m:
            raise RuntimeError(
                f"round {t}: buffer underflow ({len(out)}/{m} usable "
                f"uploads) — caller must fill until usable_pending >= M")
        a = self.cfg.staleness_exponent
        tele = {
            "staleness_hist": hist,
            "buffer_fill": sum(1 for r, _, _ in self._heap
                               if r <= self.clock),
            "n_straggling": sum(1 for r, _, _ in self._heap
                                if r > self.clock),
            "n_dropped_uploads": self._dropped_since,
            "n_stale_dropped": self._stale_since,
            "eff_participants": float(sum((1.0 + s) ** (-a)
                                          for _, s in out)),
        }
        tele.update(self.fault_counters(reset=True))
        self._dropped_since = 0
        self._stale_since = 0
        return out, tele

    def fault_counters(self, reset: bool = False) -> Dict[str, int]:
        """Fault telemetry accumulated since the last reset (fed into
        ``RoundLog`` by the buffered-async driver)."""
        d = {"n_corrupted": self._corrupted_since,
             "n_quarantined": self._quarantined_since,
             "n_retries": self._retries_since}
        if reset:
            self._corrupted_since = 0
            self._quarantined_since = 0
            self._retries_since = 0
        return d

    def regroup(self, uploads) -> Dict[int, Dict[str, list]]:
        """Bucket consumed uploads by prototype, preserving pop order."""
        per: Dict[int, Dict[str, list]] = {}
        for up, s in uploads:
            e = per.setdefault(up.proto, {"params": [], "weights": [],
                                          "staleness": [], "clients": []})
            e["params"].append(up.params)
            e["weights"].append(up.weight)
            e["staleness"].append(s)
            e["clients"].append(up.client)
        return per

    # -- checkpointing ---------------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        d = {
            "registry": self.registry.state_dict(),
            "clock": float(self.clock),
            "wave": int(self.wave),
            "seq": int(self.seq),
            "dropped_since": int(self._dropped_since),
            "stale_since": int(self._stale_since),
            "corrupted_since": int(self._corrupted_since),
            "quarantined_since": int(self._quarantined_since),
            "retries_since": int(self._retries_since),
            "pending": [up.to_dict()
                        for _, _, up in sorted(self._heap,
                                               key=lambda e: e[:2])],
        }
        if self.screen is not None:
            d["screen"] = self.screen.state_dict()
        return d

    def load_state(self, d: Dict[str, Any]) -> None:
        self.registry.load_state(d["registry"])
        self.clock = float(d["clock"])
        self.wave = int(d["wave"])
        self.seq = int(d["seq"])
        self._dropped_since = int(d["dropped_since"])
        self._stale_since = int(d["stale_since"])
        # fault counters / screen state: absent from pre-PR 8 checkpoints
        self._corrupted_since = int(d.get("corrupted_since", 0))
        self._quarantined_since = int(d.get("quarantined_since", 0))
        self._retries_since = int(d.get("retries_since", 0))
        if self.screen is not None and "screen" in d:
            self.screen.load_state(d["screen"])
        self._heap = []
        for entry in d["pending"]:
            up = Upload.from_dict(entry)
            heapq.heappush(self._heap, (up.ready, up.seq, up))
        self.sampler.load_priorities(self.registry.priority)

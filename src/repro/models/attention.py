"""GQA attention: train/prefill forward + one-token decode with KV cache.

Supports: grouped-query attention, RoPE, qk-norm (qwen3), causal /
bidirectional (hubert) / sliding-window (gemma3 local layers) masking.
Local layers use a *ring-buffer* cache of size ``window`` so a 500k-token
context costs only window-sized KV memory on those layers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.arch_config import ArchConfig
from repro.models.layers import ParamSpec, apply_rope, rmsnorm, rmsnorm_spec


class KVCache(NamedTuple):
    k: jax.Array  # [B, cache_size, KV, D]
    v: jax.Array  # [B, cache_size, KV, D]


def attn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "qkv")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "qkv")),
        "wo": ParamSpec((h, hd, d), ("heads", "qkv", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = rmsnorm_spec(hd, "qkv")
        specs["k_norm"] = rmsnorm_spec(hd, "qkv")
    return specs


def _project_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, head_dim):
    """q:[B,S,H,D] k/v:[B,T,KV,D] mask:[B,1,S,T] or broadcastable."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    q = q.reshape(b, s, kvh, rep, d)
    scores = jnp.einsum("bskrd,btkd->bkrst", q, k) / jnp.sqrt(head_dim).astype(q.dtype)
    scores = jnp.where(mask[:, None, ...] if mask.ndim == 3 else mask, scores,
                       jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", probs, v)
    return out.reshape(b, s, h, d)


def _sdpa_chunked(q, k, v, head_dim, *, causal: bool, window: Optional[int],
                  chunk: int = 1024):
    """Flash-pattern attention: scan over KV chunks with an online softmax —
    never materialises the [S, T] score matrix in HBM.  This is the HLO-level
    analogue of kernels/swa_attn.py (which does the same tiling in VMEM on
    real TPU); used by the ``attn=chunked`` §Perf variant.

    q: [B,S,H,D]  k/v: [B,T,KV,D]  ->  [B,S,H,D]
    The scan body is checkpointed so the backward pass recomputes per-chunk
    scores instead of storing them.
    """
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    nchunk = -(-t // chunk)
    pad = nchunk * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(b, s, kvh, rep, d)
    scale = 1.0 / jnp.sqrt(head_dim)
    kc = k.reshape(b, nchunk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(s)[:, None]

    def body(carry, inp):
        acc, m, denom = carry           # [B,S,KV,R,D], [B,S,KV,R], same
        ci, kb, vb = inp                # chunk idx, [B,chunk,KV,D] x2
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        scores = jnp.einsum("bskrd,btkd->bskrt", qr, kb).astype(jnp.float32)
        scores = scores * scale
        mask = jnp.ones((s, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        mask &= kpos < t  # padding
        scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        # accumulate in f32 (flash-standard); cast once at the end
        acc = acc * corr[..., None] + jnp.einsum(
            "bskrt,btkd->bskrd", p.astype(kb.dtype), vb).astype(jnp.float32)
        denom = denom * corr + jnp.sum(p, axis=-1)
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((b, s, kvh, rep, d), jnp.float32)
    m0 = jnp.full((b, s, kvh, rep), -jnp.inf, jnp.float32)
    den0 = jnp.zeros((b, s, kvh, rep), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, den0),
        (jnp.arange(nchunk), kc, vc))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(b, s, h, d).astype(v.dtype)


def _make_mask(cfg: ArchConfig, local: bool, s: int) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    if not cfg.causal:
        mask = jnp.ones((s, s), bool)
    else:
        mask = j <= i
    if local:
        mask = mask & (i - j < cfg.window)
    return mask[None, None]  # [1,1,S,S]


def attention(p: dict, cfg: ArchConfig, x: jax.Array, *, local: bool) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.attn_impl == "chunked":
        out = _sdpa_chunked(q, k, v, cfg.head_dim, causal=cfg.causal,
                            window=cfg.window if local else None,
                            chunk=min(cfg.attn_chunk, s))
    else:
        out = _sdpa(q, k, v, _make_mask(cfg, local, s), cfg.head_dim)
    return jnp.einsum("bshd,hdm->bsm", out, p["wo"])


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------

def cache_size(cfg: ArchConfig, local: bool, max_seq: int) -> int:
    return min(cfg.window, max_seq) if local else max_seq


def init_cache(cfg: ArchConfig, local: bool, batch: int, max_seq: int,
               dtype=jnp.float32) -> KVCache:
    cs = cache_size(cfg, local, max_seq)
    shape = (batch, cs, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_logical_axes(local: bool) -> KVCache:
    ax = ("batch", "cache_seq", "kv_heads", "qkv")
    return KVCache(ax, ax)


def decode_step(p: dict, cfg: ArchConfig, x: jax.Array, cache: KVCache,
                cur_len: jax.Array, *, local: bool):
    """One-token decode.  x: [B, 1, d_model]; cur_len: current context length
    (tokens already in the cache).  Returns (out [B,1,d], new_cache)."""
    b = x.shape[0]
    cs = cache.k.shape[1]
    positions = jnp.full((b, 1), cur_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    slot = (cur_len % cs).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    idx = jnp.arange(cs)
    if local:
        # ring buffer: slot occupied iff it holds one of the last `cs` tokens
        n_valid = jnp.minimum(cur_len + 1, cs)
        age = (slot - idx) % cs  # 0 = newest
        valid = age < n_valid
    else:
        valid = idx <= cur_len
    mask = valid[None, None, None, :]  # [1,1,1,cs]
    out = _sdpa(q, k, v, mask, cfg.head_dim)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    return out, KVCache(k, v)


def prefill_cache(p: dict, cfg: ArchConfig, x: jax.Array, max_seq: int,
                  *, local: bool):
    """Run full attention over the prompt AND return the populated cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.attn_impl == "chunked":
        out = _sdpa_chunked(q, k, v, cfg.head_dim, causal=cfg.causal,
                            window=cfg.window if local else None,
                            chunk=min(cfg.attn_chunk, s))
    else:
        out = _sdpa(q, k, v, _make_mask(cfg, local, s), cfg.head_dim)
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"])
    cs = cache_size(cfg, local, max_seq)
    if cs >= s:
        pad = cs - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:  # keep the trailing window, aligned to ring slots
        start = s - cs
        # slot of token t is t % cs; k[:, start + i] must land at (start+i) % cs
        roll = start % cs
        ck = jnp.roll(k[:, start:], roll, axis=1)
        cv = jnp.roll(v[:, start:], roll, axis=1)
    return out, KVCache(ck, cv)

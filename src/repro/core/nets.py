"""Small client models for the paper-validation experiments.

The paper's clients are ResNet-8 / VGG-9 / DistilBERT; our offline stand-ins
keep the *properties that matter to FedDF*:

* ``mlp`` (norm='none')  — unnormalised net (VGG-analogue): unstable under
  non-iid local training -> exercises drop-worst (Table 3).
* ``mlp`` (norm='bn')    — BatchNorm net: running statistics diverge across
  non-iid clients and parameter averaging mixes them (Table 2's quagmire).
* ``mlp`` (norm='gn')    — GroupNorm replacement (Hsieh et al. fix).
* ``tiny_transformer``   — DistilBERT-analogue for token classification.

All nets share one functional interface:
    init(key) -> params            (BN running stats live in params['bn_*'],
                                    flagged non-gradient by `trainable_mask`)
    apply(params, x, train=True) -> logits
so the FL strategies are model-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Net:
    init: Callable[[jax.Array], dict]
    apply: Callable[..., jax.Array]  # (params, x, train=) -> logits
    name: str
    # (params, x) -> (logits, params-with-refreshed-BN-running-stats);
    # identical to `apply` + identity for stateless nets.
    apply_with_stats: Callable[..., Tuple[jax.Array, dict]] = None  # type: ignore

    def trainable_mask(self, params: dict):
        return jax.tree_util.tree_map_with_path(
            lambda path, _: not any("running" in str(p) for p in path), params)


def _dense_init(key, din, dout, scale=1.0):
    w = jax.random.normal(key, (din, dout)) * (scale / math.sqrt(din))
    return {"w": w, "b": jnp.zeros((dout,))}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _batchnorm(p, x, train: bool, momentum=0.9, eps=1e-5):
    if train:
        mu = jnp.mean(x, axis=0)
        var = jnp.var(x, axis=0)
        new_running = {
            "running_mean": momentum * p["running_mean"] + (1 - momentum) * mu,
            "running_var": momentum * p["running_var"] + (1 - momentum) * var,
        }
    else:
        mu, var = p["running_mean"], p["running_var"]
        new_running = {k: p[k] for k in ("running_mean", "running_var")}
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y, new_running


def _groupnorm(p, x, groups, eps=1e-5):
    n, c = x.shape
    xg = x.reshape(n, groups, c // groups)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    y = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(n, c)
    return y * p["scale"] + p["bias"]


def mlp(in_dim: int, n_classes: int, hidden: Sequence[int] = (64, 64, 64),
        norm: str = "none", groups: int = 8, name: str | None = None) -> Net:
    """3-layer MLP (the paper's Fig.1 toy uses exactly a 3-layer MLP)."""
    dims = [in_dim] + list(hidden) + [n_classes]

    def init(key):
        keys = jax.random.split(key, len(dims))
        params = {}
        for i in range(len(dims) - 1):
            params[f"dense_{i}"] = _dense_init(keys[i], dims[i], dims[i + 1],
                                               scale=1.4)
            if i < len(dims) - 2 and norm in ("bn", "gn"):
                nd = dims[i + 1]
                p = {"scale": jnp.ones((nd,)), "bias": jnp.zeros((nd,))}
                if norm == "bn":
                    p["running_mean"] = jnp.zeros((nd,))
                    p["running_var"] = jnp.ones((nd,))
                params[f"norm_{i}"] = p
        return params

    def _forward(params, x, train):
        x = x.reshape(x.shape[0], -1)
        updated = dict(params)
        for i in range(len(dims) - 1):
            x = _dense(params[f"dense_{i}"], x)
            if i < len(dims) - 2:
                if norm == "bn":
                    x, new_run = _batchnorm(params[f"norm_{i}"], x, train)
                    updated[f"norm_{i}"] = {**params[f"norm_{i}"], **new_run}
                elif norm == "gn":
                    x = _groupnorm(params[f"norm_{i}"], x, groups)
                x = jax.nn.relu(x)
        return x, updated

    def apply(params, x, train: bool = True):
        return _forward(params, x, train)[0]

    def apply_with_stats(params, x):
        logits, updated = _forward(params, x, True)
        return logits, updated

    return Net(init=init, apply=apply, apply_with_stats=apply_with_stats,
               name=name or f"mlp-{norm}-{'x'.join(map(str, hidden))}")


def tiny_transformer(vocab: int, n_classes: int, seq_len: int,
                     d_model: int = 64, n_layers: int = 2, n_heads: int = 4,
                     name: str | None = None) -> Net:
    """Mean-pooled transformer classifier (DistilBERT stand-in)."""
    hd = d_model // n_heads

    def init(key):
        ks = jax.random.split(key, 3 + 4 * n_layers)
        params = {
            "embed": jax.random.normal(ks[0], (vocab, d_model)) * 0.05,
            "pos": jax.random.normal(ks[1], (seq_len, d_model)) * 0.05,
            "head": _dense_init(ks[2], d_model, n_classes),
        }
        for l in range(n_layers):
            k = ks[3 + 4 * l : 7 + 4 * l]
            s = 1.0 / math.sqrt(d_model)
            params[f"layer_{l}"] = {
                "wqkv": jax.random.normal(k[0], (d_model, 3 * d_model)) * s,
                "wo": jax.random.normal(k[1], (d_model, d_model)) * s,
                "w1": jax.random.normal(k[2], (d_model, 4 * d_model)) * s,
                "w2": jax.random.normal(k[3], (4 * d_model, d_model))
                * (1.0 / math.sqrt(4 * d_model)),
                "ln1": jnp.ones((d_model,)),
                "ln2": jnp.ones((d_model,)),
            }
        return params

    def _rms(w, x):
        return x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * w

    def apply(params, x, train: bool = True):
        b, s = x.shape
        h = params["embed"][x] + params["pos"][None, :s]
        for l in range(n_layers):
            p = params[f"layer_{l}"]
            y = _rms(p["ln1"], h)
            qkv = y @ p["wqkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, s, n_heads, hd)
            k = k.reshape(b, s, n_heads, hd)
            v = v.reshape(b, s, n_heads, hd)
            att = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(hd)
            att = jax.nn.softmax(att, axis=-1)
            y = jnp.einsum("bhst,bthd->bshd", att, v).reshape(b, s, d_model)
            h = h + y @ p["wo"]
            y = _rms(p["ln2"], h)
            h = h + jax.nn.gelu(y @ p["w1"]) @ p["w2"]
        pooled = jnp.mean(h, axis=1)
        return _dense(params["head"], pooled)

    return Net(init=init, apply=apply,
               apply_with_stats=lambda p, x: (apply(p, x, True), p),
               name=name or f"tinyT-{n_layers}L{d_model}d")

"""Synthetic datasets for the paper-validation experiments (offline stand-ins
for CIFAR / AG News — see DESIGN.md "changed assumptions").

Two task families:

* ``gaussian_mixture`` — M-class Gaussian blobs in R^d (generalises the
  paper's Fig. 1 toy: 3-class, 2-D, 3-layer MLP).  Non-trivial class overlap
  so accuracy is a meaningful signal.
* ``token_sequences`` — M-class synthetic text: each class has its own
  token unigram distribution plus class-indicative marker tokens; a small
  transformer must aggregate evidence over the sequence (AG News stand-in).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class Dataset:
    x: np.ndarray  # [N, ...] float or int
    y: np.ndarray  # [N] int
    n_classes: int

    def __len__(self):
        return len(self.y)

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(self.x[idx], self.y[idx], self.n_classes)


def gaussian_mixture(n: int, n_classes: int = 3, dim: int = 2,
                     spread: float = 2.2, noise: float = 1.0,
                     seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    # class means on a circle (dim>=2) / random directions otherwise
    means = rng.normal(size=(n_classes, dim))
    means = spread * means / np.linalg.norm(means, axis=1, keepdims=True)
    y = rng.integers(0, n_classes, size=n)
    x = means[y] + noise * rng.normal(size=(n, dim))
    return Dataset(x.astype(np.float32), y.astype(np.int64), n_classes)


def token_sequences(n: int, n_classes: int = 4, vocab: int = 64,
                    seq_len: int = 16, marker_rate: float = 0.3,
                    seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    # per-class unigram dists + dedicated marker tokens
    base = rng.dirichlet([0.5] * (vocab - n_classes), size=n_classes)
    y = rng.integers(0, n_classes, size=n)
    x = np.empty((n, seq_len), dtype=np.int64)
    for i in range(n):
        c = y[i]
        toks = rng.choice(vocab - n_classes, size=seq_len, p=base[c])
        marks = rng.random(seq_len) < marker_rate
        toks[marks] = vocab - n_classes + c
        x[i] = toks
    return Dataset(x, y.astype(np.int64), n_classes)


def train_val_test_split(ds: Dataset, val_frac: float = 0.1,
                         test_frac: float = 0.2, seed: int = 0
                         ) -> Tuple[Dataset, Dataset, Dataset]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    n_test = int(len(ds) * test_frac)
    n_val = int(len(ds) * val_frac)
    return (ds.subset(idx[n_test + n_val:]), ds.subset(idx[n_test:n_test + n_val]),
            ds.subset(idx[:n_test]))


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int,
            epochs: int = 1):
    rng = np.random.default_rng(seed)
    n = len(y)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            ix = order[s:s + batch_size]
            yield x[ix], y[ix]
        if n < batch_size:  # tiny client: one padded batch per epoch
            ix = rng.choice(n, size=batch_size, replace=True)
            yield x[ix], y[ix]

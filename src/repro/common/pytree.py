"""Pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_mean(trees: Sequence[Pytree], weights: Sequence[float]) -> Pytree:
    """Weighted parameter average — the FedAvg aggregation primitive."""
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    out = tree_scale(trees[0], float(w[0]))
    for t, wi in zip(trees[1:], w[1:]):
        out = jax.tree.map(lambda acc, x, wi=float(wi): acc + wi * x, out, t)
    return out


def tree_stack(trees: Sequence[Pytree]) -> Pytree:
    """Stack homogeneous pytrees along a new leading axis (client axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Pytree, n: int) -> list[Pytree]:
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_leading_dim(tree: Pytree) -> int:
    """Size of the leading (client) axis of a stacked pytree."""
    return int(jax.tree.leaves(tree)[0].shape[0])


def tree_take(tree: Pytree, idx) -> Pytree:
    """Gather along the leading (client) axis of a stacked pytree."""
    idx = jnp.asarray(idx)
    return jax.tree.map(lambda x: x[idx], tree)


def tree_cat(trees: Sequence[Pytree]) -> Pytree:
    """Concatenate stacked pytrees along the leading (client) axis —
    the bucketed round engine's per-bucket stacks re-join through this."""
    if len(trees) == 1:
        return trees[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


def tree_weighted_mean_stacked(stack: Pytree, weights) -> Pytree:
    """FedAvg aggregation over the leading (client) axis of a stacked
    pytree — one contraction per leaf instead of K sequential adds."""
    w = np.asarray(weights, dtype=np.float64)
    w = jnp.asarray(w / w.sum(), jnp.float32)
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32),
                                axes=([0], [0])).astype(x.dtype), stack)


def tree_sq_dist(a: Pytree, b: Pytree):
    """sum ||a-b||^2 over all leaves (FedProx proximal term)."""
    d = jax.tree.map(lambda x, y: jnp.sum((x - y) ** 2), a, b)
    return jax.tree.reduce(jnp.add, d)


def tree_count(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_isfinite(tree: Pytree):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_map_with_path(fn: Callable, tree: Pytree) -> Pytree:
    """fn(path_str, leaf) -> leaf, path joined with '/'."""

    def _fn(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        return fn("/".join(keys), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)

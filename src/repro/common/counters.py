"""Process-wide trace/work counters used as test + bench evidence.

A :class:`TraceCounter` bumped via a *python side effect inside a traced
function body* only moves when jax actually re-traces (and therefore
re-compiles) the function — which makes it the cheapest possible proof
that a compiled program is being reused instead of rebuilt.  The same
class doubles as a plain work counter when bumped from host code
(teacher batch-forward accounting in ``core/logit_bank.py``).

Instances are deliberately module-level singletons next to what they
count (``CLIENT_COMPILES`` in ``core/client.py``, ``CHUNK_COMPILES`` in
``core/feddf.py``, ``TEACHER_FORWARDS`` in ``core/logit_bank.py``);
tests ``reset()`` before the run under measurement.
"""
from __future__ import annotations


class TraceCounter:
    def __init__(self):
        self.count = 0

    def add(self, n: int) -> None:
        self.count += int(n)

    def reset(self) -> None:
        self.count = 0

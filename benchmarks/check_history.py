"""CI perf-regression gate over the schema'd bench history.

Every ``*_bench.py`` appends one validated record per run to
``BENCH_history.jsonl`` through :func:`benchmarks.timing.finish_bench`
(schema: ``repro.obs.history``).  This module is the single place the
acceptance thresholds live: it reads the LATEST record per
``(bench, case)`` and applies the same gates CI used to inline next to
each bench invocation — identical keys, identical thresholds, so
migrating the workflow onto this checker loosened nothing.

    PYTHONPATH=src python -m benchmarks.check_history \
        --require driver --require bucketing

``--require`` fails the run when a bench has no record at all (without
it, only benches present in the history are gated — useful locally
where you typically ran one bench).  Exit status is non-zero on any
failure; each gate prints one PASS/FAIL line.
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List

from repro.obs import history


def _distill(m: dict) -> List[str]:
    errs = []
    h, g = m["homogeneous"], m["heterogeneous"]
    if not h["speedup"] >= 1.5:
        errs.append(f"bank speedup regressed: {h['speedup']}")
    if not g["forward_reduction_x"] >= g["G"]:
        errs.append(f"hetero forward reduction {g['forward_reduction_x']} "
                    f"< G={g['G']}")
    return errs


def _distill_quant(m: dict) -> List[str]:
    errs = []
    if not m["bank_bytes_reduction_x"] >= 3.5:
        errs.append(f"int8 bank shrink regressed: "
                    f"{m['bank_bytes_reduction_x']}")
    if not m["teacher_agreement_drift"] <= 0.005:
        errs.append(f"int8 distill drift {m['teacher_agreement_drift']} "
                    f"> 0.5pt")
    if not m["marginal_steps_per_s_ratio"] >= 0.9:
        errs.append(f"int8 bank slowed distill: "
                    f"{m['marginal_steps_per_s_ratio']}")
    if len(m["roofline_records"]) != 4:  # fused/unfused x dtype
        errs.append(f"expected 4 roofline records, "
                    f"got {len(m['roofline_records'])}")
    return errs


def _bucketing(m: dict) -> List[str]:
    errs = []
    if not m["waste_reduction_x"] >= 2.0:
        errs.append(f"padding-waste reduction regressed: "
                    f"{m['waste_reduction_x']}")
    if m["trajectory_equal"] is not True:
        errs.append("bucketed trajectory drifted from unbucketed "
                    "(must be exact)")
    if not m["marginal_steps_per_s_speedup"] >= 1.1:
        errs.append(f"bucketing speedup regressed: "
                    f"{m['marginal_steps_per_s_speedup']}")
    return errs


def _driver(m: dict) -> List[str]:
    errs = []
    # local acceptance is >= 1.2x; shared-runner gate keeps slack
    if not m["speedup"] >= 1.1:
        errs.append(f"overlap speedup regressed: {m['speedup']}")
    if not m["async_staleness0"]["trajectory_equal"]:
        errs.append("async(staleness=0) trajectory drifted from sync")
    return errs


def _population(m: dict) -> List[str]:
    errs = []
    if m["buffered_degenerate"]["trajectory_equal"] is not True:
        errs.append("degenerate buffered_async drifted from sync "
                    "(must be exact)")
    if not m["uploads_ratio"] >= 1.3:
        errs.append(f"buffered upload throughput regressed: "
                    f"{m['uploads_ratio']}")
    if not m["final_acc_drift"] <= 0.005:
        errs.append(f"buffered drift {m['final_acc_drift']} > 0.5pt")
    return errs


def _robustness(m: dict) -> List[str]:
    errs = []
    if not abs(m["screened"]["drift"]) <= 0.01:
        errs.append(f"screened drift {m['screened']['drift']} > 1pt")
    if not (m["screened"]["finite"] and m["trimmed_mean"]["finite"]):
        errs.append("non-finite globals under faults")
    if not m["screened"]["quarantined"] > 0:
        errs.append("quarantine telemetry empty under chaos")
    # armed-but-idle fault seam costs <= 5% wall time (local
    # acceptance; CI slack for shared-runner noise)
    if not m["idle_overhead_frac"] <= 0.15:
        errs.append(f"idle fault-seam overhead {m['idle_overhead_frac']}")
    return errs


def _obs(m: dict) -> List[str]:
    errs = []
    if not m["overhead_frac"] <= 0.02:
        errs.append(f"armed flight-recorder overhead "
                    f"{m['overhead_frac']} > 2%")
    if m["trajectory_equal"] is not True:
        errs.append("armed trajectory drifted from disarmed "
                    "(must be bit-identical)")
    return errs


GATES: Dict[str, Callable[[dict], List[str]]] = {
    "distill": _distill,
    "distill_quant": _distill_quant,
    "bucketing": _bucketing,
    "driver": _driver,
    "population": _population,
    "robustness": _robustness,
    "obs": _obs,
}


def check(path=None, require=()) -> List[str]:
    """Gate the latest record per (bench, case); returns failure strings."""
    latest = history.latest(path)
    by_bench = {}
    for (bench, case), rec in latest.items():
        by_bench.setdefault(bench, {})[case] = rec
    failures = []
    for bench in require:
        if bench not in by_bench:
            failures.append(f"{bench}: required but no history record")
    for bench in sorted(by_bench):
        gate = GATES.get(bench)
        if gate is None:
            print(f"SKIP {bench}: no gate registered")
            continue
        for case, rec in sorted(by_bench[bench].items()):
            try:
                errs = gate(rec["metrics"])
            except (KeyError, TypeError) as e:
                errs = [f"malformed metrics: {e!r}"]
            for e in errs:
                failures.append(f"{bench}[{case}]: {e}")
            print(f"{'FAIL' if errs else 'PASS'} {bench}[{case}]"
                  + ("".join(f"\n  - {e}" for e in errs)))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", default=None,
                    help="history path (default: $BENCH_HISTORY_OUT or "
                         "BENCH_history.jsonl)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="BENCH",
                    help="fail unless this bench has a record "
                         "(repeatable)")
    args = ap.parse_args(argv)
    failures = check(args.history, args.require)
    if failures:
        print(f"{len(failures)} gate failure(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("all gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

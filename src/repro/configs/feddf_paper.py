"""The paper's own model scale: a small transformer standing in for the
ResNet-8 / DistilBERT client models used in the FedDF experiments
(Lin et al., NeurIPS 2020). Used by the paper-validation benchmarks and as
an 11th selectable config."""
from repro.common.arch_config import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="feddf-paper",
    family="dense",
    source="arXiv:2006.07242 (FedDF)",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    pattern=(BlockSpec("attn_global", "swiglu"),),
)

# Heterogeneous prototypes for Algorithm 3 (Fig. 4: ResNet-20/32/ShuffleNetV2
# analogue = same family, different depth/width)
import dataclasses as _dc
PROTO_SMALL = _dc.replace(CONFIG, name="feddf-paper-s", n_layers=2, d_model=96,
                          n_heads=4, d_ff=192, head_dim=24)
PROTO_LARGE = _dc.replace(CONFIG, name="feddf-paper-l", n_layers=6,
                          d_model=160, n_heads=4, d_ff=320, head_dim=40)

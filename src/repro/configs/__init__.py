"""Config registry: ``get(name)`` resolves ``--arch <id>``."""
from __future__ import annotations

from repro.common.arch_config import ArchConfig, reduced
from repro.configs.shapes import SHAPES, InputShape

from repro.configs import (  # noqa: F401
    feddf_paper,
    gemma3_4b,
    granite_moe_1b_a400m,
    hubert_xlarge,
    internvl2_1b,
    mamba2_2p7b,
    minicpm_2b,
    phi3_medium_14b,
    qwen3_8b,
    qwen3_moe_235b_a22b,
    zamba2_1p2b,
)

_MODULES = [
    gemma3_4b, mamba2_2p7b, qwen3_8b, hubert_xlarge, qwen3_moe_235b_a22b,
    minicpm_2b, internvl2_1b, phi3_medium_14b, granite_moe_1b_a400m,
    zamba2_1p2b, feddf_paper,
]

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ASSIGNED = [m.CONFIG.name for m in _MODULES[:10]]


def get(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return reduced(get(name[: -len("-smoke")]))
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) lowers, and the skip reason if not."""
    if shape.kind == "decode":
        if not cfg.is_decoder:
            return False, "encoder-only architecture: no decode step"
        if shape.seq_len > 100_000 and not cfg.sub_quadratic:
            return False, ("pure full-attention arch: 500k context requires "
                           "sub-quadratic attention (see DESIGN.md)")
    return True, ""

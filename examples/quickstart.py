"""Quickstart: FedDF vs FedAvg in ~40 lines.

20 non-iid clients (Dirichlet alpha=0.1), 3-class toy task (the paper's
Fig. 1 setting), server-side ensemble distillation on an out-of-domain
unlabeled pool.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FLConfig, FusionConfig, mlp, run_federated
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

# --- data: 3-class Gaussian blobs, heavily non-iid across 20 clients
ds = gaussian_mixture(6000, n_classes=3, dim=2, seed=0)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, n_clients=20, alpha=0.1, seed=0)
print("client sizes:", [len(p) for p in parts])

# --- the client model: the paper's 3-layer MLP
net = mlp(2, 3, hidden=(64, 64, 64))

# --- unlabeled distillation data from ANOTHER domain (uniform square)
source = UnlabeledDataset(
    np.random.default_rng(7).uniform(-3, 3, (4000, 2)).astype(np.float32))

common = dict(rounds=10, client_fraction=0.4, local_epochs=20,
              local_batch_size=32, local_lr=0.05, seed=0)

for strategy in ("fedavg", "feddf"):
    cfg = FLConfig(strategy=strategy,
                   fusion=FusionConfig(max_steps=500, patience=250,
                                       eval_every=50, batch_size=64),
                   **common)
    res = run_federated(net, train, parts, val, test, cfg,
                        source=source if strategy == "feddf" else None)
    curve = " ".join(f"{l.test_acc:.3f}" for l in res.logs)
    print(f"{strategy:7s} best={res.best_acc:.3f}  per-round: {curve}")

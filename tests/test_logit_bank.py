"""Teacher-logit bank fast path (docs/distill_fast_path.md):

 1. Bank-path trajectories numerically match the on-the-fly path —
    homogeneous, heterogeneous (shared bank) and SWAG-augmented teachers,
    with and without validation-based early stopping.
 2. The forward-call counter shows the K×steps (and heterogeneous G×)
    teacher-forward redundancy collapsing to one pass over the pool.
 3. The source pool/index interface holds its contract
    (``sample(key, b) == pool()[sample_indices(key, b)]``); generator /
    noise sources fall back to on-the-fly loudly when the bank is forced.
 4. FusionSpec round-trips + validates the new knobs; ``use_fused_kernel
    = 'auto'`` resolves per backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.pytree import tree_stack
from repro.core import mlp
from repro.core.feddf import (FusionConfig, distill, expected_distill_steps,
                              feddf_fuse_heterogeneous_stacked,
                              feddf_fuse_stacked, make_teacher_logits_fn)
from repro.core.logit_bank import (PERSISTENT_BANK, TEACHER_FORWARDS,
                                   bank_for_fusion, build_logit_bank,
                                   resolve_bank)
from repro.core.swag import swag_teachers, swag_teachers_stacked
from repro.data.distill_sources import (GeneratorSource, RandomNoiseSource,
                                        UnlabeledDataset)

RNG = np.random.default_rng(0)


def _fusion(**kw):
    base = dict(max_steps=75, patience=1_000, eval_every=25, batch_size=32,
                use_fused_kernel=False)
    base.update(kw)
    return FusionConfig(**base)


def _source(n=400, dim=2, seed=0):
    return UnlabeledDataset(np.random.default_rng(seed).uniform(
        -3, 3, (n, dim)).astype(np.float32))


def _val(n=150, dim=2, classes=3, seed=1):
    r = np.random.default_rng(seed)
    return (r.uniform(-3, 3, (n, dim)).astype(np.float32),
            r.integers(0, classes, size=n))


def _stack(net, k, seed0=0):
    return tree_stack([net.init(jax.random.PRNGKey(seed0 + i))
                       for i in range(k)])


def _assert_trees_close(a, b, atol=5e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# trajectory equivalence
# ---------------------------------------------------------------------------

def test_bank_matches_onthefly_homogeneous():
    net = mlp(2, 3, hidden=(16, 16))
    stack = _stack(net, 4)
    w = [1.0, 2.0, 1.0, 1.0]
    src = _source()
    vx, vy = _val()
    off, i_off = feddf_fuse_stacked(net, stack, w, src,
                                    _fusion(logit_bank="off"), vx, vy,
                                    seed=3)
    on, i_on = feddf_fuse_stacked(net, stack, w, src,
                                  _fusion(logit_bank="on"), vx, vy, seed=3)
    assert i_on["logit_bank"] and not i_off["logit_bank"]
    assert i_on["steps"] == i_off["steps"]
    # identical sampled indices -> identical eval schedule and accuracies
    assert [s for s, _ in i_on["val_history"]] == \
        [s for s, _ in i_off["val_history"]]
    np.testing.assert_allclose([a for _, a in i_on["val_history"]],
                               [a for _, a in i_off["val_history"]],
                               atol=1e-6)
    _assert_trees_close(off, on)


def test_bank_matches_onthefly_swag():
    net = mlp(2, 3, hidden=(12,))
    stack = _stack(net, 3)
    w = [1.0, 1.0, 2.0]
    src = _source(seed=5)
    kw = dict(swag_samples=2, swag_scale=0.3)
    off, _ = feddf_fuse_stacked(net, stack, w, src,
                                _fusion(logit_bank="off", **kw), seed=7)
    on, info = feddf_fuse_stacked(net, stack, w, src,
                                  _fusion(logit_bank="on", **kw), seed=7)
    assert info["logit_bank"]
    _assert_trees_close(off, on)


def test_bank_matches_onthefly_heterogeneous_and_counts():
    """G=3 groups: equal trajectories AND >= G x fewer teacher forwards."""
    G = 3
    nets = [mlp(2, 3, hidden=(8,), name="s"),
            mlp(2, 3, hidden=(12,), name="m"),
            mlp(2, 3, hidden=(16,), name="l")]
    protos = [(nets[g], _stack(nets[g], 2, seed0=10 * g), [1.0, 1.0])
              for g in range(G)]
    src = _source(seed=9)

    TEACHER_FORWARDS.reset()
    f_off, i_off = feddf_fuse_heterogeneous_stacked(
        protos, src, _fusion(logit_bank="off"), seed=1)
    n_off = TEACHER_FORWARDS.count
    TEACHER_FORWARDS.reset()
    f_on, i_on = feddf_fuse_heterogeneous_stacked(
        protos, src, _fusion(logit_bank="on"), seed=1)
    n_on = TEACHER_FORWARDS.count

    for a, b in zip(f_off, f_on):
        _assert_trees_close(a, b)
    assert all(i["logit_bank"] for i in i_on)
    # the shared bank is built once: every student gathers, none forwards
    assert n_on > 0 and n_off >= G * n_on
    assert i_on[0]["teacher_batch_forwards"] == n_on
    assert all(i["teacher_batch_forwards"] == 0 for i in i_on[1:])
    assert all(i["teacher_batch_forwards"] > 0 for i in i_off)


def test_bank_build_cost_attributed_when_first_group_empty():
    """A round where prototype 0 has no clients must still charge the
    shared bank's build forwards to some fused group's info."""
    nets = [mlp(2, 3, hidden=(8,), name="a"), mlp(2, 3, hidden=(12,),
                                                  name="b")]
    protos = [(nets[0], None, []),
              (nets[1], _stack(nets[1], 2), [1.0, 1.0])]
    TEACHER_FORWARDS.reset()
    _, infos = feddf_fuse_heterogeneous_stacked(
        protos, _source(), _fusion(logit_bank="on"), seed=0)
    assert infos[0] == {"skipped": True}
    assert infos[1]["teacher_batch_forwards"] == TEACHER_FORWARDS.count > 0


def test_auto_uses_bank_with_pool_and_fallback_without():
    net = mlp(2, 3, hidden=(8,))
    stack = _stack(net, 2)
    tfn = make_teacher_logits_fn(net, stack)
    student = net.init(jax.random.PRNGKey(9))

    _, info = distill(net, student, [tfn], _source(), _fusion(), seed=0)
    assert info["logit_bank"] and info["bank_build_s"] > 0.0

    gen = GeneratorSource((2,))
    _, info = distill(net, student, [tfn], gen, _fusion(), seed=0)
    assert not info["logit_bank"]


def test_fused_kernel_bank_path_matches_reference():
    """ensemble_kl_pre wired into the scan == jnp reference loss path."""
    net = mlp(2, 3, hidden=(12,))
    stack = _stack(net, 3)
    src = _source(seed=11)
    w = [1.0, 1.0, 1.0]
    fus = dict(max_steps=25, patience=100, eval_every=25, batch_size=16,
               logit_bank="on")
    ref_p, _ = feddf_fuse_stacked(net, stack, w, src,
                                  FusionConfig(use_fused_kernel=False,
                                               **fus), seed=2)
    ker_p, _ = feddf_fuse_stacked(net, stack, w, src,
                                  FusionConfig(use_fused_kernel=True,
                                               **fus), seed=2)
    _assert_trees_close(ref_p, ker_p, atol=1e-4)


# ---------------------------------------------------------------------------
# bank construction + counter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6),
                                       (jnp.bfloat16, 2e-2)])
def test_bank_rows_match_direct_forward(dtype, tol):
    net = mlp(2, 4, hidden=(16,))
    stack = _stack(net, 5)
    tfn = make_teacher_logits_fn(net, stack)
    pool = RNG.uniform(-2, 2, (130, 2)).astype(np.float32)  # odd N: padded
    bank = build_logit_bank([tfn], pool, chunk_size=64, dtype=dtype)
    assert bank.logits.dtype == dtype
    assert bank.logits.shape == (130, 4)
    assert bank.n == 130 and bank.n_teachers == 5
    assert bank.n_teacher_batch_forwards == 3 * 5  # ceil(130/64) chunks
    direct = jnp.mean(tfn(jnp.asarray(pool)).astype(jnp.float32), axis=0)
    np.testing.assert_allclose(np.asarray(bank.logits, dtype=np.float32),
                               np.asarray(direct), atol=tol, rtol=tol)


def test_forward_counter_tracks_build():
    net = mlp(2, 3, hidden=(8,))
    tfn = make_teacher_logits_fn(net, _stack(net, 4))
    TEACHER_FORWARDS.reset()
    build_logit_bank([tfn], RNG.uniform(-1, 1, (100, 2)).astype(np.float32),
                     chunk_size=50)
    assert TEACHER_FORWARDS.count == 2 * 4


# ---------------------------------------------------------------------------
# source pool / index interface
# ---------------------------------------------------------------------------

def test_unlabeled_sample_equals_pool_gather():
    src = _source(n=64)
    key = jax.random.PRNGKey(4)
    idx = src.sample_indices(key, 16)
    np.testing.assert_array_equal(
        np.asarray(src.sample(key, 16)),
        np.asarray(jnp.asarray(src.pool())[idx]))


def test_generator_noise_have_no_pool_and_warn_when_forced():
    net = mlp(2, 3, hidden=(8,))
    tfn = make_teacher_logits_fn(net, _stack(net, 2))
    for src in (GeneratorSource((2,)), RandomNoiseSource((2,))):
        assert src.pool() is None
        assert bank_for_fusion([tfn], src, _fusion(logit_bank="auto")) \
            is None
        with pytest.warns(UserWarning, match="no indexable pool"):
            assert bank_for_fusion([tfn], src,
                                   _fusion(logit_bank="on")) is None


def test_hetero_pool_less_source_warns_once_per_fusion():
    """logit_bank='on' + generator source: ONE fallback warning at the
    fuse level, not one more per group-student."""
    import warnings as _w
    nets = [mlp(2, 3, hidden=(8,), name="a"),
            mlp(2, 3, hidden=(12,), name="b")]
    protos = [(n, _stack(n, 2, seed0=5 * i), [1.0, 1.0])
              for i, n in enumerate(nets)]
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        feddf_fuse_heterogeneous_stacked(
            protos, GeneratorSource((2,)),
            _fusion(logit_bank="on", max_steps=25), seed=0)
    assert sum("no indexable pool" in str(w.message) for w in caught) == 1


def test_forward_count_handles_plain_callables():
    """Plain lambda teachers (no n_teachers attribute) still count their
    true K on the on-the-fly path — same ground truth as the builder."""
    net = mlp(2, 3, hidden=(8,))
    stack = _stack(net, 4)
    raw = lambda x: jax.vmap(  # noqa: E731 — deliberately attribute-less
        lambda p: net.apply(p, x, train=False))(stack)
    student = net.init(jax.random.PRNGKey(0))
    _, info = distill(net, student, [raw], GeneratorSource((2,)),
                      _fusion(logit_bank="off", max_steps=25), seed=0)
    assert info["teacher_batch_forwards"] == 25 * 4


def test_bank_mode_validated():
    net = mlp(2, 3, hidden=(8,))
    tfn = make_teacher_logits_fn(net, _stack(net, 2))
    with pytest.raises(ValueError, match="logit_bank"):
        bank_for_fusion([tfn], _source(), _fusion(logit_bank="maybe"))
    with pytest.raises(ValueError, match="bank_dtype"):
        bank_for_fusion([tfn], _source(), _fusion(bank_dtype="float64"))


# ---------------------------------------------------------------------------
# `auto` break-even heuristic (skip the build when the run is too short)
# ---------------------------------------------------------------------------

def test_expected_distill_steps():
    fus = _fusion(max_steps=10_000, patience=1_000, eval_every=100)
    # no validation -> no early stopping -> the full cap
    assert expected_distill_steps(fus, have_val=False) == 10_000
    # earliest plateau stop: first eval (always improves on the -1.0
    # initial best) + patience, on the eval_every grid
    assert expected_distill_steps(fus, have_val=True) == 1_100
    assert expected_distill_steps(
        _fusion(max_steps=10_000, patience=25, eval_every=100), True) == 200
    # patience >= max_steps -> the cap dominates
    assert expected_distill_steps(
        _fusion(max_steps=75, patience=1_000, eval_every=25), True) == 75


def test_auto_skips_bank_for_small_expected_runs():
    """auto + a patience that bounds the run below N/B rows: keep the
    on-the-fly path (the build would cost more forwards than it saves);
    'on' still insists."""
    net = mlp(2, 3, hidden=(8,))
    tfn = make_teacher_logits_fn(net, _stack(net, 2))
    src = _source(n=4000)
    vx, vy = _val()
    small = _fusion(max_steps=10_000, patience=25, eval_every=25,
                    batch_size=16)  # expected 50 steps * 16 << 4000
    bank, reason = resolve_bank(
        [tfn], src, small,
        expected_steps=expected_distill_steps(small, True))
    assert bank is None and reason == "skipped_small_run"

    student = net.init(jax.random.PRNGKey(3))
    _, info = distill(net, student, [tfn], src, small, vx, vy, seed=0)
    assert not info["logit_bank"]
    assert info["bank_decision"] == "skipped_small_run"

    # 'on' overrides the heuristic; long 'auto' runs still build
    on = _fusion(max_steps=50, patience=25, eval_every=25, batch_size=16,
                 logit_bank="on")
    _, info = distill(net, student, [tfn], src, on, vx, vy, seed=0)
    assert info["logit_bank"]
    PERSISTENT_BANK.clear()  # the 'on' build would otherwise be reused
    long_auto = _fusion(max_steps=200, patience=10_000, eval_every=25,
                        batch_size=32)  # 200 * 32 > 4000
    _, info = distill(net, student, [tfn], src, long_auto, vx, vy, seed=0)
    assert info["logit_bank"] and info["bank_decision"] == "bank"


def test_bank_decision_reaches_round_log():
    """The engine logs the per-round bank decision on RoundLog.bank."""
    from repro.core import FLConfig, run_federated
    from repro.data import (dirichlet_partition, gaussian_mixture,
                            train_val_test_split)
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    net = mlp(2, 3, hidden=(16,))
    cfg = FLConfig(strategy="feddf", rounds=1, client_fraction=0.5,
                   local_epochs=2, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                               eval_every=25, batch_size=32,
                                               use_fused_kernel=False))
    res = run_federated(net, train, parts, val, test, cfg, source=_source())
    assert res.logs[0].bank in ("bank", "bank_reused")
    cfg_skip = dataclasses_replace_fusion(cfg, max_steps=10_000, patience=25,
                                          eval_every=25, batch_size=1)
    res = run_federated(net, train, parts, val, test, cfg_skip,
                        source=_source(n=4000))
    assert res.logs[0].bank == "skipped_small_run"


def dataclasses_replace_fusion(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, fusion=dataclasses.replace(cfg.fusion,
                                                               **kw))


# ---------------------------------------------------------------------------
# persistent bank for static teacher pools
# ---------------------------------------------------------------------------

def test_persistent_bank_reused_for_identical_teacher_stacks():
    """Fusing the exact same frozen teacher arrays again reuses the
    previous build's rows: zero teacher forwards, identical output."""
    net = mlp(2, 3, hidden=(16,))
    stack = _stack(net, 4)
    src = _source()
    vx, vy = _val()
    fus = _fusion(logit_bank="on")
    PERSISTENT_BANK.clear()
    try:
        TEACHER_FORWARDS.reset()
        p1, i1 = feddf_fuse_stacked(net, stack, [1.0] * 4, src, fus,
                                    vx, vy, seed=3)
        assert i1["bank_decision"] == "bank"
        assert TEACHER_FORWARDS.count > 0
        assert i1["teacher_batch_forwards"] == TEACHER_FORWARDS.count

        TEACHER_FORWARDS.reset()
        p2, i2 = feddf_fuse_stacked(net, stack, [1.0] * 4, src, fus,
                                    vx, vy, seed=3)
        assert i2["bank_decision"] == "bank_reused"
        assert TEACHER_FORWARDS.count == 0
        assert i2["teacher_batch_forwards"] == 0
        assert i2["bank_build_s"] == 0.0
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        PERSISTENT_BANK.clear()


def test_cached_bank_beats_small_run_skip():
    """A cached bank is free, so it is used even when the auto heuristic
    would have skipped a fresh BUILD."""
    net = mlp(2, 3, hidden=(8,))
    stack = _stack(net, 2)
    tfn = make_teacher_logits_fn(net, stack)
    src = _source(n=4000)
    small = _fusion(max_steps=10_000, patience=25, eval_every=25,
                    batch_size=16)  # expected 50 steps * 16 << 4000
    PERSISTENT_BANK.clear()
    try:
        exp = expected_distill_steps(small, True)
        bank, reason = resolve_bank([tfn], src, small, expected_steps=exp)
        assert bank is None and reason == "skipped_small_run"
        # build once (forced), then the same small-run resolve reuses it
        on = _fusion(logit_bank="on")
        assert resolve_bank([tfn], src, on)[1] == "built"
        bank, reason = resolve_bank([tfn], src, small, expected_steps=exp)
        assert bank is not None and reason == "reused"
    finally:
        PERSISTENT_BANK.clear()


def test_persistent_bank_drops_when_uploads_die():
    """The cache holds the keyed uploads WEAKLY: once a run's teacher
    stacks are GC'd, the entry (and its bank rows) goes with them —
    no process-lifetime pinning of a round's working set."""
    import gc
    net = mlp(2, 3, hidden=(8,))
    src = _source(n=64)
    fus = _fusion(logit_bank="on", max_steps=25)
    PERSISTENT_BANK.clear()
    try:
        stack = _stack(net, 2)
        feddf_fuse_stacked(net, stack, [1.0, 1.0], src, fus, seed=0)
        tfn = make_teacher_logits_fn(net, stack)
        assert resolve_bank([tfn], src, fus)[1] == "reused"
        del stack, tfn
        gc.collect()
        assert PERSISTENT_BANK._bank is None  # entry died with the uploads
    finally:
        PERSISTENT_BANK.clear()


def test_hetero_break_even_scales_with_group_count():
    """The shared bank amortizes over all G students: a run too short for
    ONE student can still justify the build for G of them."""
    G = 3
    nets = [mlp(2, 3, hidden=(8,), name=f"g{i}") for i in range(G)]
    protos = [(n, _stack(n, 2, seed0=11 * i), [1.0, 1.0])
              for i, n in enumerate(nets)]
    vx, vy = _val()
    # expected 75 steps * 32 = 2400 rows per student: below a 4000-row
    # pool alone, above it for G=3 students (7200) -> hetero builds
    fus = _fusion(max_steps=75, patience=1_000, eval_every=25,
                  batch_size=32)
    src = _source(n=4000)
    tfn = make_teacher_logits_fn(nets[0], protos[0][1])
    PERSISTENT_BANK.clear()
    try:
        assert resolve_bank(
            [tfn], src, fus,
            expected_steps=expected_distill_steps(fus, True)
        )[1] == "skipped_small_run"
        _, infos = feddf_fuse_heterogeneous_stacked(protos, src, fus,
                                                    vx, vy, seed=0)
        assert all(i["bank_decision"] == "bank" for i in infos)
    finally:
        PERSISTENT_BANK.clear()


def test_persistent_bank_invalidated_on_any_upload_change():
    net = mlp(2, 3, hidden=(16,))
    src = _source()
    fus = _fusion(logit_bank="on")
    PERSISTENT_BANK.clear()
    try:
        s1 = _stack(net, 3)
        feddf_fuse_stacked(net, s1, [1.0] * 3, src, fus, seed=1)
        TEACHER_FORWARDS.reset()
        s2 = _stack(net, 3, seed0=50)  # new uploads -> new leaf identities
        _, info = feddf_fuse_stacked(net, s2, [1.0] * 3, src, fus, seed=1)
        assert info["bank_decision"] == "bank"  # rebuilt, not reused
        assert TEACHER_FORWARDS.count > 0
    finally:
        PERSISTENT_BANK.clear()


def test_persistent_bank_shared_across_hetero_round_repeat():
    """Repeating a heterogeneous fusion with unchanged teacher stacks
    (feddf_init_from='previous'-style static teacher pools) rebuilds
    nothing; every group's info reports the reuse."""
    nets = [mlp(2, 3, hidden=(8,), name="a"),
            mlp(2, 3, hidden=(12,), name="b")]
    protos = [(n, _stack(n, 2, seed0=7 * i), [1.0, 1.0])
              for i, n in enumerate(nets)]
    src = _source(seed=3)
    fus = _fusion(logit_bank="on")
    PERSISTENT_BANK.clear()
    try:
        f1, i1 = feddf_fuse_heterogeneous_stacked(protos, src, fus, seed=2)
        assert all(i["bank_decision"] == "bank" for i in i1)
        TEACHER_FORWARDS.reset()
        f2, i2 = feddf_fuse_heterogeneous_stacked(protos, src, fus, seed=2)
        assert all(i["bank_decision"] == "bank_reused" for i in i2)
        assert TEACHER_FORWARDS.count == 0
        assert all(i["teacher_batch_forwards"] == 0 for i in i2)
        for a, b in zip(f1, f2):
            _assert_trees_close(a, b, atol=0)
    finally:
        PERSISTENT_BANK.clear()


# ---------------------------------------------------------------------------
# SWAG stacked helper
# ---------------------------------------------------------------------------

def test_swag_teachers_stacked_matches_list_path():
    net = mlp(2, 3, hidden=(10,))
    plist = [net.init(jax.random.PRNGKey(i)) for i in range(3)]
    legacy = tree_stack(swag_teachers(plist, 2, scale=0.4, seed=5))
    stacked = swag_teachers_stacked(tree_stack(plist), 2, scale=0.4, seed=5)
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# spec plumbing + kernel auto mode
# ---------------------------------------------------------------------------

def test_fusion_spec_roundtrips_and_validates_bank_fields():
    from repro.api import ExperimentSpec
    from repro.api.spec import FusionSpec

    spec = ExperimentSpec()
    spec.strategy.fusion = FusionSpec(logit_bank="on", bank_dtype="bfloat16",
                                      use_fused_kernel="auto")
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    spec.validate()

    for bad in (dict(logit_bank="sometimes"), dict(bank_dtype="fp16"),
                dict(use_fused_kernel="cpu"), dict(use_fused_kernel=1)):
        s = ExperimentSpec()
        s.strategy.fusion = FusionSpec(**bad)
        with pytest.raises(ValueError):
            s.validate()


def test_use_fused_kernel_auto_resolves_per_backend():
    from repro.kernels.ops import use_pallas
    assert use_pallas(True) is True
    assert use_pallas(False) is False
    assert use_pallas("auto") == (jax.default_backend() == "tpu")
    # bool("off") is True — unrecognized strings must fail loudly
    with pytest.raises(ValueError, match="use_fused_kernel"):
        use_pallas("off")


# ---------------------------------------------------------------------------
# sharded bank build on a multi-device mesh (forced host devices in a
# subprocess: the parent's jax is already initialised single-device)
# ---------------------------------------------------------------------------

def test_sharded_bank_matches_unsharded_on_4_device_mesh():
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.common.pytree import tree_stack
from repro.core import mlp
from repro.core.feddf import make_teacher_logits_fn
from repro.core.logit_bank import build_logit_bank
from repro.launch.mesh import make_client_mesh

assert len(jax.devices()) == 4, jax.devices()
net = mlp(4, 5, hidden=(16,))
stack = tree_stack([net.init(jax.random.PRNGKey(i)) for i in range(3)])
tfn = make_teacher_logits_fn(net, stack)
pool = np.random.default_rng(0).uniform(-3, 3, (512, 4)).astype(np.float32)

plain = build_logit_bank([tfn], pool)
mesh = make_client_mesh(4)
sharding = NamedSharding(mesh, P("data"))
sharded = build_logit_bank([tfn], pool, sharding=sharding)

# the sharded bank really lives on all 4 devices, rows split over them
assert len(sharded.logits.sharding.device_set) == 4, sharded.logits.sharding
assert len(sharded.pool.sharding.device_set) == 4, sharded.pool.sharding
# and holds exactly the unsharded rows
np.testing.assert_array_equal(np.asarray(sharded.logits),
                              np.asarray(plain.logits))
np.testing.assert_array_equal(np.asarray(sharded.pool),
                              np.asarray(plain.pool))
# a gather by sampled index (what the distill scan does) agrees too
idx = jax.random.randint(jax.random.PRNGKey(7), (64,), 0, 512)
np.testing.assert_array_equal(np.asarray(sharded.logits[idx]),
                              np.asarray(plain.logits[idx]))
print("SHARDED_BANK_OK", sharded.n_teacher_batch_forwards)
""".format(src=os.path.join(root, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True)
    assert r.stdout.count("SHARDED_BANK_OK") == 1, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# quantized banks (int8 / fp8_e4m3 rows + per-row fp32 scales)
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    from repro.core.logit_bank import dequantize_rows, quantize_rows
    rows = jnp.asarray(np.random.default_rng(2).normal(
        0, 4, (33, 17)).astype(np.float32))
    rows = rows.at[5].set(0.0)  # an all-zero row must round-trip exactly
    q, scales = quantize_rows(rows, "int8")
    assert q.dtype == jnp.int8
    assert scales.shape == (33,) and scales.dtype == jnp.float32
    deq = dequantize_rows(q, scales)
    # symmetric round-to-nearest: per-element error <= scale/2 per row
    err = np.abs(np.asarray(deq) - np.asarray(rows))
    assert (err <= np.asarray(scales)[:, None] * 0.5 + 1e-7).all()
    np.testing.assert_array_equal(np.asarray(deq[5]), 0.0)
    # each row's |amax| maps to +-127 exactly -> representable losslessly
    amax_err = np.abs(np.abs(np.asarray(deq)).max(1)
                      - np.abs(np.asarray(rows)).max(1))
    assert (amax_err <= np.asarray(scales) * 1e-5 + 1e-7).all()


def test_quantize_fp8_when_supported():
    from repro.core.logit_bank import dequantize_rows, quantize_rows
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("this jax has no float8_e4m3fn")
    rows = jnp.asarray(np.random.default_rng(3).normal(
        0, 2, (9, 24)).astype(np.float32))
    q, scales = quantize_rows(rows, "fp8_e4m3")
    assert q.dtype == jnp.float8_e4m3fn
    deq = dequantize_rows(q, scales)
    # fp8 e4m3 keeps ~2 mantissa-ish digits: relative error per row
    err = np.abs(np.asarray(deq) - np.asarray(rows))
    assert (err <= np.asarray(scales)[:, None] * 448 * 0.0625 + 1e-6).all()


def test_quantized_bank_nbytes_and_metadata():
    from repro.core.logit_bank import dequantize_rows
    net = mlp(2, 4, hidden=(16,))
    tfn = make_teacher_logits_fn(net, _stack(net, 3))
    pool = RNG.uniform(-2, 2, (96, 2)).astype(np.float32)
    f32 = build_logit_bank([tfn], pool)
    q = build_logit_bank([tfn], pool, chunk_size=40, dtype="int8")
    assert not f32.quantized and f32.dtype_name == "float32"
    assert f32.scales is None and f32.nbytes == 96 * 4 * 4
    assert q.quantized and q.dtype_name == "int8"
    assert q.logits.dtype == jnp.int8 and q.scales.shape == (96,)
    # the ISSUE's memory claim: N x C x 1 bytes of rows + N x 4 of scales
    assert q.nbytes == 96 * 4 * 1 + 96 * 4
    assert f32.nbytes / q.nbytes >= 2.0  # C=4 is the worst case; C>=64 >3.5
    # chunked quantization == whole-bank quantization of the fp32 rows
    deq = dequantize_rows(q.logits, q.scales)
    err = np.abs(np.asarray(deq) - np.asarray(f32.logits, dtype=np.float32))
    assert (err <= np.asarray(q.scales)[:, None] * 0.5 + 1e-6).all()


def test_int8_bank_trajectory_tracks_fp32():
    """Distilling from the int8 bank (unfused dequantize-then-KL and the
    fused gather+dequantize kernel) stays within a tight tolerance of the
    fp32-bank trajectory, and the info stream reports dtype + bytes."""
    net = mlp(2, 3, hidden=(16, 16))
    stack = _stack(net, 4)
    src = _source()
    w = [1.0] * 4
    PERSISTENT_BANK.clear()
    try:
        f32_p, i_f32 = feddf_fuse_stacked(
            net, stack, w, src, _fusion(logit_bank="on"), seed=3)
        PERSISTENT_BANK.clear()
        q_p, i_q = feddf_fuse_stacked(
            net, stack, w, src,
            _fusion(logit_bank="on", bank_dtype="int8"), seed=3)
        PERSISTENT_BANK.clear()
        qf_p, i_qf = feddf_fuse_stacked(
            net, stack, w, src,
            _fusion(logit_bank="on", bank_dtype="int8",
                    use_fused_kernel=True), seed=3)
    finally:
        PERSISTENT_BANK.clear()
    assert i_f32["bank_dtype"] == "float32"
    assert i_q["bank_dtype"] == i_qf["bank_dtype"] == "int8"
    assert 0 < i_q["bank_nbytes"] < i_f32["bank_nbytes"]
    # the quantization perturbs teacher logits, not the rng stream: the
    # trajectory stays close to fp32 (measured ~3.5e-5 after 50 steps)
    _assert_trees_close(f32_p, q_p, atol=5e-3)
    _assert_trees_close(f32_p, qf_p, atol=5e-3)
    # fused vs unfused on the SAME int8 bank is kernel-tolerance tight
    _assert_trees_close(q_p, qf_p, atol=1e-4)


def test_round_log_carries_bank_dtype_and_nbytes():
    from repro.core import FLConfig, run_federated
    from repro.data import (dirichlet_partition, gaussian_mixture,
                            train_val_test_split)
    ds = gaussian_mixture(1200, n_classes=3, dim=2, seed=0)
    train, val, test = train_val_test_split(ds)
    parts = dirichlet_partition(train.y, 6, 1.0, seed=0)
    net = mlp(2, 3, hidden=(16,))
    cfg = FLConfig(strategy="feddf", rounds=1, client_fraction=0.5,
                   local_epochs=2, local_batch_size=32, local_lr=0.05,
                   seed=0, fusion=FusionConfig(max_steps=50, patience=50,
                                               eval_every=25, batch_size=32,
                                               use_fused_kernel=False,
                                               logit_bank="on",
                                               bank_dtype="int8"))
    res = run_federated(net, train, parts, val, test, cfg, source=_source())
    log = res.logs[0]
    assert log.bank in ("bank", "bank_reused")
    assert log.bank_dtype == "int8" and log.bank_nbytes > 0
    # old checkpoints (dicts without the new fields) still round-trip
    from repro.core.engine import RoundLog
    d = dataclasses_replace_roundlog_dict(log)
    old = RoundLog(**d)
    assert old.bank_dtype == "" and old.bank_nbytes == 0


def dataclasses_replace_roundlog_dict(log):
    import dataclasses
    d = dataclasses.asdict(log)
    d.pop("bank_dtype"), d.pop("bank_nbytes")
    return d


# ---------------------------------------------------------------------------
# distill-axis bucketing (per-group batch sizes -> padded capacities)
# ---------------------------------------------------------------------------

def _hetero_protos():
    nets = [mlp(2, 3, hidden=(8,), name="s"),
            mlp(2, 3, hidden=(12,), name="m"),
            mlp(2, 3, hidden=(16,), name="l")]
    return [(nets[g], _stack(nets[g], 2, seed0=10 * g), [1.0, 1.0])
            for g in range(3)]


def test_distill_bucketing_reduces_padding():
    """batch_sizes=(12,16,48): 'none' pads every group to 48 (68 wasted
    rows/step); 'pow2' gives the small students intermediate capacities."""
    protos = _hetero_protos()
    src = _source(seed=9)
    runs = {}
    for kind in ("none", "pow2"):
        fus = _fusion(logit_bank="on", max_steps=50,
                      batch_sizes=(12, 16, 48), distill_bucket=kind)
        runs[kind] = feddf_fuse_heterogeneous_stacked(protos, src, fus,
                                                      seed=1)
    i_none, i_pow2 = runs["none"][1], runs["pow2"][1]
    assert [i["batch_capacity"] for i in i_none] == [48, 48, 48]
    assert [i["padded_rows_per_step"] for i in i_none] == [36, 32, 0]
    assert [i["batch_capacity"] for i in i_pow2] == [16, 16, 48]
    assert [i["padded_rows_per_step"] for i in i_pow2] == [4, 0, 0]

    # trajectories agree across bucketings: bitwise where the padded
    # capacity matches, reassociation-level (XLA reduce order over the
    # different padded shapes) where it does not
    f_none, f_pow2 = runs["none"][0], runs["pow2"][0]
    for gi, (a, b) in enumerate(zip(f_none, f_pow2)):
        if i_none[gi]["batch_capacity"] == i_pow2[gi]["batch_capacity"]:
            _assert_trees_close(a, b, atol=0)
        else:
            _assert_trees_close(a, b, atol=1e-6)


def test_distill_batch_sizes_validated():
    protos = _hetero_protos()
    with pytest.raises(ValueError, match="batch_sizes"):
        feddf_fuse_heterogeneous_stacked(
            protos, _source(), _fusion(batch_sizes=(8, 16)), seed=0)


def test_fusion_spec_roundtrips_and_validates_distill_bucketing():
    from repro.api import ExperimentSpec
    from repro.api.spec import FusionSpec

    spec = ExperimentSpec()
    n_protos = len(spec.cohort.prototypes)
    spec.strategy.fusion = FusionSpec(bank_dtype="int8",
                                      batch_sizes=[32] * n_protos,
                                      distill_bucket="pow2",
                                      distill_max_buckets=2)
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    spec.validate()
    # fp8_e4m3 is always a VALID spec literal (runtime gates jax support)
    spec.strategy.fusion = FusionSpec(bank_dtype="fp8_e4m3")
    spec.validate()

    for bad in (dict(bank_dtype="int4"), dict(distill_bucket="pow3"),
                dict(distill_max_buckets=0),
                dict(batch_sizes=[32] * (n_protos + 1)),
                dict(batch_sizes=[0] * n_protos)):
        s = ExperimentSpec()
        s.strategy.fusion = FusionSpec(**bad)
        with pytest.raises(ValueError):
            s.validate()

"""End-to-end federated training driver (CLI) over the declarative API.

CLI flags compile into one serializable :class:`repro.api.ExperimentSpec`
(``repro/api/spec.py``), so every run is reproducible as data:

    PYTHONPATH=src python -m repro.launch.train \\
        --strategy feddf --rounds 20 --clients 20 -C 0.4 --alpha 0.1 \\
        --local-epochs 20 --task tokens --out runs/feddf \\
        --dump-config runs/feddf/spec.json

    # replay the exact run (identical per-round accuracy log):
    PYTHONPATH=src python -m repro.launch.train \\
        --config runs/feddf/spec.json --out runs/replay

    # continue an interrupted run from its per-round checkpoints:
    PYTHONPATH=src python -m repro.launch.train --resume runs/feddf

Strategies: any name in the server-strategy registry
(``core/strategies.py``: fedavg | fedprox | fedavgm | feddf | ...) plus
``feddf-hetero``, which compiles to a feddf run over the task's default
three-prototype cohort ladder (Algorithm 3).  ``--shard-clients`` shards
the round engine's client axis over all visible devices.  ``--driver``
selects the round driver (docs/drivers.md): ``sync`` (default),
``async_pipelined`` (``--staleness 1`` overlaps round t+1's client
training with round t's fusion), ``multihost`` (client axis sharded
over every visible device/host — heterogeneous cohorts included), or
``distributed`` (fusion pod + client pods behind the versioned wire
protocol — ``--transport``, ``--wire-codec``, ``--heartbeat-s``,
``--upload-deadline-s``; docs/distributed.md).
``--bucket-by pow2|quantile`` buckets clients by local-step count so
skewed non-IID cohorts stop scanning padded no-op steps
(docs/bucketing.md; trajectories identical to ``none``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.api import (BucketSpec, CohortSpec, DistSpec, DriverSpec,
                       Experiment, ExperimentSpec, FaultSpec, FusionSpec,
                       ModelSpec, ObsSpec, PartitionSpec, PopulationSpec,
                       PrivacySpec, ShardingSpec, SourceSpec, StrategySpec,
                       TaskSpec, TrafficSpec, default_prototype_ladder)
from repro.checkpoint import io as ckpt
from repro.common.options import (ARRIVAL_KINDS, BANK_DTYPES, BUCKET_KINDS,
                                  BYZANTINE_MODES, SCREEN_MODES,
                                  TRANSPORT_KINDS)
from repro.core import available_strategies
from repro.dist.frames import available_codecs
from repro.drivers import available_drivers
from repro.population import available_samplers


def spec_from_args(args: argparse.Namespace) -> ExperimentSpec:
    """Compile CLI flags into the canonical experiment spec."""
    hetero = args.strategy == "feddf-hetero"
    strategy_name = "feddf" if hetero else args.strategy
    if args.robust_agg:
        # robust aggregation is a strategy override, not a new axis:
        # --robust-agg trimmed_mean replaces fedavg-family fusion
        strategy_name = args.robust_agg

    task = TaskSpec(name=args.task, n_samples=args.n_samples)
    if hetero:
        prototypes = [ModelSpec.from_dict(m)
                      for m in default_prototype_ladder(args.task)]
    elif args.task == "blobs":
        prototypes = [ModelSpec("mlp", {"hidden": [64, 64, 64],
                                        "norm": args.norm})]
    else:
        prototypes = [ModelSpec("tiny_transformer", {})]

    batch_sizes = (None if not args.distill_batch_sizes else
                   [int(b) for b in args.distill_batch_sizes.split(",")])
    if batch_sizes is not None and len(batch_sizes) != len(prototypes):
        raise SystemExit(
            f"--distill-batch-sizes needs one entry per prototype "
            f"({len(prototypes)}), got {len(batch_sizes)}")

    return ExperimentSpec(
        task=task,
        partition=PartitionSpec(n_clients=args.clients, alpha=args.alpha),
        cohort=CohortSpec(prototypes=prototypes),
        strategy=StrategySpec(
            name=strategy_name, drop_worst=args.drop_worst,
            trim_frac=args.trim_frac,
            fusion=FusionSpec(
                max_steps=args.distill_steps,
                patience=max(args.distill_steps // 5, 100),
                eval_every=100, batch_size=64,
                bank_dtype=args.bank_dtype,
                batch_sizes=batch_sizes,
                distill_bucket=args.distill_bucket_by,
                distill_max_buckets=args.distill_max_buckets)),
        source=SourceSpec(name=args.distill_source),
        privacy=PrivacySpec(quantizer="binarize" if args.binarize else None),
        sharding=ShardingSpec(shard_clients=args.shard_clients),
        driver=DriverSpec(kind=args.driver, staleness=args.staleness,
                          prefetch=args.prefetch),
        bucket=BucketSpec(kind=args.bucket_by,
                          max_buckets=args.max_buckets),
        population=PopulationSpec(
            size=args.population_size, sampler=args.sampler,
            buffer_size=args.buffer_size,
            max_staleness=args.max_staleness,
            staleness_exponent=args.staleness_exponent,
            traffic=TrafficSpec(
                arrival=args.traffic, rate=args.traffic_rate,
                latency=args.traffic_latency, jitter=args.traffic_jitter,
                straggler_frac=args.straggler_frac,
                straggler_mult=args.straggler_mult,
                dropout=args.traffic_dropout)),
        faults=FaultSpec(
            nan_rate=args.faults_nan,
            byzantine_frac=args.faults_byzantine,
            byzantine_scale=args.faults_byzantine_scale,
            byzantine_mode=args.faults_byzantine_mode,
            bitflip_rate=args.faults_bitflip,
            crash_rate=args.faults_crash,
            screen=args.screen, teacher_filter=args.teacher_filter,
            quorum=args.quorum, retries=args.retries,
            backoff=args.backoff,
            transport_drop=args.faults_transport_drop,
            transport_corrupt=args.faults_transport_corrupt,
            transport_delay=args.faults_transport_delay,
            transport_delay_s=args.faults_transport_delay_s,
            transport_disconnect=args.faults_transport_disconnect),
        dist=DistSpec(
            transport=args.transport, wire_codec=args.wire_codec,
            n_pods=args.n_pods, heartbeat_s=args.heartbeat_s,
            upload_deadline_s=args.upload_deadline_s,
            verify_crc=not args.no_verify_crc,
            wire_log=args.wire_log),
        obs=ObsSpec(
            trace=bool(args.trace or args.profile),
            trace_path=args.trace or None,
            metrics_dir=args.metrics_dir or None,
            profile=bool(args.profile),
            profile_dir=args.profile_dir or None),
        rounds=args.rounds, client_fraction=args.fraction,
        local_epochs=args.local_epochs, local_lr=args.local_lr,
        target_accuracy=args.target, seed=args.seed)


def print_event(event) -> None:
    l = event.log
    if event.heterogeneous:
        print(f"[round {l.round:3d}] proto{event.group} "
              f"test={l.test_acc:.4f} ens={l.ensemble_acc:.4f}")
    else:
        print(f"[round {l.round:3d}] test={l.test_acc:.4f} "
              f"val={l.val_acc:.4f} distill_steps={l.distill_steps} "
              f"dropped={l.n_dropped}")


def build_parser() -> argparse.ArgumentParser:
    """The full CLI surface, separated from :func:`main` so tests can
    pin the flag -> spec -> JSON round trip without running anything."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="SPEC_JSON",
                    help="load the full experiment spec from a JSON file "
                         "(all other experiment flags are ignored)")
    ap.add_argument("--dump-config", default=None, metavar="SPEC_JSON",
                    help="write the compiled spec to this path, then run")
    ap.add_argument("--resume", default=None, metavar="RUN_DIR",
                    help="continue a checkpointed run from RUN_DIR "
                         "(ignores the other experiment flags)")
    ap.add_argument("--strategy", default="feddf",
                    choices=available_strategies() + ["feddf-hetero"])
    ap.add_argument("--task", default="blobs", choices=["blobs", "tokens"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("-C", "--fraction", type=float, default=0.4)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--local-epochs", type=int, default=20)
    ap.add_argument("--local-lr", type=float, default=0.05)
    ap.add_argument("--n-samples", type=int, default=6000)
    ap.add_argument("--distill-source", default="unlabeled",
                    choices=["unlabeled", "in_domain", "generator", "noise"])
    ap.add_argument("--distill-steps", type=int, default=1000)
    ap.add_argument("--norm", default="none", choices=["none", "bn", "gn"])
    ap.add_argument("--drop-worst", action="store_true")
    ap.add_argument("--binarize", action="store_true")
    ap.add_argument("--target", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/latest")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="write resumable per-round checkpoints every N "
                         "rounds under OUT/ckpt (0 disables)")
    ap.add_argument("--shard-clients", action="store_true",
                    help="shard the round engine's client axis over all "
                         "devices (active clients must divide the count)")
    ap.add_argument("--driver", default="sync",
                    choices=available_drivers(),
                    help="round driver (docs/drivers.md): sync | "
                         "async_pipelined (overlap round t+1 client "
                         "training with round t fusion) | multihost "
                         "(client axis sharded over all devices)")
    ap.add_argument("--bucket-by", default="none",
                    choices=["none", "pow2", "quantile"],
                    help="bucket clients by local-step count so skewed "
                         "cohorts stop scanning padded no-op steps "
                         "(docs/bucketing.md); trajectories are identical "
                         "to --bucket-by none")
    ap.add_argument("--max-buckets", type=int, default=4,
                    help="cap on step buckets per prototype (bounds the "
                         "compile count at buckets x prototypes)")
    ap.add_argument("--bank-dtype", default="float32",
                    choices=list(BANK_DTYPES),
                    help="teacher-logit-bank storage dtype "
                         "(docs/distill_fast_path.md): float32 keeps bank "
                         "trajectories bitwise-identical; int8/fp8_e4m3 "
                         "shrink the bank ~4x with per-row scales "
                         "dequantized inside the fused kernel")
    ap.add_argument("--distill-batch-sizes", default=None,
                    metavar="B0,B1,...",
                    help="per-prototype distillation batch sizes "
                         "(heterogeneous fusion; one entry per prototype, "
                         "default: uniform)")
    ap.add_argument("--distill-bucket-by", default="none",
                    choices=list(BUCKET_KINDS),
                    help="bucket the per-prototype distill batch sizes "
                         "into padded capacities (docs/bucketing.md): "
                         "none pads every group to the largest size; "
                         "pow2/quantile give small students intermediate "
                         "capacities")
    ap.add_argument("--distill-max-buckets", type=int, default=4,
                    help="cap on distill batch-size buckets")
    ap.add_argument("--staleness", type=int, default=0,
                    help="async_pipelined: 0 = exact sync semantics, S >= "
                         "1 = up to S rounds of training overlap the "
                         "oldest fusion (bounded staleness ring); "
                         "buffered_async: 1 overlaps wave training with "
                         "the previous fusion")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="rounds of host-side batch building prefetched "
                         "ahead by the async driver")
    ap.add_argument("--traffic", default="always",
                    choices=list(ARRIVAL_KINDS),
                    help="client arrival model (docs/population.md): "
                         "always = every client reachable every wave; "
                         "bernoulli = online with prob --traffic-rate")
    ap.add_argument("--traffic-rate", type=float, default=1.0,
                    help="bernoulli arrival probability per wave")
    ap.add_argument("--traffic-latency", type=float, default=0.0,
                    help="mean virtual upload latency (0 = instantaneous, "
                         "the degenerate sync-equivalent setting)")
    ap.add_argument("--traffic-jitter", type=float, default=0.0,
                    help="lognormal sigma of per-client speed and "
                         "per-upload latency noise")
    ap.add_argument("--straggler-frac", type=float, default=0.0,
                    help="fraction of persistently slow clients")
    ap.add_argument("--straggler-mult", type=float, default=8.0,
                    help="straggler latency multiplier")
    ap.add_argument("--traffic-dropout", type=float, default=0.0,
                    help="per-upload loss probability")
    ap.add_argument("--population-size", type=int, default=None,
                    help="registered client population size (default: the "
                         "partition roster; larger populations map onto "
                         "data partitions round-robin)")
    ap.add_argument("--sampler", default="uniform",
                    choices=available_samplers(),
                    help="cohort sampler (docs/population.md): uniform "
                         "(historic draw, bit-identical) | capacity_aware "
                         "(fills PR5 step-buckets evenly to cut padding "
                         "waste) | prioritized (O(log N) sum-tree, stale "
                         "clients bubble up)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="buffered_async: aggregate every M buffered "
                         "uploads (default: the active cohort size K — "
                         "with zero latency that is exactly sync)")
    ap.add_argument("--max-staleness", type=int, default=4,
                    help="buffered_async: uploads more than this many "
                         "fusions old are dropped instead of fused")
    ap.add_argument("--staleness-exponent", type=float, default=0.5,
                    help="FedAsync importance (1+s)^-a exponent applied "
                         "to stale uploads at fusion")
    ap.add_argument("--faults-nan", type=float, default=0.0,
                    help="fault injection (docs/robustness.md): per-upload "
                         "probability of NaN/Inf poisoning")
    ap.add_argument("--faults-byzantine", type=float, default=0.0,
                    help="fraction of persistently byzantine clients "
                         "(sign-flipped / scaled deltas, static draw)")
    ap.add_argument("--faults-byzantine-scale", type=float, default=10.0,
                    help="byzantine delta amplification factor")
    ap.add_argument("--faults-byzantine-mode", default="sign_flip",
                    choices=list(BYZANTINE_MODES),
                    help="byzantine payload: sign_flip sends the negated "
                         "scaled delta, scale sends it amplified")
    ap.add_argument("--faults-bitflip", type=float, default=0.0,
                    help="per-upload probability of payload bit flips")
    ap.add_argument("--faults-crash", type=float, default=0.0,
                    help="per-upload probability of a mid-round client "
                         "crash (partial upload: trailing delta zeroed)")
    ap.add_argument("--screen", default="auto",
                    choices=list(SCREEN_MODES),
                    help="upload screening (finite-ness + delta-norm "
                         "quarantine): auto = active iff any fault rate "
                         "is positive, keeping fault-free runs "
                         "bit-identical")
    ap.add_argument("--teacher-filter", default="auto",
                    choices=list(SCREEN_MODES),
                    help="FedDF teacher-consensus filter: drop non-finite "
                         "/ divergent teachers before distillation")
    ap.add_argument("--quorum", type=float, default=None,
                    help="minimum usable-upload fraction to fuse a round; "
                         "below it the round skips fusion (globals carry "
                         "over). Default None keeps historic strictness")
    ap.add_argument("--retries", type=int, default=2,
                    help="re-dispatch attempts for quarantined uploads "
                         "before the client is written off for the round")
    ap.add_argument("--backoff", type=float, default=2.0,
                    help="exponential retry backoff base (virtual "
                         "seconds, buffered_async)")
    ap.add_argument("--trace", default=None, metavar="SPANS_JSONL",
                    help="arm the flight recorder and append phase spans "
                         "to this JSONL file (docs/observability.md); the "
                         "summary gains an 'obs' per-round phase breakdown")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="stream per-round metrics records (registry "
                         "counter deltas, accuracy, device watermark) to "
                         "DIR/metrics.jsonl + DIR/metrics.csv")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in jax.profiler.start_trace with a "
                         "TraceAnnotation per span (XLA timelines carry "
                         "the span taxonomy); writes to --profile-dir "
                         "(default OUT/profile)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="jax profiler artifact directory")
    ap.add_argument("--robust-agg", default=None,
                    choices=["trimmed_mean", "coordinate_median"],
                    help="override --strategy with a robust aggregator "
                         "(docs/robustness.md)")
    ap.add_argument("--trim-frac", type=float, default=0.2,
                    help="trimmed_mean: fraction of client updates "
                         "trimmed from each end per coordinate")
    ap.add_argument("--transport", default="loopback",
                    choices=list(TRANSPORT_KINDS),
                    help="--driver distributed: loopback (pods are "
                         "threads — the CI transport) or tcp (one "
                         "subprocess per pod on localhost); see "
                         "docs/distributed.md")
    ap.add_argument("--wire-codec", default="fp32",
                    choices=available_codecs(),
                    help="payload codec for client uploads on the wire: "
                         "fp32 is exact (bit-identical to sync), "
                         "binarize/int8 cut bytes-on-wire ~32x/~4x")
    ap.add_argument("--n-pods", type=int, default=2,
                    help="client pods; client k homes on pod k %% n_pods")
    ap.add_argument("--heartbeat-s", type=float, default=5.0,
                    help="pod heartbeat period; a pod is presumed dead "
                         "after 3 missed beats and its clients re-route")
    ap.add_argument("--upload-deadline-s", type=float, default=30.0,
                    help="per-dispatch TRAIN->UPLOAD deadline before the "
                         "fusion pod re-dispatches (exponential backoff "
                         "via --backoff)")
    ap.add_argument("--no-verify-crc", action="store_true",
                    help="UNDEFENDED ablation: accept frames without "
                         "checking the CRC (corruption lands in params)")
    ap.add_argument("--wire-log", default=None, metavar="PATH",
                    help="append accepted UPLOAD frames to this crash-"
                         "safe record log; a restarted fusion pod "
                         "replays it")
    ap.add_argument("--faults-transport-drop", type=float, default=0.0,
                    help="P(UPLOAD frame silently lost in flight)")
    ap.add_argument("--faults-transport-corrupt", type=float, default=0.0,
                    help="P(UPLOAD frame bytes flipped in flight — "
                         "caught by CRC unless --no-verify-crc)")
    ap.add_argument("--faults-transport-delay", type=float, default=0.0,
                    help="P(UPLOAD frame delivery delayed)")
    ap.add_argument("--faults-transport-delay-s", type=float, default=0.25,
                    help="delay duration for delayed frames (wall "
                         "seconds)")
    ap.add_argument("--faults-transport-disconnect", type=float,
                    default=0.0,
                    help="P(pod link goes dark for the rest of the round)")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.profile and not args.profile_dir:
        args.profile_dir = os.path.join(args.out, "profile")

    t0 = time.time()
    if args.resume:
        out = args.out if args.out != "runs/latest" else args.resume
        res = Experiment.resume(os.path.join(args.resume, "ckpt"),
                                observers=[print_event],
                                checkpoint_every=args.checkpoint_every)
        spec = res.spec
    else:
        spec = (ExperimentSpec.load(args.config) if args.config
                else spec_from_args(args))
        if args.dump_config:
            os.makedirs(os.path.dirname(args.dump_config) or ".",
                        exist_ok=True)
            spec.save(args.dump_config)
        out = args.out
        ckpt_dir = (os.path.join(out, "ckpt")
                    if args.checkpoint_every > 0 else None)
        res = Experiment(spec).run(observers=[print_event],
                                   checkpoint_dir=ckpt_dir,
                                   checkpoint_every=args.checkpoint_every)

    os.makedirs(out, exist_ok=True)
    summary = res.summary()
    if res.heterogeneous:
        for g, params in enumerate(res.global_params):
            ckpt.save(os.path.join(out, f"proto_{g}"), params,
                      {"arch": res.net_names[g]})
    else:
        ckpt.save(os.path.join(out, "global"), res.global_params[0],
                  {"net": res.net_names[0],
                   "strategy": spec.strategy.name})

    summary["wall_s"] = time.time() - t0
    # the spec IS the config: replay any run dir via
    #   python -m repro.launch.train --config <out>/spec.json
    summary["config"] = spec.to_dict()
    spec.save(os.path.join(out, "spec.json"))
    with open(os.path.join(out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("per_round", "config")}, indent=2))


if __name__ == "__main__":
    main()

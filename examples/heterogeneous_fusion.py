"""Heterogeneous model fusion (paper Algorithm 3 / Figure 4).

Three distinct client prototypes (different widths/depths — the
ResNet-20/32/ShuffleNetV2 analogue).  Parameter averaging can only operate
within a prototype group; FedDF distils the cross-group ensemble into every
prototype, so small models learn from big ones and vice versa.

    PYTHONPATH=src python examples/heterogeneous_fusion.py
"""
import numpy as np

from repro.core import (FLConfig, FusionConfig, mlp,
                        run_federated_heterogeneous)
from repro.data import (UnlabeledDataset, dirichlet_partition,
                        gaussian_mixture, train_val_test_split)

ds = gaussian_mixture(6000, n_classes=3, dim=2, seed=1)
train, val, test = train_val_test_split(ds)
parts = dirichlet_partition(train.y, n_clients=9, alpha=1.0, seed=1)

nets = [mlp(2, 3, hidden=(32, 32), name="proto-small"),
        mlp(2, 3, hidden=(64, 64), name="proto-medium"),
        mlp(2, 3, hidden=(48, 48, 48), name="proto-deep")]
client_proto = [k % 3 for k in range(9)]  # evenly distributed

source = UnlabeledDataset(
    np.random.default_rng(7).uniform(-3, 3, (4000, 2)).astype(np.float32))

for strategy in ("fedavg", "feddf"):
    cfg = FLConfig(strategy=strategy, rounds=6, client_fraction=0.67,
                   local_epochs=20, local_batch_size=32, local_lr=0.05,
                   seed=1, fusion=FusionConfig(max_steps=400, patience=200,
                                               eval_every=50, batch_size=64))
    results, _ = run_federated_heterogeneous(
        nets, client_proto, train, parts, val, test, cfg,
        source=source if strategy == "feddf" else None)
    print(f"--- {strategy}")
    for g, r in enumerate(results):
        print(f"  {nets[g].name:13s} best={r.best_acc:.3f} "
              f"ensemble_ub={max(l.ensemble_acc for l in r.logs):.3f}")

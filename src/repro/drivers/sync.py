"""The serial reference driver — the historic ``run_rounds`` loop in
driver form.

Phase order per round t:

    sample_cohort(t) -> build_round_batches(t) -> train_clients(t)
    -> fault_pipeline(t) -> aggregate(t) -> guard_globals
    -> evaluate_round(t) -> log -> round_end_hook(t)

Nothing overlaps; round t+1's client training initialises from round t's
fused globals.  Trajectories are pinned bit-identical to the
pre-subsystem loop in ``tests/test_drivers.py``.

The fault seam (docs/robustness.md) is inert unless ``cfg.faults``
enables an injection class: ``fault_pipeline`` corrupts/screens/retries
the trained stacks, a quorum shortfall skips aggregation for the round
(globals carry over, ``RoundLog.fused=False``), and ``guard_globals``
rolls non-finite fused params back to the round's starting globals.
"""
from __future__ import annotations

from repro.core.engine import _UNSET, RoundEngine
from repro.drivers.base import Driver, register_driver


@register_driver("sync")
class SyncDriver(Driver):
    def __init__(self, staleness: int = 0, prefetch: int = 1):
        if staleness != 0:
            # mirror spec validation: silently running sync semantics
            # when the caller asked for overlap would be a lie
            raise ValueError(
                f"{type(self).__name__} runs sync semantics; staleness="
                f"{staleness} only applies to the async_pipelined driver")
        super().__init__(staleness=staleness, prefetch=prefetch)

    def run(self, engine: RoundEngine, *, log_fn=None, init_globals=None,
            init_state=_UNSET, start_round=1, init_logs=None,
            round_end_hook=None):
        globals_, state, logs, rng = self._setup(
            engine, init_globals, init_state, init_logs, start_round)
        rounds_to_target = None

        for t in range(start_round, engine.cfg.rounds + 1):
            active = engine.sample_cohort(rng)
            batches = engine.build_round_batches(t, active)
            groups = engine.train_clients(t, globals_, batches)
            fstats = engine.fault_pipeline(t, groups, batches)
            fuse = engine.quorum_met(fstats)
            prev = list(globals_)
            if fuse:
                globals_, state, infos, dropped, ens_acc = engine.aggregate(
                    t, groups, state)
                globals_, rolled = engine.guard_globals(globals_, prev)
            else:  # quorum shortfall: carry the globals, skip fusion
                infos = [{} for _ in range(engine.n_proto)]
                dropped = [0] * engine.n_proto
                ens_acc = None
                rolled = [False] * engine.n_proto
            round_logs = engine.evaluate_round(t, globals_, groups, infos,
                                               dropped, ens_acc)
            if fstats is not None:
                for p, log in enumerate(round_logs):
                    log.n_corrupted = fstats["corrupted"]
                    log.n_quarantined = fstats["quarantined"]
                    log.n_retries = fstats["retries"]
                    log.fused = fuse
                    log.rolled_back = bool(log.rolled_back or rolled[p])
            reached, stop_requested = self._emit_round(
                engine, t, round_logs, logs, log_fn)
            if reached:
                rounds_to_target = t

            # target check precedes the hook so checkpoints record the
            # stop — a resumed run must not retrain past a recorded stop
            if round_end_hook is not None:
                round_end_hook(t, globals_, state, logs, rounds_to_target)

            if rounds_to_target is not None or stop_requested:
                break

        return self._results(engine, logs, globals_, rounds_to_target)

"""Aggregate experiments/dryrun/*.json into the §Roofline table (markdown +
CSV lines).  Run after `python -m repro.launch.dryrun --all`."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(variant="baseline", mesh=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if r.get("variant") != variant:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def markdown_table(recs) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | fit/skip |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                        f"| — | — | — | SKIP: {r['skipped'][:48]} |")
            continue
        if not r.get("ok") or "roofline" not in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                        f"| — | — | — | — | — | "
                        f"FAIL: {r.get('error','?')[:40]} |")
            continue
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} "
            f"| {rf['collective_s']:.3g} | {rf['dominant'][:-2]} "
            f"| {ratio:.2f} | ok |" if ratio else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} "
            f"| {rf['collective_s']:.3g} | {rf['dominant'][:-2]} | — | ok |")
    return hdr + "\n".join(rows)


def run(seed: int = 0) -> dict:
    recs = load()
    n_ok = sum(1 for r in recs if r.get("ok") and "roofline" in r)
    n_skip = sum(1 for r in recs if "skipped" in r)
    n_fail = sum(1 for r in recs if not r.get("ok"))
    print(f"roofline_report,0,pairs_ok={n_ok};skips={n_skip};fails={n_fail}")
    md = markdown_table(recs)
    out = os.path.join(DRYRUN_DIR, "roofline_table.md")
    with open(out, "w") as f:
        f.write(md + "\n")
    return {"ok": n_ok, "skip": n_skip, "fail": n_fail, "table": md}


if __name__ == "__main__":
    run()
